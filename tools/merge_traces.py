"""Merge per-process Chrome trace files into one Perfetto-loadable doc.

``python -m tools.merge_traces -o merged.json trace.p0.json trace.p1.json``

Each ``dist`` worker records with its process index as the Chrome ``pid``
(see :mod:`repro.obs`), so the merge is pure event concatenation — lanes
stay grouped per process, and per-file dropped-record counts are summed
into ``otherData.dropped_records``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="merge per-process Chrome trace JSON files")
    ap.add_argument("inputs", nargs="+", help="per-process trace files")
    ap.add_argument("-o", "--out", required=True, help="merged output path")
    args = ap.parse_args(argv)

    try:
        from repro.obs.trace import merge_traces
    except ModuleNotFoundError:   # run from the repo root without PYTHONPATH
        sys.path.insert(0, os.path.join(
            os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
            "src"))
        from repro.obs.trace import merge_traces

    docs = []
    for path in args.inputs:
        with open(path) as f:
            docs.append(json.load(f))
    merged = merge_traces(docs)
    with open(args.out, "w") as f:
        json.dump(merged, f)
    print(f"[merge_traces] {args.out}: {len(merged['traceEvents'])} events "
          f"from {len(docs)} processes")
    return 0


if __name__ == "__main__":
    sys.exit(main())
