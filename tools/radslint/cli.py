"""Command line interface: ``python -m tools.radslint [options]``."""
from __future__ import annotations

import argparse
import sys

from tools.radslint.api import lint_project, load_default_config


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="radslint",
        description="jit-safety / determinism / recompile-trigger static "
                    "analysis for the RADS engine")
    ap.add_argument("--project-root", default=None,
                    help="repo root (default: nearest pyproject.toml)")
    ap.add_argument("--no-baseline", action="store_true",
                    help="report every finding, ignoring baseline.json")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite baseline.json from the current findings "
                         "(the ratchet should only ever shrink)")
    args = ap.parse_args(argv)

    cfg = load_default_config(args.project_root)
    res = lint_project(cfg, use_baseline=not args.no_baseline,
                       update_baseline=args.update_baseline)
    print(res.render())
    if args.update_baseline:
        print(f"baseline updated: {cfg.baseline} "
              f"({len(res.baselined)} entries)")
        return 0
    return 0 if res.ok else 1


if __name__ == "__main__":
    sys.exit(main())
