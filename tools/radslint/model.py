"""Findings and the inline suppression grammar.

A finding is suppressed by a trailing (or immediately preceding) comment::

    # radslint: allow[RL001] intentional wave-retire sync point
    # radslint: allow[RL001,RL003] <justification>

The justification is mandatory: an ``allow`` with no text after the bracket
is itself reported as RL000 (invalid-suppression), so the committed code can
never grow silent waivers.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from pathlib import Path

CHECKER_TITLES = {
    "RL000": "invalid suppression",
    "RL001": "host sync / tracer leak inside jit-reachable code",
    "RL002": "recompile trigger",
    "RL003": "nondeterminism hazard",
    "RL004": "stat field not threaded end to end",
    "RL005": "64-bit dtype inside jitted code (x64 is off)",
}


@dataclass(frozen=True)
class Finding:
    checker: str          # "RL001" ... "RL005" (or "RL000")
    file: str             # path relative to project root, posix separators
    line: int             # 1-based
    message: str
    hint: str = ""

    def render(self) -> str:
        out = f"{self.file}:{self.line}: {self.checker} {self.message}"
        if self.hint:
            out += f"\n    hint: {self.hint}"
        return out

    def baseline_key(self, line_text: str) -> tuple[str, str, str]:
        """Line-number-free identity used by the ratchet file: moving code
        around does not resurrect a baselined finding, editing the line does."""
        return (self.file, self.checker, line_text.strip())


_ALLOW_RE = re.compile(
    r"#\s*radslint:\s*allow\[(?P<ids>RL\d{3}(?:\s*,\s*RL\d{3})*)\]"
    r"(?P<just>[^#]*)")


@dataclass
class Suppressions:
    """Per-file map of line -> allowed checker ids (with justifications)."""

    by_line: dict[int, set[str]] = field(default_factory=dict)
    invalid: list[Finding] = field(default_factory=list)

    def allows(self, line: int, checker: str) -> bool:
        # an allow comment covers its own line and the line directly below,
        # so both trailing and preceding-line placement work
        return (checker in self.by_line.get(line, ()) or
                checker in self.by_line.get(line - 1, ()))


def scan_suppressions(path: str, source: str) -> Suppressions:
    sup = Suppressions()
    for lineno, text in enumerate(source.splitlines(), start=1):
        m = _ALLOW_RE.search(text)
        if not m:
            continue
        ids = {s.strip() for s in m.group("ids").split(",")}
        if not m.group("just").strip():
            sup.invalid.append(Finding(
                "RL000", path, lineno,
                "suppression without a justification",
                hint="write `# radslint: allow[RLnnn] <why this is safe>`"))
            continue
        sup.by_line.setdefault(lineno, set()).update(ids)
    return sup


def relpath(path: Path, root: Path) -> str:
    try:
        return path.resolve().relative_to(root).as_posix()
    except ValueError:
        return path.as_posix()
