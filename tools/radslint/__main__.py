import sys

from tools.radslint.cli import main

sys.exit(main())
