"""radslint programmatic entry point (the CLI and the tests both use this)."""
from __future__ import annotations

from dataclasses import dataclass, field
from pathlib import Path

from tools.radslint.baseline import (load_baseline, save_baseline,
                                     split_by_baseline)
from tools.radslint.callgraph import ProjectIndex, build_call_graph
from tools.radslint.checkers import LintContext, run_checkers
from tools.radslint.config import Config, load_config
from tools.radslint.model import Finding, scan_suppressions
from tools.radslint.taint import ClassRegistry


@dataclass
class LintResult:
    findings: list[Finding] = field(default_factory=list)   # new (failing)
    baselined: list[Finding] = field(default_factory=list)
    suppressed: int = 0
    n_reachable: int = 0
    n_files: int = 0

    @property
    def ok(self) -> bool:
        return not self.findings

    def render(self) -> str:
        lines = [f.render() for f in self.findings]
        lines.append(
            f"radslint: {len(self.findings)} new finding(s), "
            f"{len(self.baselined)} baselined, {self.suppressed} "
            f"suppressed inline ({self.n_files} files, "
            f"{self.n_reachable} jit-reachable functions)")
        return "\n".join(lines)


def load_default_config(project_root: str | Path | None = None) -> Config:
    """Config from ``<root>/pyproject.toml``; the root defaults to the
    nearest ancestor of cwd that has a pyproject.toml."""
    if project_root is None:
        cur = Path.cwd()
        for cand in [cur, *cur.parents]:
            if (cand / "pyproject.toml").exists():
                cur = cand
                break
        project_root = cur
    return load_config(Path(project_root))


def lint_project(cfg: Config, use_baseline: bool = True,
                 update_baseline: bool = False) -> LintResult:
    index = ProjectIndex(cfg)
    graph = build_call_graph(index)
    ctx = LintContext(cfg=cfg, index=index, graph=graph,
                      reg=ClassRegistry(index))
    raw = run_checkers(ctx)

    res = LintResult(n_reachable=len(graph.reachable),
                     n_files=len(index.modules))

    # inline suppressions (and their RL000 twins for missing justifications)
    sups = {mod.rel: scan_suppressions(mod.rel, mod.source)
            for mod in index.modules.values()}
    kept: list[Finding] = []
    for f in raw:
        sup = sups.get(f.file)
        if sup is not None and sup.allows(f.line, f.checker):
            res.suppressed += 1
        else:
            kept.append(f)
    for sup in sups.values():
        kept.extend(sup.invalid)
    kept.sort(key=lambda f: (f.file, f.line, f.checker))

    bl_path = cfg.project_root / cfg.baseline
    if update_baseline:
        save_baseline(bl_path, cfg.project_root, kept)
        res.baselined = kept
        return res
    baseline = load_baseline(bl_path) if use_baseline else set()
    res.findings, res.baselined = split_by_baseline(
        cfg.project_root, kept, baseline)
    return res
