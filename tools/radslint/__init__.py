"""radslint — jit-safety, determinism and recompile-trigger static analysis
for the RADS engine.

The analyzer is purely AST based (it never imports the code under analysis):
it builds a project index over the configured roots, roots a call graph at
the jitted engine entry points, and runs five checkers over everything
reachable inside a trace (plus the configured host-side hot loops):

* RL001 — host syncs / tracer leaks inside jit-reachable code,
* RL002 — recompile triggers (scalar jit params without ``static_argnames``,
  closure-captured mutables, capacities off the power-of-two ladder),
* RL003 — determinism hazards (``jnp.unique`` without ``size=``,
  unannotated duplicate-index scatter-adds, set/dict iteration order
  feeding array construction),
* RL004 — stat-threading (every ``bytes_*``/``*_hits``/``*_probes``
  WaveState field must reach ``finalize_wave`` and every configured
  consumer),
* RL005 — dtype hygiene (64-bit dtypes inside jitted code; x64 is off).

See ``tools/radslint/README.md`` for the design note and the suppression
grammar (``# radslint: allow[RLnnn] <justification>``).
"""
from tools.radslint.api import lint_project, load_default_config  # noqa: F401

__version__ = "0.1.0"
