"""The zero-new-findings ratchet.

``baseline.json`` holds the accepted pre-existing findings; anything not in
it fails the lint.  Entries are keyed by ``(file, checker, stripped line
text)`` — line-number free, so unrelated edits cannot resurrect a baselined
finding, while editing the offending line itself re-surfaces it.  The file
is committed and should only ever shrink.
"""
from __future__ import annotations

import json
from pathlib import Path

from tools.radslint.model import Finding

VERSION = 1


def _line_text(project_root: Path, finding: Finding,
               cache: dict[str, list[str]]) -> str:
    lines = cache.get(finding.file)
    if lines is None:
        p = project_root / finding.file
        lines = cache[finding.file] = (
            p.read_text().splitlines() if p.exists() else [])
    if 1 <= finding.line <= len(lines):
        return lines[finding.line - 1].strip()
    return ""


def load_baseline(path: Path) -> set[tuple[str, str, str]]:
    if not path.exists():
        return set()
    data = json.loads(path.read_text())
    return {(e["file"], e["checker"], e["text"])
            for e in data.get("findings", [])}


def save_baseline(path: Path, project_root: Path,
                  findings: list[Finding]) -> None:
    cache: dict[str, list[str]] = {}
    entries = sorted({(f.file, f.checker,
                       _line_text(project_root, f, cache))
                      for f in findings})
    path.write_text(json.dumps(
        {"version": VERSION,
         "findings": [{"file": a, "checker": b, "text": c}
                      for a, b, c in entries]}, indent=2) + "\n")


def split_by_baseline(project_root: Path, findings: list[Finding],
                      baseline: set[tuple[str, str, str]]
                      ) -> tuple[list[Finding], list[Finding]]:
    """-> (new, baselined)."""
    cache: dict[str, list[str]] = {}
    new, old = [], []
    for f in findings:
        key = f.baseline_key(_line_text(project_root, f, cache))
        (old if key in baseline else new).append(f)
    return new, old
