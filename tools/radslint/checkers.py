"""The five radslint checkers (see package docstring and README).

Each checker is a pure function ``(LintContext) -> list[Finding]``; the
orchestration (suppressions, baseline, output) lives in ``api.py``.
"""
from __future__ import annotations

import ast
import re
from dataclasses import dataclass, field

from tools.radslint.callgraph import CallGraph, FuncInfo, ProjectIndex
from tools.radslint.config import Config
from tools.radslint.model import Finding
from tools.radslint.taint import (ClassRegistry, FunctionTaint, Taint,
                                  dotted_name)

_JNP_CONSTRUCTORS = {"jax.numpy.array", "jax.numpy.asarray",
                     "jax.numpy.stack", "jax.numpy.concatenate"}
_SCATTER_METHODS = {"add", "mul", "max", "min"}
_WIDE_DTYPES = {"int64", "float64", "uint64"}


@dataclass
class LintContext:
    cfg: Config
    index: ProjectIndex
    graph: CallGraph
    reg: ClassRegistry
    taints: dict[str, FunctionTaint] = field(default_factory=dict)
    hot_taints: dict[str, FunctionTaint] = field(default_factory=dict)

    def taint_for(self, fi: FuncInfo) -> FunctionTaint:
        ft = self.taints.get(fi.qualname)
        if ft is None:
            ft = self.taints[fi.qualname] = FunctionTaint(
                fi, self.index, self.reg)
        return ft

    def hot_taint_for(self, fi: FuncInfo) -> FunctionTaint:
        ft = self.hot_taints.get(fi.qualname)
        if ft is None:
            ft = self.hot_taints[fi.qualname] = FunctionTaint(
                fi, self.index, self.reg,
                hot_traced_calls=set(self.cfg.hot_traced_calls))
        return ft


def _hot_funcs(ctx: LintContext) -> list[FuncInfo]:
    return [fi for q in ctx.cfg.hot_loops
            if (fi := ctx.index.resolve(q)) is not None]


# --------------------------------------------------------------------------- #
# RL001 — host sync / tracer leak
# --------------------------------------------------------------------------- #
def check_rl001(ctx: LintContext) -> list[Finding]:
    out: list[Finding] = []
    for fi in ctx.graph.reachable.values():
        out += _rl001_walk(ctx, fi, ctx.taint_for(fi), where="jit-reachable",
                           strict_item=True)
    for fi in _hot_funcs(ctx):
        if fi.qualname in ctx.graph.reachable:
            continue
        out += _rl001_walk(ctx, fi, ctx.hot_taint_for(fi),
                           where="hot wave loop", strict_item=False)
    return out


def _rl001_walk(ctx: LintContext, fi: FuncInfo, ft: FunctionTaint,
                where: str, strict_item: bool) -> list[Finding]:
    out: list[Finding] = []
    rel = fi.module.rel

    def emit(node, msg, hint):
        out.append(Finding("RL001", rel, node.lineno,
                           f"{msg} [{where}: {fi.qualname}]", hint))

    for node in ast.walk(fi.node):
        if isinstance(node, (ast.If, ast.While)):
            if ft.taint(node.test) == Taint.TRACED:
                kw = "while" if isinstance(node, ast.While) else "if"
                emit(node.test, f"Python `{kw}` branches on a traced value",
                     "use jnp.where / lax.cond, or device_get once at the "
                     "drain point")
        elif isinstance(node, ast.IfExp):
            if ft.taint(node.test) == Taint.TRACED:
                emit(node.test, "conditional expression on a traced value",
                     "use jnp.where(cond, a, b)")
        elif isinstance(node, ast.For):
            if ft.taint(node.iter) == Taint.TRACED:
                emit(node.iter, "Python `for` iterates a traced value",
                     "use lax.scan / lax.fori_loop, or iterate a static "
                     "shape-derived range")
        elif isinstance(node, ast.Call):
            name = dotted_name(node.func, fi.module)
            args = list(node.args) + [kw.value for kw in node.keywords]
            traced_arg = any(ft.taint(a) == Taint.TRACED for a in args)
            if name in ("int", "float", "bool", "len") and traced_arg:
                emit(node, f"`{name}()` on a traced value forces a host "
                     "sync", "keep it on device, or batch the transfer "
                     "with jax.device_get at the wave drain point")
            elif name is not None and name.startswith("numpy.") and \
                    traced_arg:
                emit(node, f"`{name.replace('numpy.', 'np.')}` call on a "
                     "traced value pulls it to host",
                     "use the jnp equivalent, or jax.device_get once")
            elif isinstance(node.func, ast.Attribute) and \
                    node.func.attr in ("item", "tolist"):
                base = ft.taint(node.func.value)
                if base == Taint.TRACED or (strict_item and
                                            base == Taint.UNKNOWN):
                    emit(node, f"`.{node.func.attr}()` forces a host sync",
                         "thread the value through the returned state "
                         "instead of reading it mid-trace")
    return out


# --------------------------------------------------------------------------- #
# RL002 — recompile triggers
# --------------------------------------------------------------------------- #
def _fold_int(e: ast.expr):
    if isinstance(e, ast.Constant) and type(e.value) is int:
        return e.value
    if isinstance(e, ast.UnaryOp) and isinstance(e.op, ast.USub):
        v = _fold_int(e.operand)
        return -v if v is not None else None
    if isinstance(e, ast.BinOp):
        lv, rv = _fold_int(e.left), _fold_int(e.right)
        if lv is None or rv is None:
            return None
        ops = {ast.LShift: lambda a, b: a << b,
               ast.RShift: lambda a, b: a >> b,
               ast.Mult: lambda a, b: a * b,
               ast.Add: lambda a, b: a + b,
               ast.Sub: lambda a, b: a - b,
               ast.Pow: lambda a, b: a ** b,
               ast.FloorDiv: lambda a, b: a // b if b else None}
        fn = ops.get(type(e.op))
        return fn(lv, rv) if fn else None
    return None


def _on_ladder(v: int, base: int) -> bool:
    if v < 1:
        return False
    while v % base == 0:
        v //= base
    return v == 1


def _static_names(fi: FuncInfo) -> set[str]:
    """Names in static_argnames of a @jax.jit/@partial(jax.jit,...) def."""
    names: set[str] = set()
    if isinstance(fi.node, ast.Lambda):
        return names
    for dec in fi.node.decorator_list:
        if not isinstance(dec, ast.Call):
            continue
        for kw in dec.keywords:
            if kw.arg == "static_argnames":
                if isinstance(kw.value, ast.Constant) and \
                        isinstance(kw.value.value, str):
                    names.add(kw.value.value)
                elif isinstance(kw.value, (ast.Tuple, ast.List)):
                    names |= {el.value for el in kw.value.elts
                              if isinstance(el, ast.Constant)}
    return names


def check_rl002(ctx: LintContext) -> list[Finding]:
    out: list[Finding] = []
    cap_re = ctx.cfg.cap_re()
    base = ctx.cfg.ladder_base

    # (a) scalar params of directly-jitted defs must be static_argnames
    for fi in ctx.graph.jit_defs.values():
        statics = _static_names(fi)
        a = fi.node.args
        for p in list(a.posonlyargs) + list(a.args) + list(a.kwonlyargs):
            if p.annotation is None or p.arg in statics:
                continue
            ann = ast.unparse(p.annotation).strip()
            if ann in ("int", "bool", "str"):
                out.append(Finding(
                    "RL002", fi.module.rel, p.lineno,
                    f"jitted `{fi.name}` takes Python scalar `{p.arg}: "
                    f"{ann}` without static_argnames — every new value "
                    "re-traces",
                    "add it to static_argnames, or pass a device array"))

    for mod in ctx.index.modules.values():
        # (b) jit lambdas must not close over mutable locals
        mutable_bindings = _mutable_local_bindings(mod.tree)
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Call) and node.args and \
                    isinstance(node.args[0], ast.Lambda):
                name = dotted_name(node.func, mod)
                if name not in ("jax.jit", "jit"):
                    continue
                lam = node.args[0]
                params = {p.arg for p in lam.args.args +
                          lam.args.posonlyargs + lam.args.kwonlyargs}
                for free in ast.walk(lam.body):
                    if isinstance(free, ast.Name) and \
                            isinstance(free.ctx, ast.Load) and \
                            free.id not in params and \
                            free.id in mutable_bindings:
                        out.append(Finding(
                            "RL002", mod.rel, lam.lineno,
                            f"jit lambda closes over mutable `{free.id}` — "
                            "identity changes silently re-trace",
                            "close over immutables (tuple / frozen "
                            "dataclass), or pass it as a pytree argument"))

        # (c) literal capacities must sit on the escalation ladder
        for node in ast.walk(mod.tree):
            tgt_val: list[tuple[str, ast.expr, int]] = []
            if isinstance(node, ast.Assign):
                for t in node.targets:
                    if isinstance(t, ast.Name):
                        tgt_val.append((t.id, node.value, node.lineno))
            elif isinstance(node, ast.AnnAssign) and \
                    isinstance(node.target, ast.Name) and node.value:
                tgt_val.append((node.target.id, node.value, node.lineno))
            elif isinstance(node, ast.Call):
                for kw in node.keywords:
                    if kw.arg:
                        tgt_val.append((kw.arg, kw.value, kw.value.lineno))
            for name, value, lineno in tgt_val:
                if not cap_re.search(name):
                    continue
                v = _fold_int(value)
                if v is not None and not _on_ladder(v, base):
                    out.append(Finding(
                        "RL002", mod.rel, lineno,
                        f"capacity `{name} = {v}` is off the power-of-"
                        f"{base} escalation ladder — warm-started caps "
                        "will never hit the jit cache",
                        f"round up to {_next_ladder(v, base)}"))
    return out


def _next_ladder(v: int, base: int) -> int:
    out = 1
    while out < max(v, 1):
        out *= base
    return out


def _mutable_local_bindings(tree: ast.Module) -> set[str]:
    out: set[str] = set()
    for node in ast.walk(tree):
        if not isinstance(node, ast.Assign):
            continue
        mutable = isinstance(node.value, (ast.List, ast.Dict, ast.Set,
                                          ast.ListComp, ast.DictComp,
                                          ast.SetComp)) or (
            isinstance(node.value, ast.Call) and
            isinstance(node.value.func, ast.Name) and
            node.value.func.id in ("list", "dict", "set", "bytearray"))
        if mutable:
            for t in node.targets:
                if isinstance(t, ast.Name):
                    out.add(t.id)
    return out


# --------------------------------------------------------------------------- #
# RL003 — determinism hazards
# --------------------------------------------------------------------------- #
def _set_derived(e: ast.expr) -> bool:
    if isinstance(e, (ast.Set, ast.SetComp, ast.DictComp)):
        return True
    if isinstance(e, ast.Call):
        if isinstance(e.func, ast.Name) and \
                e.func.id in ("set", "frozenset"):
            return True
        if isinstance(e.func, ast.Attribute) and \
                e.func.attr in ("keys", "values", "items"):
            return True
    return False


def _const_index(e: ast.expr) -> bool:
    if isinstance(e, (ast.Constant, ast.Slice)):
        return True
    if isinstance(e, ast.Tuple):
        return all(_const_index(el) for el in e.elts)
    if isinstance(e, ast.UnaryOp):
        return _const_index(e.operand)
    return False


def check_rl003(ctx: LintContext) -> list[Finding]:
    out: list[Finding] = []
    for fi in ctx.graph.reachable.values():
        rel = fi.module.rel
        for node in ast.walk(fi.node):
            if isinstance(node, ast.For) and _set_derived(node.iter):
                out.append(Finding(
                    "RL003", rel, node.iter.lineno,
                    "iteration order of a set/dict feeds traced code "
                    f"[{fi.qualname}]",
                    "iterate a sorted(...) or an ordered sequence"))
            if not isinstance(node, ast.Call):
                continue
            name = dotted_name(node.func, fi.module)
            if name == "jax.numpy.unique":
                if not any(kw.arg == "size" for kw in node.keywords):
                    out.append(Finding(
                        "RL003", rel, node.lineno,
                        f"jnp.unique without size= [{fi.qualname}] — "
                        "output shape becomes data-dependent",
                        "pass size=<cap>, fill_value=<sentinel>"))
            if name in _JNP_CONSTRUCTORS and \
                    any(_set_derived(a) for a in node.args):
                out.append(Finding(
                    "RL003", rel, node.lineno,
                    "array built from set/dict iteration order "
                    f"[{fi.qualname}]",
                    "sort first — device arrays must not depend on hash "
                    "order"))
            # X.at[idx].add(...) with a data-dependent idx
            if isinstance(node.func, ast.Attribute) and \
                    node.func.attr in _SCATTER_METHODS and \
                    isinstance(node.func.value, ast.Subscript) and \
                    isinstance(node.func.value.value, ast.Attribute) and \
                    node.func.value.value.attr == "at":
                idx = node.func.value.slice
                kws = {kw.arg for kw in node.keywords}
                if not _const_index(idx) and \
                        not ({"unique_indices", "mode"} & kws):
                    out.append(Finding(
                        "RL003", rel, node.lineno,
                        f".at[].{node.func.attr} scatter with potentially "
                        f"duplicate indices [{fi.qualname}]",
                        "pass unique_indices=True or mode=..., or suppress "
                        "with a justification if duplicates are summed "
                        "deterministically (integer adds)"))
    return out


# --------------------------------------------------------------------------- #
# RL004 — stat threading
# --------------------------------------------------------------------------- #
def check_rl004(ctx: LintContext) -> list[Finding]:
    return _rl004_stat_fields(ctx) + _rl004_metric_schema(ctx)


def _rl004_stat_fields(ctx: LintContext) -> list[Finding]:
    cfg = ctx.cfg
    if not cfg.stat_state or "." not in cfg.stat_state:
        return []
    mod_q, clsname = cfg.stat_state.rsplit(".", 1)
    mod = ctx.index.modules.get(mod_q)
    if mod is None:
        return []
    pats = cfg.stat_res()
    fields = [(f, ln) for f, ln in ctx.reg.stat_fields(clsname)
              if any(p.search(f) for p in pats)]

    fin = ctx.index.resolve(cfg.stat_finalizer) if cfg.stat_finalizer else None
    fin_names: set[str] = set()
    if fin is not None:
        for node in ast.walk(fin.node):
            if isinstance(node, ast.keyword) and node.arg:
                fin_names.add(node.arg)
            elif isinstance(node, ast.Attribute):
                fin_names.add(node.attr)
            elif isinstance(node, ast.Constant) and \
                    isinstance(node.value, str):
                fin_names.add(node.value)

    consumers: list[tuple[str, str]] = []
    for relp in cfg.stat_consumers:
        p = cfg.project_root / relp
        consumers.append((relp, p.read_text() if p.exists() else ""))

    out: list[Finding] = []
    for f, ln in fields:
        if fin is not None and f not in fin_names:
            out.append(Finding(
                "RL004", mod.rel, ln,
                f"stat field `{clsname}.{f}` never reaches "
                f"`{cfg.stat_finalizer.rsplit('.', 1)[-1]}`",
                "thread it into the finalized stats dict"))
        for relp, text in consumers:
            if not re.search(rf"\b{re.escape(f)}\b", text):
                out.append(Finding(
                    "RL004", mod.rel, ln,
                    f"stat field `{clsname}.{f}` is not consumed in "
                    f"{relp}",
                    "surface it (driver stats key / benchmark column) or "
                    "drop the field"))
    return out


_METRIC_CTORS = {"counter", "gauge", "info", "histogram"}


def _rl004_metric_schema(ctx: LintContext) -> list[Finding]:
    """Every instrument the metric schema module declares (a literal
    ``counter("name", ...)`` / ``gauge`` / ``info`` / ``histogram`` call)
    must be surfaced by at least one configured consumer — the same
    registry -> exporter -> benchmark-column threading guarantee
    ``WaveState`` byte counters get from the stat-field half above."""
    cfg = ctx.cfg
    if not cfg.metric_schema:
        return []
    mod = ctx.index.modules.get(cfg.metric_schema)
    if mod is None:
        return []
    declared: list[tuple[str, int]] = []
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Call) and \
                isinstance(node.func, ast.Name) and \
                node.func.id in _METRIC_CTORS and node.args and \
                isinstance(node.args[0], ast.Constant) and \
                isinstance(node.args[0].value, str):
            declared.append((node.args[0].value, node.lineno))
    blob = "\n".join(
        (cfg.project_root / relp).read_text()
        if (cfg.project_root / relp).exists() else ""
        for relp in cfg.metric_consumers)
    out: list[Finding] = []
    for name, ln in declared:
        if not re.search(rf"\b{re.escape(name)}\b", blob):
            out.append(Finding(
                "RL004", mod.rel, ln,
                f"metric instrument `{name}` is declared but never "
                "exported by any configured metric consumer",
                "surface it (registry summary / exporter / benchmark "
                "column) or drop the declaration"))
    return out


# --------------------------------------------------------------------------- #
# RL005 — dtype hygiene
# --------------------------------------------------------------------------- #
def check_rl005(ctx: LintContext) -> list[Finding]:
    out: list[Finding] = []
    for fi in ctx.graph.reachable.values():
        rel = fi.module.rel
        for node in ast.walk(fi.node):
            if isinstance(node, ast.Attribute) and \
                    node.attr in _WIDE_DTYPES:
                base = dotted_name(node.value, fi.module)
                if base in ("jax.numpy", "numpy"):
                    out.append(Finding(
                        "RL005", rel, node.lineno,
                        f"64-bit dtype `{node.attr}` inside jitted code "
                        f"[{fi.qualname}] — x64 is disabled, this "
                        "silently truncates (or forces x64 on)",
                        "use the 32-bit dtype"))
            elif isinstance(node, ast.Call):
                wide = []
                if isinstance(node.func, ast.Attribute) and \
                        node.func.attr == "astype":
                    wide += [a for a in node.args
                             if isinstance(a, ast.Constant) and
                             a.value in _WIDE_DTYPES]
                wide += [kw.value for kw in node.keywords
                         if kw.arg == "dtype" and
                         isinstance(kw.value, ast.Constant) and
                         kw.value.value in _WIDE_DTYPES]
                for w in wide:
                    out.append(Finding(
                        "RL005", rel, node.lineno,
                        f"64-bit dtype string {w.value!r} inside jitted "
                        f"code [{fi.qualname}]",
                        "use the 32-bit dtype"))
    return out


ALL_CHECKERS = (check_rl001, check_rl002, check_rl003, check_rl004,
                check_rl005)


def run_checkers(ctx: LintContext) -> list[Finding]:
    seen: set[tuple] = set()
    out: list[Finding] = []
    for chk in ALL_CHECKERS:
        for f in chk(ctx):
            key = (f.checker, f.file, f.line, f.message)
            if key not in seen:
                seen.add(key)
                out.append(f)
    out.sort(key=lambda f: (f.file, f.line, f.checker))
    return out
