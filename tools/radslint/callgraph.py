"""Project index and the jit-rooted call graph.

The analyzer never imports the code under analysis: every file under the
configured roots is parsed, functions (including nested defs and methods)
are indexed under dotted qualnames derived from the file path, and a call
graph is rooted at

* the configured entry points (``[tool.radslint] entrypoints``),
* every function decorated ``@jax.jit`` / ``@partial(jax.jit, ...)``, and
* every Name or lambda passed directly to a ``jax.jit(...)`` call site
  (the :class:`StageRunner` jit-cache pattern).

Reachability is conservative: plain-name calls resolve through module scope
and the import map; ``mod.fn`` attribute calls resolve through imported
modules; bare method calls match every indexed method of that name; and any
function *referenced* as a call argument (``jax.vmap(f)``, ``lax.scan(f,
...)``) is treated as called.  Over-approximation only ever costs a
suppression comment — under-approximation would cost a missed host sync.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from pathlib import Path

from tools.radslint.config import Config
from tools.radslint.model import relpath

FunctionNode = ast.FunctionDef | ast.AsyncFunctionDef | ast.Lambda


@dataclass
class FuncInfo:
    qualname: str                  # e.g. repro.core.engine.fetch_stage
    name: str                      # bare name ("<lambda>" for lambdas)
    module: "ModuleInfo"
    node: FunctionNode
    is_method: bool = False


@dataclass
class ModuleInfo:
    path: Path
    rel: str                       # project-root-relative posix path
    qualname: str                  # dotted module name
    source: str
    tree: ast.Module
    funcs: dict[str, FuncInfo] = field(default_factory=dict)
    imports: dict[str, str] = field(default_factory=dict)
    classes: dict[str, ast.ClassDef] = field(default_factory=dict)


class _Collector(ast.NodeVisitor):
    def __init__(self, mod: ModuleInfo):
        self.mod = mod
        self.stack: list[tuple[str, str]] = []   # (kind, name)

    def _def(self, node: FunctionNode, name: str) -> None:
        qual = ".".join([self.mod.qualname] +
                        [n for _, n in self.stack] + [name])
        self.mod.funcs[qual] = FuncInfo(
            qualname=qual, name=name, module=self.mod, node=node,
            is_method=bool(self.stack) and self.stack[-1][0] == "class")

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        self._def(node, node.name)
        self.stack.append(("func", node.name))
        self.generic_visit(node)
        self.stack.pop()

    visit_AsyncFunctionDef = visit_FunctionDef  # type: ignore[assignment]

    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        if not self.stack:
            self.mod.classes[node.name] = node
        self.stack.append(("class", node.name))
        self.generic_visit(node)
        self.stack.pop()

    def visit_Import(self, node: ast.Import) -> None:
        for a in node.names:
            self.mod.imports[a.asname or a.name.split(".")[0]] = (
                a.name if a.asname else a.name.split(".")[0])

    def visit_ImportFrom(self, node: ast.ImportFrom) -> None:
        if node.level:                       # relative import
            parts = self.mod.qualname.split(".")[:-node.level]
            base = ".".join(parts + ([node.module] if node.module else []))
        else:
            base = node.module or ""
        for a in node.names:
            if a.name == "*":
                continue
            self.mod.imports[a.asname or a.name] = f"{base}.{a.name}"


class ProjectIndex:
    def __init__(self, cfg: Config):
        self.cfg = cfg
        self.modules: dict[str, ModuleInfo] = {}       # qualname -> info
        self.funcs: dict[str, FuncInfo] = {}           # qualname -> info
        self.methods_by_name: dict[str, list[FuncInfo]] = {}
        for root in cfg.roots:
            for path in sorted((cfg.project_root / root).rglob("*.py")):
                self._add(path)

    def _module_qualname(self, path: Path) -> str:
        resolved = path.resolve()
        for base in self.cfg.import_roots + [""]:
            basep = (self.cfg.project_root / base).resolve()
            try:
                rel = resolved.relative_to(basep)
            except ValueError:
                continue
            return ".".join(rel.with_suffix("").parts)
        return path.stem

    def _add(self, path: Path) -> None:
        source = path.read_text()
        mod = ModuleInfo(path=path,
                         rel=relpath(path, self.cfg.project_root),
                         qualname=self._module_qualname(path),
                         source=source, tree=ast.parse(source))
        _Collector(mod).visit(mod.tree)
        self.modules[mod.qualname] = mod
        for q, fi in mod.funcs.items():
            self.funcs[q] = fi
            if fi.is_method:
                self.methods_by_name.setdefault(fi.name, []).append(fi)

    # ---- resolution ---------------------------------------------------- #

    def resolve(self, qualified: str) -> FuncInfo | None:
        return self.funcs.get(qualified)

    def resolve_name(self, mod: ModuleInfo, name: str) -> FuncInfo | None:
        """A bare ``name`` used in ``mod``: module-level def, then imports."""
        hit = self.funcs.get(f"{mod.qualname}.{name}")
        if hit is not None:
            return hit
        target = mod.imports.get(name)
        return self.funcs.get(target) if target else None

    def resolve_call(self, mod: ModuleInfo, call: ast.Call) -> list[FuncInfo]:
        fn = call.func
        out: list[FuncInfo] = []
        if isinstance(fn, ast.Name):
            hit = self.resolve_name(mod, fn.id)
            if hit:
                out.append(hit)
        elif isinstance(fn, ast.Attribute):
            if isinstance(fn.value, ast.Name):
                target = mod.imports.get(fn.value.id)
                if target and target in self.modules:
                    hit = self.funcs.get(f"{target}.{fn.attr}")
                    if hit:
                        out.append(hit)
                        return out
            # bare method call: conservatively fan out to every indexed
            # method with this name (self.foo(), runner.fetch(), ...)
            out.extend(self.methods_by_name.get(fn.attr, []))
        # functions passed as values (vmap/scan/shard_map/cond operands)
        for arg in list(call.args) + [kw.value for kw in call.keywords]:
            if isinstance(arg, ast.Name):
                hit = self.resolve_name(mod, arg.id)
                if hit:
                    out.append(hit)
        return out


def _is_jax_jit(expr: ast.expr, mod: ModuleInfo) -> bool:
    """``jax.jit`` / ``jit`` (imported from jax) as an expression."""
    if isinstance(expr, ast.Attribute) and expr.attr == "jit" and \
            isinstance(expr.value, ast.Name) and \
            mod.imports.get(expr.value.id, expr.value.id) == "jax":
        return True
    if isinstance(expr, ast.Name):
        return mod.imports.get(expr.id) == "jax.jit"
    return False


def _jit_decorated(fi: FuncInfo) -> bool:
    if isinstance(fi.node, ast.Lambda):
        return False
    for dec in fi.node.decorator_list:
        if _is_jax_jit(dec, fi.module):
            return True
        if isinstance(dec, ast.Call):
            if _is_jax_jit(dec.func, fi.module):
                return True
            # @partial(jax.jit, static_argnames=...)
            if isinstance(dec.func, ast.Name) and \
                    dec.func.id == "partial" and dec.args and \
                    _is_jax_jit(dec.args[0], fi.module):
                return True
    return False


@dataclass
class CallGraph:
    index: ProjectIndex
    reachable: dict[str, FuncInfo]          # jit-reachable functions
    roots: dict[str, FuncInfo]
    jit_defs: dict[str, FuncInfo]           # directly @jax.jit-decorated

    def by_module(self) -> dict[ModuleInfo, list[FuncInfo]]:
        out: dict[ModuleInfo, list[FuncInfo]] = {}
        for fi in self.reachable.values():
            out.setdefault(fi.module, []).append(fi)
        return out


def build_call_graph(index: ProjectIndex) -> CallGraph:
    roots: dict[str, FuncInfo] = {}
    jit_defs: dict[str, FuncInfo] = {}

    for ep in index.cfg.entrypoints:
        fi = index.resolve(ep)
        if fi is not None:
            roots[fi.qualname] = fi

    for q, fi in index.funcs.items():
        if _jit_decorated(fi):
            roots[q] = fi
            jit_defs[q] = fi

    # jax.jit(...) call sites: Name or lambda first argument becomes a root
    for mod in index.modules.values():
        for node in ast.walk(mod.tree):
            if not (isinstance(node, ast.Call) and
                    _is_jax_jit(node.func, mod) and node.args):
                continue
            arg = node.args[0]
            if isinstance(arg, ast.Name):
                hit = index.resolve_name(mod, arg.id)
                if hit:
                    roots[hit.qualname] = hit
            elif isinstance(arg, ast.Lambda):
                q = f"{mod.qualname}.<jit-lambda@L{arg.lineno}>"
                fi = FuncInfo(qualname=q, name="<lambda>",
                              module=mod, node=arg)
                mod.funcs[q] = fi
                index.funcs[q] = fi
                roots[q] = fi

    reachable: dict[str, FuncInfo] = {}
    work = list(roots.values())
    while work:
        fi = work.pop()
        if fi.qualname in reachable:
            continue
        reachable[fi.qualname] = fi
        for node in ast.walk(fi.node):
            if isinstance(node, ast.Call):
                for callee in index.resolve_call(fi.module, node):
                    if callee.qualname not in reachable:
                        work.append(callee)
    return CallGraph(index=index, reachable=reachable,
                     roots=roots, jit_defs=jit_defs)
