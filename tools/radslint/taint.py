"""Intra-procedural taint analysis: which expressions hold traced values?

Three-point lattice ``STATIC < UNKNOWN < TRACED``.  Checkers only ever fire
on provably ``TRACED`` expressions (with narrow exceptions like ``.item()``),
so the analysis is tuned to be *sound about STATIC*: anything it cannot
prove host-side stays UNKNOWN and is never reported.  Sources of TRACED:

* parameters annotated with jax array types (``jnp.ndarray``, ``jax.Array``),
* fields of project dataclasses with such annotations (looked up through
  the class AST, merged over same-named classes and subclasses, traced wins),
* results of ``jnp.*`` / ``jax.lax.*`` / ``jax.vmap`` / project functions
  with traced return annotations,
* in *hot-loop* mode, results of the configured ``hot_traced_calls``
  (``runner.fetch(...)``, ``finalize_wave(...)``, ...) — the host wave loop
  handles device futures without being inside a trace itself.

``x is None`` / ``x is not None`` is always STATIC (shape-level dispatch,
not a value read), ``.shape``/``.ndim``/``.dtype``/``.size`` are STATIC,
and ``jax.device_get(...)`` results are STATIC — it is the sanctioned
batched transfer.
"""
from __future__ import annotations

import ast
from enum import IntEnum

from tools.radslint.callgraph import FuncInfo, ModuleInfo, ProjectIndex


class Taint(IntEnum):
    STATIC = 0
    UNKNOWN = 1
    TRACED = 2


class TV:
    """A taint value, optionally carrying a project class for field lookup."""

    __slots__ = ("taint", "cls")

    def __init__(self, taint: Taint, cls: str | None = None):
        self.taint = taint
        self.cls = cls


STATIC = TV(Taint.STATIC)
UNKNOWN = TV(Taint.UNKNOWN)
TRACED = TV(Taint.TRACED)

_SCALAR_ANNS = {"int", "bool", "float", "str", "bytes", "None", "object",
                "complex"}
_STATIC_HEADS = ("tuple", "list", "dict", "set", "frozenset", "np.ndarray",
                 "numpy.ndarray", "Path", "deque")
_TRACED_MARKS = ("jnp.", "jax.Array", "ArrayLike")
_STATIC_ATTRS = {"shape", "ndim", "dtype", "size", "sharding"}
# jax callables whose results live on the host
_JAX_STATIC_CALLS = {
    "jax.default_backend", "jax.devices", "jax.device_count",
    "jax.local_device_count", "jax.local_devices", "jax.device_get",
    "jax.tree_util.tree_structure", "jax.eval_shape", "jax.named_scope",
}
_HOST_CASTS = {"int", "float", "bool", "len", "str", "repr", "format"}


def dotted_name(expr: ast.expr, mod: ModuleInfo) -> str | None:
    """``np.asarray`` -> ``numpy.asarray`` through the module's import map."""
    parts: list[str] = []
    while isinstance(expr, ast.Attribute):
        parts.append(expr.attr)
        expr = expr.value
    if not isinstance(expr, ast.Name):
        return None
    base = mod.imports.get(expr.id, expr.id)
    return ".".join([base] + parts[::-1])


class ClassRegistry:
    """Field / method-return annotations across all indexed classes, with
    same-name merge and one-name-based subclass closure (traced wins)."""

    def __init__(self, index: ProjectIndex):
        self.index = index
        self.by_name: dict[str, list[ast.ClassDef]] = {}
        for mod in index.modules.values():
            for name, cd in mod.classes.items():
                self.by_name.setdefault(name, []).append(cd)
        self.subs: dict[str, set[str]] = {n: {n} for n in self.by_name}
        changed = True
        while changed:
            changed = False
            for name, cds in self.by_name.items():
                for cd in cds:
                    for b in cd.bases:
                        bn = b.id if isinstance(b, ast.Name) else (
                            b.attr if isinstance(b, ast.Attribute) else None)
                        if bn in self.subs and name not in self.subs[bn]:
                            self.subs[bn].add(name)
                            changed = True

    def has(self, name: str) -> bool:
        return name in self.by_name

    def _defs(self, clsname: str) -> list[ast.ClassDef]:
        return [cd for n in self.subs.get(clsname, {clsname})
                for cd in self.by_name.get(n, [])]

    def field_ann(self, clsname: str, attr: str) -> list[str]:
        out = []
        for cd in self._defs(clsname):
            for st in cd.body:
                if isinstance(st, ast.AnnAssign) and \
                        isinstance(st.target, ast.Name) and \
                        st.target.id == attr:
                    out.append(ast.unparse(st.annotation))
        return out

    def method_return(self, clsname: str, attr: str) -> list[str]:
        out = []
        for cd in self._defs(clsname):
            for st in cd.body:
                if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                        and st.name == attr and st.returns is not None:
                    out.append(ast.unparse(st.returns))
        return out

    def stat_fields(self, clsname: str) -> list[tuple[str, int]]:
        """(field, lineno) for every annotated field of ``clsname`` itself."""
        out = []
        for cd in self.by_name.get(clsname, []):
            for st in cd.body:
                if isinstance(st, ast.AnnAssign) and \
                        isinstance(st.target, ast.Name):
                    out.append((st.target.id, st.lineno))
        return out


def classify_annotation(ann: str, reg: ClassRegistry) -> TV:
    a = ann.strip().strip("'\"")
    for wrap in ("Optional[", "ClassVar[", "Final["):
        if a.startswith(wrap) and a.endswith("]"):
            a = a[len(wrap):-1].strip()
    if "|" in a:
        parts = [p.strip() for p in a.split("|") if p.strip() != "None"]
        if len(parts) != 1:
            tvs = [classify_annotation(p, reg) for p in parts]
            return TV(max(tv.taint for tv in tvs))
        a = parts[0]
    if any(m in a for m in _TRACED_MARKS) or a == "Array":
        return TRACED
    head = a.split("[")[0].strip()
    if head in _SCALAR_ANNS or head in _STATIC_HEADS:
        return STATIC
    if reg.has(head):
        return TV(Taint.STATIC, cls=head)
    return UNKNOWN


def _merge(a: TV, b: TV) -> TV:
    if a.taint == b.taint and a.cls == b.cls:
        return a
    return TV(max(a.taint, b.taint),
              a.cls if a.cls == b.cls else None)


class FunctionTaint:
    """One pass over a function body; records a TV per visited expression
    node (keyed by identity), queryable by checkers afterwards."""

    def __init__(self, fi: FuncInfo, index: ProjectIndex, reg: ClassRegistry,
                 hot_traced_calls: set[str] = frozenset()):
        self.fi = fi
        self.mod = fi.module
        self.index = index
        self.reg = reg
        self.hot_calls = set(hot_traced_calls)
        self.cache: dict[int, TV] = {}
        env = self._param_env(fi.node, fi)
        body = fi.node.body
        if isinstance(fi.node, ast.Lambda):
            self._eval(fi.node.body, env)
        else:
            self._exec(body, env)

    def taint(self, node: ast.AST) -> Taint:
        return self.cache.get(id(node), UNKNOWN).taint

    # -- seeding ---------------------------------------------------------- #

    def _param_env(self, node, fi: FuncInfo) -> dict[str, TV]:
        env: dict[str, TV] = {}
        a = node.args
        params = list(a.posonlyargs) + list(a.args) + list(a.kwonlyargs)
        # *args / **kwargs bind tuples/dicts: the container itself is
        # structural (len() etc. are static) even when elements are traced
        for p in (a.vararg, a.kwarg):
            if p is not None:
                env[p.arg] = STATIC
        for i, p in enumerate(params):
            if p.annotation is not None:
                env[p.arg] = classify_annotation(
                    ast.unparse(p.annotation), self.reg)
            elif i == 0 and fi is not None and fi.is_method and \
                    p.arg in ("self", "cls"):
                cls = fi.qualname.split(".")[-2]
                env[p.arg] = TV(Taint.STATIC, cls=cls)
            else:
                env[p.arg] = UNKNOWN
        return env

    # -- statements ------------------------------------------------------- #

    def _exec(self, stmts: list[ast.stmt], env: dict[str, TV]) -> None:
        for st in stmts:
            self._stmt(st, env)

    def _assign_target(self, tgt: ast.expr, tv: TV, env: dict,
                       value: ast.expr | None = None) -> None:
        if isinstance(tgt, ast.Name):
            env[tgt.id] = tv
        elif isinstance(tgt, (ast.Tuple, ast.List)):
            vals = (value.elts if isinstance(value, (ast.Tuple, ast.List))
                    and len(value.elts) == len(tgt.elts) else None)
            for i, el in enumerate(tgt.elts):
                etv = self.cache.get(id(vals[i]), tv) if vals else tv
                self._assign_target(el, etv, env)
        elif isinstance(tgt, ast.Starred):
            self._assign_target(tgt.value, tv, env)
        # Attribute / Subscript stores mutate objects we don't model

    def _stmt(self, st: ast.stmt, env: dict[str, TV]) -> None:
        if isinstance(st, ast.Assign):
            tv = self._eval(st.value, env)
            for tgt in st.targets:
                self._assign_target(tgt, tv, env, st.value)
        elif isinstance(st, ast.AnnAssign):
            tv = classify_annotation(ast.unparse(st.annotation), self.reg)
            if st.value is not None:
                vtv = self._eval(st.value, env)
                if tv.taint == Taint.UNKNOWN:
                    tv = vtv
            if isinstance(st.target, ast.Name):
                env[st.target.id] = tv
        elif isinstance(st, ast.AugAssign):
            tv = self._eval(st.value, env)
            if isinstance(st.target, ast.Name):
                env[st.target.id] = _merge(env.get(st.target.id, UNKNOWN), tv)
        elif isinstance(st, (ast.If, ast.While)):
            self._eval(st.test, env)
            b1, b2 = dict(env), dict(env)
            self._exec(st.body, b1)
            self._exec(st.orelse, b2)
            for k in set(b1) | set(b2):
                env[k] = _merge(b1.get(k, env.get(k, UNKNOWN)),
                                b2.get(k, env.get(k, UNKNOWN)))
        elif isinstance(st, ast.For):
            self._eval(st.iter, env)
            self._assign_target(st.target, self._elem(st.iter, env), env)
            body = dict(env)
            self._exec(st.body, body)
            self._exec(st.orelse, body)
            for k in set(body):
                env[k] = _merge(body[k], env.get(k, body[k]))
        elif isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef)):
            env[st.name] = STATIC
            inner = dict(env)
            inner.update(self._param_env(st, None))
            self._exec(st.body, inner)
        elif isinstance(st, ast.With):
            for item in st.items:
                self._eval(item.context_expr, env)
            self._exec(st.body, env)
        elif isinstance(st, ast.Try):
            self._exec(st.body, env)
            for h in st.handlers:
                self._exec(h.body, env)
            self._exec(st.orelse, env)
            self._exec(st.finalbody, env)
        elif isinstance(st, (ast.Expr, ast.Return)) and st.value is not None:
            self._eval(st.value, env)
        elif isinstance(st, ast.Assert):
            self._eval(st.test, env)
        elif isinstance(st, ast.Raise) and st.exc is not None:
            self._eval(st.exc, env)
        elif isinstance(st, ast.Delete):
            for t in st.targets:
                if isinstance(t, ast.Name):
                    env.pop(t.id, None)

    # -- expressions ------------------------------------------------------ #

    def _elem(self, it: ast.expr, env: dict) -> TV:
        """Element taint of an iterable expression (for-loop targets)."""
        if isinstance(it, ast.Call):
            name = dotted_name(it.func, self.mod)
            if name == "range":
                return STATIC
            if name in ("enumerate", "zip", "reversed", "sorted", "map",
                        "filter"):
                tvs = [self._elem(a, env) for a in it.args
                       if not isinstance(a, ast.Lambda)]
                return TV(max((t.taint for t in tvs), default=Taint.UNKNOWN))
        if isinstance(it, (ast.Tuple, ast.List)):
            tvs = [self.cache.get(id(e), UNKNOWN) for e in it.elts]
            return TV(max((t.taint for t in tvs), default=Taint.STATIC))
        return self.cache.get(id(it), UNKNOWN)

    def _eval(self, e: ast.expr, env: dict[str, TV]) -> TV:
        tv = self._eval_inner(e, env)
        self.cache[id(e)] = tv
        return tv

    def _eval_inner(self, e: ast.expr, env: dict[str, TV]) -> TV:
        if isinstance(e, ast.Constant):
            return STATIC
        if isinstance(e, ast.Name):
            if e.id in env:
                return env[e.id]
            if self.reg.has(e.id) or \
                    self.index.resolve_name(self.mod, e.id) is not None:
                return STATIC                      # class / function object
            target = self.mod.imports.get(e.id)
            return STATIC if target else UNKNOWN
        if isinstance(e, ast.Attribute):
            base = self._eval(e.value, env)
            if e.attr in _STATIC_ATTRS:
                return STATIC
            if base.cls is not None:
                anns = self.reg.field_ann(base.cls, e.attr)
                if anns:
                    tvs = [classify_annotation(a, self.reg) for a in anns]
                    out = tvs[0]
                    for t in tvs[1:]:
                        out = _merge(out, t)
                    return out
                return UNKNOWN
            if e.attr == "at":
                return TRACED if base.taint == Taint.TRACED else base
            return TV(base.taint)
        if isinstance(e, ast.Subscript):
            base = self._eval(e.value, env)
            self._eval(e.slice, env)
            return TV(base.taint)
        if isinstance(e, ast.Call):
            return self._call(e, env)
        if isinstance(e, ast.BoolOp):
            tvs = [self._eval(v, env) for v in e.values]
            return TV(max(t.taint for t in tvs))
        if isinstance(e, ast.BinOp):
            lt = self._eval(e.left, env)
            rt = self._eval(e.right, env)
            return TV(max(lt.taint, rt.taint))
        if isinstance(e, ast.UnaryOp):
            return TV(self._eval(e.operand, env).taint)
        if isinstance(e, ast.Compare):
            tvs = [self._eval(e.left, env)]
            tvs += [self._eval(c, env) for c in e.comparators]
            if all(isinstance(op, (ast.Is, ast.IsNot)) for op in e.ops):
                return STATIC          # `x is None` dispatch, not a read
            return TV(max(t.taint for t in tvs))
        if isinstance(e, ast.IfExp):
            self._eval(e.test, env)
            return _merge(self._eval(e.body, env), self._eval(e.orelse, env))
        if isinstance(e, (ast.Tuple, ast.List, ast.Set)):
            tvs = [self._eval(el, env) for el in e.elts]
            return TV(max((t.taint for t in tvs), default=Taint.STATIC))
        if isinstance(e, ast.Dict):
            tvs = [self._eval(v, env) for v in e.values if v is not None]
            for k in e.keys:
                if k is not None:
                    self._eval(k, env)
            return TV(max((t.taint for t in tvs), default=Taint.STATIC))
        if isinstance(e, (ast.ListComp, ast.SetComp, ast.GeneratorExp,
                          ast.DictComp)):
            inner = dict(env)
            for gen in e.generators:
                self._eval(gen.iter, inner)
                self._assign_target(gen.target, self._elem(gen.iter, inner),
                                    inner)
                for cond in gen.ifs:
                    self._eval(cond, inner)
            if isinstance(e, ast.DictComp):
                self._eval(e.key, inner)
                return TV(self._eval(e.value, inner).taint)
            return TV(self._eval(e.elt, inner).taint)
        if isinstance(e, ast.Lambda):
            inner = dict(env)
            inner.update(self._param_env(e, None))
            self._eval(e.body, inner)
            return STATIC
        if isinstance(e, ast.NamedExpr):
            tv = self._eval(e.value, env)
            self._assign_target(e.target, tv, env)
            return tv
        if isinstance(e, ast.Starred):
            return self._eval(e.value, env)
        if isinstance(e, ast.JoinedStr):
            for v in e.values:
                if isinstance(v, ast.FormattedValue):
                    self._eval(v.value, env)
            return STATIC
        if isinstance(e, ast.Slice):
            for part in (e.lower, e.upper, e.step):
                if part is not None:
                    self._eval(part, env)
            return STATIC
        return UNKNOWN

    def _call(self, e: ast.Call, env: dict[str, TV]) -> TV:
        arg_tvs = [self._eval(a, env) for a in e.args]
        arg_tvs += [self._eval(kw.value, env) for kw in e.keywords]
        arg_max = Taint(max((t.taint for t in arg_tvs), default=Taint.STATIC))
        fn = e.func
        name = dotted_name(fn, self.mod)

        if name in _HOST_CASTS:
            return STATIC
        if name is not None:
            if name in _JAX_STATIC_CALLS:
                return STATIC
            if name.startswith(("jax.numpy.", "jax.lax.", "jax.nn.",
                                "jax.random.", "jax.scipy.")) or \
                    name in ("jax.vmap", "jax.pmap", "jax.jit",
                             "jax.checkpoint", "jax.grad"):
                return TRACED
            if name.startswith("numpy."):
                return STATIC
            if name in ("min", "max", "sum", "abs", "sorted", "tuple",
                        "list", "dict", "set", "zip", "enumerate", "map"):
                return TV(arg_max)
            if name == "range":
                return STATIC
        # calling the result of another call (jax.vmap(f)(xs), jitted fns)
        if isinstance(fn, ast.Call):
            inner = self._eval(fn, env)
            return TRACED if inner.taint == Taint.TRACED else UNKNOWN

        bare = fn.id if isinstance(fn, ast.Name) else (
            fn.attr if isinstance(fn, ast.Attribute) else None)
        if bare in self.hot_calls:
            return TRACED
        if isinstance(fn, ast.Attribute):
            base = self._eval(fn.value, env)
            if bare in ("item", "tolist"):
                return STATIC
            if base.cls is not None:
                rets = self.reg.method_return(base.cls, bare)
                if rets:
                    tvs = [classify_annotation(a, self.reg) for a in rets]
                    out = tvs[0]
                    for t in tvs[1:]:
                        out = _merge(out, t)
                    return out
                return UNKNOWN
            return TV(base.taint)
        if isinstance(fn, ast.Name):
            if self.reg.has(fn.id):
                return TV(Taint.STATIC, cls=fn.id)      # constructor
            target = self.index.resolve_name(self.mod, fn.id)
            if target is not None and \
                    not isinstance(target.node, ast.Lambda) and \
                    target.node.returns is not None:
                return classify_annotation(
                    ast.unparse(target.node.returns), self.reg)
        return UNKNOWN
