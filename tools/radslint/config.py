"""radslint configuration: the ``[tool.radslint]`` block of pyproject.toml.

The container python is 3.10 (no :mod:`tomllib`), so a minimal TOML-subset
reader lives here: it understands exactly the shapes the config block uses
— ``[section]`` headers, ``key = "string"``, ``key = int``, ``key = bool``
and (possibly multi-line) ``key = [ "...", ... ]`` string/int lists.  That
is deliberately all of it; anything fancier belongs in a real TOML parser.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from pathlib import Path

_SECTION = "tool.radslint"


@dataclass
class Config:
    """Resolved analyzer configuration (paths relative to ``project_root``)."""

    project_root: Path
    # directories scanned and indexed (package code under analysis)
    roots: list[str] = field(default_factory=lambda: ["src/repro"])
    # sys.path-style bases used to turn file paths into module qualnames
    import_roots: list[str] = field(default_factory=lambda: ["src"])
    # qualified functions that root the jit call graph (additional roots are
    # discovered from @jax.jit decorators and jax.jit(...) call sites)
    entrypoints: list[str] = field(default_factory=list)
    # host-side functions whose device round-trips RL001 also polices
    hot_loops: list[str] = field(default_factory=list)
    # call names whose results are device values inside a hot loop
    hot_traced_calls: list[str] = field(default_factory=list)
    # RL002: capacity ladder base and the name pattern of capacity knobs
    ladder_base: int = 2
    cap_name_pattern: str = r"(^|_)cap$"
    # RL004: the stat-carrying state class, its drain point, and the files
    # that must consume every matching field
    stat_state: str = ""
    stat_finalizer: str = ""
    stat_field_patterns: list[str] = field(
        default_factory=lambda: [r"^bytes_", r"_hits$", r"_probes$"])
    stat_consumers: list[str] = field(default_factory=list)
    # RL004 metric extension: the module whose literal counter()/gauge()/
    # info()/histogram() calls declare the metrics schema, and the
    # exporter / benchmark files that must surface every declared
    # instrument (registry -> exporter -> benchmark column)
    metric_schema: str = ""
    metric_consumers: list[str] = field(default_factory=list)
    # zero-findings ratchet file
    baseline: str = "tools/radslint/baseline.json"

    def cap_re(self) -> re.Pattern:
        return re.compile(self.cap_name_pattern)

    def stat_res(self) -> list[re.Pattern]:
        return [re.compile(p) for p in self.stat_field_patterns]


class ConfigError(ValueError):
    pass


def _parse_scalar(text: str):
    text = text.strip()
    if (text.startswith('"') and text.endswith('"')) or (
            text.startswith("'") and text.endswith("'")):
        return text[1:-1]
    if text in ("true", "false"):
        return text == "true"
    try:
        return int(text, 0)
    except ValueError:
        raise ConfigError(f"unsupported TOML value: {text!r}") from None


def _parse_list(text: str) -> list:
    body = text.strip()
    assert body.startswith("[") and body.endswith("]")
    items, depth, cur = [], 0, ""
    for ch in body[1:-1]:
        if ch == "," and depth == 0:
            if cur.strip():
                items.append(_parse_scalar(cur))
            cur = ""
        else:
            if ch in "[{":
                depth += 1
            elif ch in "]}":
                depth -= 1
            cur += ch
    if cur.strip():
        items.append(_parse_scalar(cur))
    return items


def read_toml_section(path: Path, section: str = _SECTION) -> dict:
    """Read one ``[section]`` of a TOML file with the subset grammar above."""
    out: dict = {}
    in_section = False
    pending_key, pending_val = None, ""
    for raw in path.read_text().splitlines():
        line = raw.split("#", 1)[0].rstrip() if '"' not in raw else raw.rstrip()
        if pending_key is not None:
            pending_val += " " + line.strip()
            if pending_val.count("[") == pending_val.count("]"):
                out[pending_key] = _parse_list(pending_val)
                pending_key, pending_val = None, ""
            continue
        stripped = line.strip()
        if stripped.startswith("["):
            in_section = stripped == f"[{section}]"
            continue
        if not in_section or not stripped or stripped.startswith("#"):
            continue
        if "=" not in stripped:
            raise ConfigError(f"cannot parse TOML line: {raw!r}")
        key, val = (s.strip() for s in stripped.split("=", 1))
        if val.startswith("["):
            if val.count("[") == val.count("]"):
                out[key] = _parse_list(val)
            else:
                pending_key, pending_val = key, val
        else:
            out[key] = _parse_scalar(val)
    return out


def load_config(project_root: Path, pyproject: Path | None = None) -> Config:
    """Build a :class:`Config` from ``<project_root>/pyproject.toml``."""
    project_root = Path(project_root).resolve()
    path = pyproject or project_root / "pyproject.toml"
    raw = read_toml_section(path) if path.exists() else {}
    cfg = Config(project_root=project_root)
    for key, val in raw.items():
        if not hasattr(cfg, key):
            raise ConfigError(f"unknown [tool.radslint] key: {key!r}")
        setattr(cfg, key, val)
    return cfg
