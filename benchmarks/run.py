"""Benchmark harness — one module per paper table/figure. Prints
``name,us_per_call,derived`` CSV lines (common.emit contract).

  enumeration   — Figures 8-11 (RADS vs PSgL/TwinTwig/SEED/Crystal)
  compression   — Tables 3-4 (EL vs ET)
  plan_effect   — Figure 13 (RanS / RanM / full plan)
  scalability   — Figure 12
  kernels       — kernel micro-benchmarks
  roofline      — §Roofline terms from the dry-run artifacts
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma list: enumeration,compression,plan,scale,"
                         "kernels,roofline")
    ap.add_argument("--smoke", action="store_true",
                    help="fast subset (enumeration + scale honor this): "
                         "one dataset/query per group")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    def want(name):
        return only is None or name in only

    print("name,us_per_call,derived")
    failures = []
    if want("kernels"):
        from benchmarks import kernels_bench
        _safe(kernels_bench.run, failures, "kernels")
    if want("enumeration"):
        from benchmarks import enumeration
        _safe(lambda: enumeration.run(smoke=args.smoke), failures,
              "enumeration")
    if want("compression"):
        from benchmarks import compression
        _safe(compression.run, failures, "compression")
    if want("plan"):
        from benchmarks import plan_effect
        _safe(plan_effect.run, failures, "plan")
    if want("scale"):
        from benchmarks import scalability
        _safe(lambda: scalability.run(smoke=args.smoke), failures, "scale")
    if want("roofline"):
        from benchmarks import roofline
        _safe(roofline.run, failures, "roofline")
        _safe(lambda: roofline.run("multi"), failures, "roofline-multi")
    if failures:
        print(f"# {len(failures)} benchmark groups failed: {failures}",
              file=sys.stderr)
        sys.exit(1)


def _safe(fn, failures, name):
    try:
        fn()
    except Exception:
        traceback.print_exc()
        failures.append(name)


if __name__ == "__main__":
    main()
