"""Figures 8-11: per-(dataset x query) comparison — RADS vs PSgL vs
TwinTwig vs SEED vs Crystal-lite. Metrics: wall time, communication volume
(RADS: fetchV+verifyE bytes; baselines: shuffled intermediate bytes — the
paper's headline axis), and peak intermediate rows (memory robustness).

RADS cells are timed twice: the first (cold) call runs through a shared
``runner_cache`` *and* a single persistent stage-executable store
(``runtime/compile_cache.py``) shared by the whole sweep — cells whose
stage cache keys genuinely match (expand/init/finalize are wire-agnostic,
for example) reuse each other's executables, and the per-cell
``exec_cold``/``exec_warm`` hit/miss columns show exactly which did.  The
second (warm) call uses a FRESH ``runner_cache`` so a brand-new
:class:`StageRunner` must resolve every stage purely from the on-disk
store: ``compiles_warm == 0`` and ``compile_us_warm <= 5%`` of
``compile_us_cold`` are hard gates (asserted after the JSON artifact is
written, so failures still ship data).  Each RADS cell also runs under
both on-device storage formats (``dense`` vs ``bucketed``) with the
resident adjacency footprint in the ``peak_adj_bytes`` column; a count
divergence between formats aborts the benchmark (and thereby
``make bench-smoke`` / CI).

Besides the ``common.emit`` CSV lines, the run writes a machine-readable
``BENCH_enumeration.json`` with two sections:

* ``results``      — patterns × systems/backends × storage formats ×
  adjacency-cache on/off × wire format (``raw`` | ``varint`` | ``auto``,
  the last resolved from wire trials recorded by the raw/varint cells):
  ``compile_us``/``wall_us`` plus the executable-store columns
  ``compile_us_cold``/``compile_us_warm``/``compiles_warm``/
  ``compile_cache_hits``, match count, comm bytes (plus
  ``bytes_saved_cache`` / ``cache_hit_rate`` / ``bytes_fetch_compressed``
  and the actual coded ``bytes_wire_fetch``/``bytes_wire_verify``),
  ``peak_adj_bytes`` (the perf-trajectory payload); a count divergence
  between cache configurations or wire formats aborts the benchmark
  exactly like a storage-format divergence;
* ``sync_vs_async`` — the staged scheduler timed on the *same warm jitted
  stages* with ``depth=1`` (the old synchronous wave loop) vs
  ``depth=2`` (double-buffered pipeline, lazy Algorithm-3 grouping and
  embedding extraction overlapping device compute): wall times, overlap
  speedup, in-flight depth, and wave counts.

``run(smoke=True)`` (the ``make bench-smoke`` / CI entry) trims to a
~30-second subset so the trajectory files always carry fresh numbers.
"""
from __future__ import annotations

import json
import os
import tempfile
import time

import numpy as np

import dataclasses

from benchmarks.common import emit
from repro.configs.rads import DEFAULT_ENGINE, EngineConfig, QUERIES
from repro.core import (GroupQueue, Pattern, PipelineScheduler, StageRunner,
                        best_plan, extract_embeddings, iter_region_groups,
                        rads_enumerate)
from repro.core.baselines import (build_triangle_index, crystal_lite,
                                  join_enumerate, psgl_enumerate)
from repro.core.engine import build_plan_data
from repro.core.exchange import Exchange
from repro.graph import device_graph, load_dataset, partition

STORAGE_FORMATS = ("dense", "bucketed")

CFG = EngineConfig(frontier_cap=1 << 13, fetch_cap=1 << 10, verify_cap=1 << 12,
                   region_group_budget=1 << 12)

# sync-vs-async cell: small waves + lazy grouping so the pipeline has both
# many waves to overlap and real host-side work (Algorithm 3, np.unique
# extraction) to hide behind device compute
ASYNC_CFG = EngineConfig(frontier_cap=1 << 11, fetch_cap=256, verify_cap=512)
ASYNC_BUDGET = 192.0
ASYNC_COST = 12.0
ASYNC_SCAP = 16
ASYNC_REPS = 4

JSON_PATH = "BENCH_enumeration.json"


def _bench_sync_vs_async(pg, pat, backend: str, ndev: int) -> dict:
    """Time depth=1 vs depth=2 on shared warm jitted stages (min over
    paired reps; each rep re-runs lazy grouping + per-wave extraction)."""
    pd = build_plan_data(best_plan(pat))
    runner = StageRunner(device_graph(pg, "dense"), pd, ASYNC_CFG,
                         Exchange(backend))

    def make_queues():
        qs = []
        for t in range(ndev):
            nl = int(pg.n_local[t])
            cand = np.flatnonzero(pg.deg[t, :nl] >= pd.start_deg)
            gids = (cand + t * pg.stride).astype(np.int64)
            qs.append(GroupQueue(
                lazy=iter_region_groups(pg, gids,
                                        np.full(len(gids), ASYNC_COST),
                                        ASYNC_BUDGET),
                n_lazy_seeds=len(gids)))
        return qs

    stats = dict(overflow_retries=0, cap_escalations=0, n_waves=0,
                 max_inflight_waves=0, steal_events=0, wave_s_total=0.0,
                 bytes_fetch=0.0, bytes_verify=0.0)
    embs: set = set()

    def consume(rows, alive, counts, st, phase):
        stats["bytes_fetch"] += float(st["bytes_fetch"])
        stats["bytes_verify"] += float(st["bytes_verify"])
        embs.update(extract_embeddings(np.asarray(rows), np.asarray(alive),
                                       pd, pg))

    sched = PipelineScheduler(runner, stats, consume)
    sched.run(make_queues(), ASYNC_SCAP, local_only=False, phase="warm")
    n_waves, count = stats["n_waves"], len(embs)
    bytes_total = stats["bytes_fetch"] + stats["bytes_verify"]

    def one(depth: int) -> float:
        embs.clear()
        queues = make_queues()
        t0 = time.perf_counter()
        sched.run(queues, ASYNC_SCAP, local_only=False, phase="bench",
                  depth=depth)
        return time.perf_counter() - t0

    # paired + interleaved reps: host-load drift hits both modes equally
    sync_s = async_s = float("inf")
    for _ in range(ASYNC_REPS):
        sync_s = min(sync_s, one(1))
        async_s = min(async_s, one(2))
    return dict(backend=backend, sync_us=sync_s * 1e6, async_us=async_s * 1e6,
                speedup=sync_s / async_s,
                async_leq_sync=bool(async_s <= sync_s),
                n_waves=int(n_waves),
                max_inflight_waves=int(stats["max_inflight_waves"]),
                count=int(count), comm_bytes=float(bytes_total))


def run(datasets=("dblp_bench", "roadnet_bench", "livejournal_bench",
                  "uk2002_bench"),
        queries=("q1", "q2"), ndev: int = 4, smoke: bool = False,
        json_path: str = JSON_PATH):
    if smoke:   # the ~30s CI subset: one dataset, triangle query
        datasets, queries = ("dblp_bench",), ("q1",)
    out = {"results": [], "sync_vs_async": []}
    # one persistent executable store + one cold-path runner cache for the
    # whole sweep: cells whose stage cache keys genuinely match share
    # executables (per-cell exec_cold/exec_warm columns report hit/miss)
    exec_dir = tempfile.mkdtemp(prefix="rads-stagex-")
    shared_cache: dict = {}
    for ds in datasets:
        g = load_dataset(ds)
        pg = partition(g, ndev, method="bfs")
        tri = build_triangle_index(g)
        # denser stand-ins run the triangle only (CPU bench budget; the
        # multi-round queries are covered on dblp/roadnet + in tests)
        qs = queries if ds in ("dblp_bench", "roadnet_bench") else ("q1",)
        for q in qs:
            pat = Pattern.from_edges(QUERIES[q])
            counts: set[int] = set()
            # sim backend × both storage formats × adjacency cache on/off
            # (cache-off only on dense — the cache is format-agnostic) ×
            # wire format (varint cells prove the coded exchange: identical
            # counts, smaller actual wire bytes); a shared runner_cache
            # makes the second call reuse the jitted stages, so the warm
            # run times steady-state execution and compile_us is the
            # cold-warm delta
            # the trailing 'auto' cell resolves its codec from the wire
            # trials its two un-timed recorder runs persist just below
            cells = ([(f, True, "raw") for f in STORAGE_FORMATS]
                     + [("dense", False, "raw"), ("dense", True, "varint"),
                        ("dense", False, "varint"), ("dense", True, "auto")])
            pri_path = os.path.join(exec_dir, f"priors_{ds}_{q}.json")
            for fmt, use_cache, wire in cells:
                cfg_fmt = dataclasses.replace(CFG, storage_format=fmt,
                                              enable_cache=use_cache,
                                              wire_format=wire,
                                              compile_cache_dir=exec_dir)
                if wire == "auto":
                    cfg_fmt = dataclasses.replace(cfg_fmt,
                                                  priors_path=pri_path)
                    # record one measured trial per concrete codec (un-timed;
                    # the second run also stabilizes the persisted per-seed
                    # cost, so the timed cold/warm pair below replays
                    # identical wave shapes and hits the executable store)
                    for wfmt in ("raw", "varint"):
                        rads_enumerate(pg, pat,
                                       dataclasses.replace(cfg_fmt,
                                                           wire_format=wfmt),
                                       mode="sim", return_embeddings=False,
                                       runner_cache=shared_cache)
                t0 = time.perf_counter()
                rc = rads_enumerate(pg, pat, cfg_fmt, mode="sim",
                                    return_embeddings=False,
                                    runner_cache=shared_cache)
                cold_us = (time.perf_counter() - t0) * 1e6
                t0 = time.perf_counter()
                r = rads_enumerate(pg, pat, cfg_fmt, mode="sim",
                                   return_embeddings=False,
                                   runner_cache=shared_cache)
                wall_us = (time.perf_counter() - t0) * 1e6
                compile_us = max(cold_us - wall_us, 0.0)
                # store-resolve call: a FRESH runner cache forces a
                # brand-new runner that must resolve every stage from the
                # persistent on-disk store — compiles_warm == 0 and
                # compile_us_warm <= 5% of cold are the zero-re-jit proof
                # the smoke gate checks
                rs = rads_enumerate(pg, pat, cfg_fmt, mode="sim",
                                    return_embeddings=False,
                                    runner_cache={})
                # byte/cache traffic columns come from the COLD run (the
                # within-run truth); the WARM run reuses the runner's
                # already-populated AdjCache, so its hit rate is the
                # functional end-to-end signal the smoke gate checks — a
                # broken probe/insert path shows up as hit_rate_warm == 0
                st = rc.stats
                rads_bytes = st["bytes_fetch"] + st["bytes_verify"]
                wire_bytes = st["bytes_wire_fetch"] + st["bytes_wire_verify"]
                tag = ("" if use_cache else "-nocache") + (
                    "" if wire == "raw" else f"-{wire}")
                emit(f"enum/{ds}/{q}/rads-{fmt}{tag}", wall_us,
                     f"count={r.count};comm_bytes={rads_bytes:.0f};"
                     f"wire_bytes={wire_bytes:.0f};"
                     f"compile_us={compile_us:.0f};"
                     f"compile_us_cold={rc.stats['compile_s'] * 1e6:.0f};"
                     f"compile_us_warm={rs.stats['compile_s'] * 1e6:.0f};"
                     f"compile_cache_hits="
                     f"{rs.stats['compile_cache_hits']:.0f};"
                     f"peak_adj_bytes={st['peak_adj_bytes']};"
                     f"cache_hit_rate={st['cache_hit_rate']:.3f};"
                     f"cache_hit_rate_warm={r.stats['cache_hit_rate']:.3f};"
                     f"bytes_saved_cache={st['bytes_saved_cache']:.0f};"
                     f"sme={st['n_sme_seeds']}")
                out["results"].append(dict(
                    dataset=ds, query=q, system="rads-sim", storage=fmt,
                    cache="on" if use_cache else "off", wire=wire,
                    wire_resolved=st["wire_format"],
                    wire_auto_reason=st["wire_auto_reason"],
                    compile_us_cold=float(rc.stats["compile_s"]) * 1e6,
                    compile_us_warm=float(rs.stats["compile_s"]) * 1e6,
                    compiles_cold=int(rc.stats["compiles"]),
                    compiles_warm=int(rs.stats["compiles"]),
                    compile_cache_hits=float(rs.stats["compile_cache_hits"]),
                    exec_cold=rc.stats.get("exec_cache"),
                    exec_warm=rs.stats.get("exec_cache"),
                    cache_enabled=bool(st["cache_enabled"]),
                    cache_hits=float(st["cache_hits"]),
                    cache_probes=float(st["cache_probes"]),
                    wall_us=wall_us, compile_us=compile_us,
                    count=int(r.count), comm_bytes=float(rads_bytes),
                    bytes_fetch=float(st["bytes_fetch"]),
                    bytes_verify=float(st["bytes_verify"]),
                    bytes_wire_fetch=float(st["bytes_wire_fetch"]),
                    bytes_wire_verify=float(st["bytes_wire_verify"]),
                    bytes_wire_fetch_dev=list(st["bytes_wire_fetch_dev"]),
                    bytes_wire_verify_dev=list(st["bytes_wire_verify_dev"]),
                    comm_skew=float(st["comm_skew"]),
                    bytes_fetch_compressed=float(
                        st["bytes_fetch_compressed"]),
                    bytes_saved_cache=float(st["bytes_saved_cache"]),
                    cache_hit_rate=float(st["cache_hit_rate"]),
                    cache_hit_rate_warm=float(r.stats["cache_hit_rate"]),
                    bytes_saved_cache_warm=float(
                        r.stats["bytes_saved_cache"]),
                    peak_adj_bytes=int(st["peak_adj_bytes"]),
                    n_waves=int(st["n_waves"]),
                    max_inflight_waves=int(st["max_inflight_waves"])))
                counts.add(r.count)
                counts.add(rc.count)
                counts.add(rs.count)
            if smoke:   # keep the patterns x backends axis in the subset
                cfg_g = dataclasses.replace(CFG, storage_format="bucketed",
                                            compile_cache_dir=exec_dir)
                t0 = time.perf_counter()
                rgc = rads_enumerate(pg, pat, cfg_g, mode="gather",
                                     return_embeddings=False,
                                     runner_cache=shared_cache)
                cold_us = (time.perf_counter() - t0) * 1e6
                t0 = time.perf_counter()
                rg = rads_enumerate(pg, pat, cfg_g, mode="gather",
                                    return_embeddings=False,
                                    runner_cache={})
                t_g = (time.perf_counter() - t0) * 1e6
                # cold-run stats for the same warm-cache reason as above
                g_bytes = (rgc.stats["bytes_fetch"]
                           + rgc.stats["bytes_verify"])
                emit(f"enum/{ds}/{q}/rads-gather-bucketed", t_g,
                     f"count={rg.count};comm_bytes={g_bytes:.0f};"
                     f"compile_us_cold={rgc.stats['compile_s'] * 1e6:.0f};"
                     f"compile_us_warm={rg.stats['compile_s'] * 1e6:.0f}")
                out["results"].append(dict(
                    dataset=ds, query=q, system="rads-gather",
                    storage="bucketed", cache="on", wire="raw", wall_us=t_g,
                    compile_us=max(cold_us - t_g, 0.0),
                    compile_us_cold=float(rgc.stats["compile_s"]) * 1e6,
                    compile_us_warm=float(rg.stats["compile_s"]) * 1e6,
                    compiles_cold=int(rgc.stats["compiles"]),
                    compiles_warm=int(rg.stats["compiles"]),
                    compile_cache_hits=float(rg.stats["compile_cache_hits"]),
                    exec_cold=rgc.stats.get("exec_cache"),
                    exec_warm=rg.stats.get("exec_cache"),
                    peak_adj_bytes=int(rgc.stats["peak_adj_bytes"]),
                    cache_hit_rate=float(rgc.stats["cache_hit_rate"]),
                    bytes_saved_cache=float(rgc.stats["bytes_saved_cache"]),
                    count=int(rg.count), comm_bytes=float(g_bytes)))
                counts.add(rg.count)
                counts.add(rgc.count)
            if not smoke:
                p = psgl_enumerate(pg, pat, return_embeddings=False)
                emit(f"enum/{ds}/{q}/psgl", p.seconds * 1e6,
                     f"count={p.count};comm_bytes={p.bytes_shuffled:.0f};"
                     f"peak_rows={p.peak_rows}")
                out["results"].append(dict(
                    dataset=ds, query=q, system="psgl",
                    wall_us=p.seconds * 1e6, count=int(p.count),
                    comm_bytes=float(p.bytes_shuffled)))
                for kind in ("twintwig", "seed"):
                    j = join_enumerate(pg, pat, kind, return_embeddings=False)
                    emit(f"enum/{ds}/{q}/{kind}", j.seconds * 1e6,
                         f"count={j.count};comm_bytes={j.bytes_shuffled:.0f};"
                         f"peak_rows={j.peak_rows}")
                    out["results"].append(dict(
                        dataset=ds, query=q, system=kind,
                        wall_us=j.seconds * 1e6, count=int(j.count),
                        comm_bytes=float(j.bytes_shuffled)))
                c = crystal_lite(pg, pat, g, tri_index=tri,
                                 return_embeddings=False)
                emit(f"enum/{ds}/{q}/crystal", c.seconds * 1e6,
                     f"count={c.count};index_bytes={c.extra['index_bytes']}")
                out["results"].append(dict(
                    dataset=ds, query=q, system="crystal",
                    wall_us=c.seconds * 1e6, count=int(c.count)))
                counts |= {p.count, c.count}
            assert len(counts) == 1, f"count mismatch {ds}/{q}: {counts}"

    # ---- sync-vs-async overlap efficiency (staged scheduler) -------------- #
    sv_datasets = ("dblp_bench",)            # grouping-heavy => overlap shows
    sv_queries = ("q1",) if smoke else ("q1", "q2")
    sv_backends = ("sim",) if smoke else ("sim", "gather")
    for ds in sv_datasets:
        g = load_dataset(ds)
        pg = partition(g, ndev, method="bfs")
        for q in sv_queries:
            pat = Pattern.from_edges(QUERIES[q])
            for backend in sv_backends:
                cell = _bench_sync_vs_async(pg, pat, backend, ndev)
                cell.update(dataset=ds, query=q)
                out["sync_vs_async"].append(cell)
                emit(f"enum_async/{ds}/{q}/{backend}", cell["async_us"],
                     f"sync_us={cell['sync_us']:.0f};"
                     f"speedup={cell['speedup']:.3f};"
                     f"waves={cell['n_waves']};"
                     f"inflight={cell['max_inflight_waves']}")

    totals = dict(
        sync_us=sum(c["sync_us"] for c in out["sync_vs_async"]),
        async_us=sum(c["async_us"] for c in out["sync_vs_async"]))
    totals["async_leq_sync"] = totals["async_us"] <= totals["sync_us"]
    out["sync_vs_async_total"] = totals

    # ---- traced smoke run: the Perfetto timeline artifact CI ships -------- #
    # one full wave-level trace per smoke invocation (warm stages via the
    # shared runner cache, so the timeline shows steady-state execution);
    # the Makefile gate validates the Chrome schema and flow pairing
    if smoke:
        from repro.obs import TraceRecorder

        tracer = TraceRecorder()
        g = load_dataset("dblp_bench")
        pg = partition(g, ndev, method="bfs")
        pat = Pattern.from_edges(QUERIES["q1"])
        rt = rads_enumerate(pg, pat,
                            dataclasses.replace(CFG,
                                                compile_cache_dir=exec_dir),
                            mode="sim", return_embeddings=False,
                            runner_cache=shared_cache, tracer=tracer)
        trace_path = tracer.save("trace_smoke.json")
        out["trace_smoke"] = dict(
            path=trace_path, count=int(rt.count),
            events=int(tracer.n_recorded), dropped=int(tracer.n_dropped),
            wall_us=float(rt.stats["wall_us"]),
            sme_wall_us=float(rt.stats["sme_wall_us"]),
            dist_wall_us=float(rt.stats["dist_wall_us"]))
        emit("enum_trace_smoke", float(rt.stats["wall_us"]),
             f"path={trace_path};events={tracer.n_recorded};"
             f"dropped={tracer.n_dropped};count={rt.count}")
    with open(json_path, "w") as f:
        json.dump(out, f, indent=1)
    emit("enum_json", 0.0, f"path={json_path}")

    # ---- hard gates (after the artifact write, so failures still ship data) -- #
    # 1. the warm path must not re-jit: a fresh runner resolving from the
    #    persistent store pays <= 5% of the cold compile time (and zero
    #    stage traces)
    warm_viol = [r for r in out["results"]
                 if r.get("compile_us_cold", 0.0) > 0.0
                 and (r["compile_us_warm"] > 0.05 * r["compile_us_cold"]
                      or r["compiles_warm"] > 0)]
    assert not warm_viol, "warm-path recompilation: " + "; ".join(
        f"{r['dataset']}/{r['query']}/{r['system']}-{r.get('storage')}"
        f"-{r.get('wire')}: warm {r['compile_us_warm']:.0f}us "
        f"({r['compiles_warm']} traces) vs cold {r['compile_us_cold']:.0f}us"
        for r in warm_viol)
    # 2. the double-buffered pipeline must actually win (or at worst tie)
    assert totals["async_leq_sync"], (
        f"async pipeline slower than sync: async {totals['async_us']:.0f}us "
        f"> sync {totals['sync_us']:.0f}us")
