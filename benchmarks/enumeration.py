"""Figures 8-11: per-(dataset x query) comparison — RADS vs PSgL vs
TwinTwig vs SEED vs Crystal-lite. Metrics: wall time, communication volume
(RADS: fetchV+verifyE bytes; baselines: shuffled intermediate bytes — the
paper's headline axis), and peak intermediate rows (memory robustness)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.configs.rads import DEFAULT_ENGINE, EngineConfig, QUERIES
from repro.core import Pattern, rads_enumerate
from repro.core.baselines import (build_triangle_index, crystal_lite,
                                  join_enumerate, psgl_enumerate)
from repro.graph import load_dataset, partition

CFG = EngineConfig(frontier_cap=1 << 13, fetch_cap=1 << 10, verify_cap=1 << 12,
                   region_group_budget=1 << 12)


def run(datasets=("dblp_bench", "roadnet_bench", "livejournal_bench",
                  "uk2002_bench"),
        queries=("q1", "q2"), ndev: int = 4):
    for ds in datasets:
        g = load_dataset(ds)
        pg = partition(g, ndev, method="bfs")
        tri = build_triangle_index(g)
        # denser stand-ins run the triangle only (CPU bench budget; the
        # multi-round queries are covered on dblp/roadnet + in tests)
        qs = queries if ds in ("dblp_bench", "roadnet_bench") else ("q1",)
        for q in qs:
            pat = Pattern.from_edges(QUERIES[q])
            t0 = time.perf_counter()
            r = rads_enumerate(pg, pat, CFG, mode="sim",
                               return_embeddings=False)
            t_rads = (time.perf_counter() - t0) * 1e6
            rads_bytes = r.stats["bytes_fetch"] + r.stats["bytes_verify"]
            emit(f"enum/{ds}/{q}/rads", t_rads,
                 f"count={r.count};comm_bytes={rads_bytes:.0f};"
                 f"sme={r.stats['n_sme_seeds']}")
            p = psgl_enumerate(pg, pat, return_embeddings=False)
            emit(f"enum/{ds}/{q}/psgl", p.seconds * 1e6,
                 f"count={p.count};comm_bytes={p.bytes_shuffled:.0f};"
                 f"peak_rows={p.peak_rows}")
            for kind in ("twintwig", "seed"):
                j = join_enumerate(pg, pat, kind, return_embeddings=False)
                emit(f"enum/{ds}/{q}/{kind}", j.seconds * 1e6,
                     f"count={j.count};comm_bytes={j.bytes_shuffled:.0f};"
                     f"peak_rows={j.peak_rows}")
            c = crystal_lite(pg, pat, g, tri_index=tri,
                             return_embeddings=False)
            emit(f"enum/{ds}/{q}/crystal", c.seconds * 1e6,
                 f"count={c.count};index_bytes={c.extra['index_bytes']}")
            counts = {r.count, p.count, c.count}
            assert len(counts) == 1, f"count mismatch {ds}/{q}: {counts}"
