"""Shared benchmark helpers + the CSV emission contract:
every benchmark prints ``name,us_per_call,derived`` lines."""
from __future__ import annotations

import time

import numpy as np


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.1f},{derived}")


def time_call(fn, *args, warmup: int = 1, iters: int = 3, **kw) -> float:
    for _ in range(warmup):
        fn(*args, **kw)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args, **kw)
    if hasattr(out, "block_until_ready"):
        out.block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6
