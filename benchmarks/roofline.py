"""§Roofline: derive the three roofline terms per (arch x shape x mesh) from
the dry-run artifacts (memory/cost/collective analysis of the compiled
SPMD module).

  compute term    = HLO_flops_per_dev / peak_FLOPs
  memory term     = HLO_bytes_per_dev / HBM_bw
  collective term = collective_bytes_per_dev / link_bw

Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
cost_analysis() of the partitioned module is per-device; collective bytes
are parsed from the compiled HLO (output-buffer bytes of each collective).
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit
from repro.utils import load_json

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

ARTIFACT_DIR = os.environ.get(
    "DRYRUN_ARTIFACTS",
    os.path.join(os.path.dirname(__file__), "..", "experiments", "artifacts"))


def terms(rec: dict) -> dict | None:
    """Three roofline terms with the scan-trip correction.

    XLA's cost model counts while-loop (scan) bodies once; scanned models
    execute them ``scan_trip`` (= n_layers) times (calibrated:
    EXPERIMENTS.md §Roofline notes). Corrections applied:
      flops   -> max(HLO flops, analytic model flops / ndev) — the analytic
                 6ND/2ND count is a lower bound immune to the undercount;
      coll    -> entry-computation bytes + region (loop-body) bytes x trip;
      memory  -> HLO bytes_accessed with the same trip scaling for the
                 scanned fraction approximated via temp traffic (reported
                 raw + corrected)."""
    if not rec.get("ok"):
        return None
    trip = int(rec.get("meta", {}).get("scan_trip", 1) or 1)
    flops_raw = rec["cost"].get("flops", 0.0)
    bytes_raw = rec["cost"].get("bytes_accessed", 0.0)
    coll = rec["collectives"]
    in_reg = coll.get("in_regions", 0)
    coll_corr = coll["total"] + in_reg * (trip - 1)
    model_fl = rec.get("meta", {}).get("model_flops", 0)
    ndev = rec.get("n_devices", 256)
    flops_eff = max(flops_raw, model_fl / ndev)
    bytes_eff = bytes_raw * (trip if flops_raw * trip <= flops_eff * 1.5
                             else 1)
    t_comp = flops_eff / PEAK_FLOPS
    t_mem = bytes_eff / HBM_BW
    t_coll = coll_corr / LINK_BW
    dom = max(("compute", t_comp), ("memory", t_mem),
              ("collective", t_coll), key=lambda kv: kv[1])
    useful = (model_fl / ndev) / flops_eff if flops_eff else 0.0
    bound = max(t_comp, t_mem, t_coll)
    frac = ((model_fl / ndev) / PEAK_FLOPS) / bound if bound else 0.0
    return dict(t_compute=t_comp, t_memory=t_mem, t_collective=t_coll,
                dominant=dom[0], bound_s=bound, useful_flops_frac=useful,
                roofline_frac=frac, coll_corrected=coll_corr,
                flops_eff=flops_eff)


def run(mesh: str = "single"):
    files = sorted(glob.glob(os.path.join(ARTIFACT_DIR,
                                          f"dryrun_*_{mesh}.json")))
    files += sorted(glob.glob(os.path.join(ARTIFACT_DIR,
                                           f"dryrun_*_{mesh}_opt.json")))
    for f in files:
        rec = load_json(f)
        t = terms(rec)
        var = rec.get("variant", "baseline")
        name = f"roofline/{rec['arch']}/{rec['shape']}/{rec['mesh']}/{var}"
        if t is None:
            emit(name, 0.0, "FAILED")
            continue
        emit(name, t["bound_s"] * 1e6,
             f"dom={t['dominant']};comp_s={t['t_compute']:.2e};"
             f"mem_s={t['t_memory']:.2e};coll_s={t['t_collective']:.2e};"
             f"useful={t['useful_flops_frac']:.2f};"
             f"roofline_frac={t['roofline_frac']:.3f}")
