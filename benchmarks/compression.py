"""Tables 3-4: embedding-list (EL) vs embedding-trie (ET) bytes for the
actual enumeration outputs."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.configs.rads import EngineConfig, QUERIES
from repro.core import Pattern, rads_enumerate
from repro.core.trie import compression_report
from repro.graph import load_dataset, partition

CFG = EngineConfig(frontier_cap=1 << 13, fetch_cap=1 << 10,
                   verify_cap=1 << 12, region_group_budget=1 << 12)


def run(datasets=("dblp_bench", "roadnet_bench"),
        queries=("q1", "q2")):
    for ds in datasets:
        g = load_dataset(ds)
        pg = partition(g, 4, method="bfs")
        for q in queries:
            pat = Pattern.from_edges(QUERIES[q])
            r = rads_enumerate(pg, pat, CFG, mode="sim")
            if not r.embeddings:
                emit(f"compress/{ds}/{q}", 0.0, "empty")
                continue
            rows = np.array(sorted(r.embeddings))
            rep = compression_report(rows)
            emit(f"compress/{ds}/{q}", 0.0,
                 f"n={rep['n_results']};el_bytes={rep['el_bytes']};"
                 f"et_bytes={rep['et_bytes']};ratio={rep['ratio']:.2f}")
