"""Figure 12: scalability — vary machine count, report the paper's
scalability ratio plus per-device balance. Wall-clock on this container is
single-CPU simulation, so the scalable quantities are (a) max-per-device
communication and (b) seed balance after work stealing."""
from __future__ import annotations

import time

from benchmarks.common import emit
from repro.configs.rads import EngineConfig, QUERIES
from repro.core import Pattern, rads_enumerate
from repro.graph import load_dataset, partition

CFG = EngineConfig(frontier_cap=1 << 13, fetch_cap=1 << 10,
                   verify_cap=1 << 12, region_group_budget=1 << 12)


def run(dataset="dblp_bench", query="q1", ndevs=(2, 4, 8)):
    g = load_dataset(dataset)
    pat = Pattern.from_edges(QUERIES[query])
    base = None
    for nd in ndevs:
        pg = partition(g, nd, method="bfs")
        t0 = time.perf_counter()
        r = rads_enumerate(pg, pat, CFG, mode="sim", return_embeddings=False)
        us = (time.perf_counter() - t0) * 1e6
        comm = r.stats["bytes_fetch"] + r.stats["bytes_verify"]
        if base is None:
            base = comm if comm else 1.0
        emit(f"scale/{dataset}/{query}/ndev{nd}", us,
             f"count={r.count};comm_bytes={comm:.0f};"
             f"comm_ratio={comm/base:.2f};sme={r.stats['n_sme_seeds']};"
             f"dist={r.stats['n_dist_seeds']}")
