"""Figure 12: cross-process scalability — launch the ``dist`` backend at
1..N OS processes and report wall / wire-byte / skew curves.

Each cell spawns ``nd`` single-device worker subprocesses through
:func:`repro.launch.dist_worker.launch_local` (the container stand-in for
one-command-per-host launches) and replays the *same* configuration
in-process with ``mode="sim"`` as the parity reference.  Three gates run
after the artifact is written:

* every process's ``bytes_wire_*_dev`` entries sum exactly to the sim
  run's scalar wire totals (the per-device attribution is complete);
* the dist embedding count equals the sim count at every N;
* max-per-process communication bytes strictly decrease as N grows for
  N >= 2 on the bfs-partitioned powerlaw cell (the paper's scalability
  claim: more machines, less traffic per machine).

Wall-clock on this container is oversubscribed-CPU simulation, so the
wall curve is descriptive; the byte curves are the scalable quantities.
The ``wall_skew`` column (max over mean of the per-process engine-span
``wall_us``, from :func:`repro.core.driver.merge_process_stats`) factors
subprocess startup out of that noise: it is the honest straggler signal
per N even when absolute wall is not comparable across N.
When the jaxlib build lacks gloo CPU collectives the dist columns degrade
to ``null`` and the gates are skipped — the artifact still records the
sim-side curves so downstream tooling always has the file.
"""
from __future__ import annotations

import dataclasses
import json
import time

from benchmarks.common import emit
from repro.core import Pattern, rads_enumerate
from repro.core.driver import merge_process_stats
from repro.graph import load_dataset, partition
from repro.launch.dist_worker import (build_argparser, dist_available,
                                      launch_local, worker_config)
from repro.configs.rads import QUERIES

JSON_PATH = "BENCH_scalability.json"
# the paper's locality-aware partitioner: on the powerlaw cells it is the
# method whose per-process traffic actually shrinks with N (hash/block
# spread the hubs so one process's request traffic grows with peer count)
PARTITION = "bfs"
NDEVS = (1, 2, 4)

# small power-of-two caps: the n=700 powerlaw cell fits with headroom and
# every subprocess compiles in seconds instead of minutes
CAPS = dict(frontier_cap=1 << 13, fetch_cap=1 << 10, verify_cap=1 << 12,
            region_budget=1 << 12)


def _worker_args(dataset: str, query: str, wire: str) -> list[str]:
    return ["--dataset", dataset, "--query", query,
            "--partition", PARTITION, "--wire", wire, "--no-cache",
            "--frontier-cap", str(CAPS["frontier_cap"]),
            "--fetch-cap", str(CAPS["fetch_cap"]),
            "--verify-cap", str(CAPS["verify_cap"]),
            "--region-budget", str(CAPS["region_budget"])]


def _sim_reference(g, pat, nd: int, wargs: list[str]):
    """In-process ``sim`` run of the exact worker configuration."""
    cfg = worker_config(build_argparser().parse_args(wargs))
    if cfg.pipeline_depth == "auto":
        # the dist driver pins auto -> 2 for cross-process determinism;
        # mirror it so wave scheduling is identical on both sides
        cfg = dataclasses.replace(cfg, pipeline_depth=2)
    pg = partition(g, nd, method=PARTITION)
    t0 = time.perf_counter()
    r = rads_enumerate(pg, pat, cfg, mode="sim", return_embeddings=False)
    return r, time.perf_counter() - t0


def _gate_cell(workers: list[dict], sim_res) -> list[str]:
    """Per-cell parity checks; returns human-readable failure strings."""
    fails = []
    merged = merge_process_stats([w["stats"] for w in workers])
    counts = sorted({int(w["count"]) for w in workers})
    if len(counts) != 1:
        fails.append(f"per-process counts diverged: {counts}")
    elif counts[0] != sim_res.count:
        fails.append(f"dist count {counts[0]} != sim count {sim_res.count}")
    for phase in ("fetch", "verify"):
        sim_total = float(sim_res.stats[f"bytes_wire_{phase}"])
        for w in workers:
            dev_sum = float(sum(w["stats"][f"bytes_wire_{phase}_dev"]))
            if dev_sum != sim_total:
                fails.append(
                    f"proc {w['process_id']} bytes_wire_{phase}_dev sums to "
                    f"{dev_sum} != sim total {sim_total}")
        if float(merged[f"bytes_wire_{phase}"]) != sim_total:
            fails.append(
                f"dist bytes_wire_{phase} {merged[f'bytes_wire_{phase}']} "
                f"!= sim {sim_total}")
    return fails


def run(dataset="dblp_bench", queries=("q1", "q2"), ndevs=NDEVS,
        wire="raw", smoke=False, json_path=JSON_PATH):
    if smoke:
        queries = queries[:1]
    g = load_dataset(dataset)
    have_dist = dist_available()
    doc = dict(dataset=dataset, partition=PARTITION, wire=wire, cache=False,
               ndevs=list(ndevs), dist_available=have_dist,
               queries={}, gate_failures=[])

    for q in queries:
        pat = Pattern.from_edges(QUERIES[q])
        wargs = _worker_args(dataset, q, wire)
        curve = dict(count=None, wall_s=[], wall_s_mean=[], sim_wall_s=[],
                     bytes_wire_total=[], bytes_wire_max_dev=[],
                     comm_skew=[], wall_skew=[], parity=[])
        for nd in ndevs:
            sim_res, sim_wall = _sim_reference(g, pat, nd, wargs)
            curve["count"] = int(sim_res.count)
            curve["sim_wall_s"].append(round(sim_wall, 4))
            workers = launch_local(nd, wargs) if have_dist or nd == 1 \
                else None
            if workers is None:
                have_dist = False
                doc["dist_available"] = False
                for k in ("wall_s", "wall_s_mean", "bytes_wire_total",
                          "bytes_wire_max_dev", "comm_skew", "wall_skew",
                          "parity"):
                    curve[k].append(None)
                emit(f"scale/{dataset}/{q}/ndev{nd}", sim_wall * 1e6,
                     f"count={sim_res.count};dist=unavailable")
                continue
            try:
                fails = _gate_cell(workers, sim_res)
                merged = merge_process_stats([w["stats"] for w in workers])
            except ValueError as e:   # cross-process logical divergence
                fails, merged = [str(e)], None
            doc["gate_failures"].extend(f"{q}/ndev{nd}: {f}" for f in fails)
            if merged is None:
                for k in ("wall_s", "wall_s_mean", "bytes_wire_total",
                          "bytes_wire_max_dev", "comm_skew", "wall_skew"):
                    curve[k].append(None)
                curve["parity"].append(False)
                continue
            walls = [float(w["wall_s"]) for w in workers]
            total = (float(merged["bytes_wire_fetch"])
                     + float(merged["bytes_wire_verify"]))
            curve["wall_s"].append(round(max(walls), 4))
            curve["wall_s_mean"].append(round(sum(walls) / len(walls), 4))
            curve["bytes_wire_total"].append(total)
            curve["bytes_wire_max_dev"].append(
                float(merged["bytes_wire_max_dev"]))
            curve["comm_skew"].append(float(merged["comm_skew"]))
            # engine-clock honesty columns: per-process wall from the span
            # clock inside rads_enumerate (subprocess startup excluded),
            # max-merged + skew by merge_process_stats — the straggler
            # signal the wall_s subprocess timing can't separate out
            curve["wall_skew"].append(round(float(merged["wall_skew"]), 4))
            curve["parity"].append(not fails)
            emit(f"scale/{dataset}/{q}/ndev{nd}", max(walls) * 1e6,
                 f"count={workers[0]['count']};wire_bytes={total:.0f};"
                 f"max_dev={merged['bytes_wire_max_dev']:.0f};"
                 f"skew={merged['comm_skew']:.3f};"
                 f"wall_skew={merged['wall_skew']:.3f};"
                 f"engine_wall_us={merged['wall_us']:.0f};"
                 f"parity={'ok' if not fails else 'FAIL'}")
        # the scalability claim: per-process traffic shrinks as N grows
        maxdev = [m for nd, m in zip(ndevs, curve["bytes_wire_max_dev"])
                  if m is not None and nd >= 2]
        if len(maxdev) >= 2 and any(b >= a for a, b in zip(maxdev,
                                                           maxdev[1:])):
            doc["gate_failures"].append(
                f"{q}: max-per-process wire bytes not strictly "
                f"decreasing over ndevs>=2: {maxdev}")
        doc["queries"][q] = curve

    with open(json_path, "w") as f:
        json.dump(doc, f, indent=1, default=float)
    emit("scale_json", 0.0, f"path={json_path}")
    # gates run AFTER the artifact lands so a red run still leaves evidence
    if doc["gate_failures"]:
        raise AssertionError("scalability gates failed: "
                             + "; ".join(doc["gate_failures"]))
