"""Figure 13: execution-plan effectiveness — the full §4 plan (min rounds +
min span + score) vs RanS (random stars) vs RanM (min rounds, unscored)."""
from __future__ import annotations

import time

from benchmarks.common import emit
from repro.configs.rads import EngineConfig, QUERIES
from repro.core import (Pattern, best_plan, min_rounds_unscored_plan,
                        rads_enumerate, random_star_plan)
from repro.graph import load_dataset, partition

CFG = EngineConfig(frontier_cap=1 << 13, fetch_cap=1 << 10,
                   verify_cap=1 << 12, region_group_budget=1 << 12)


def run(dataset="roadnet_bench", queries=("q2", "q6")):
    g = load_dataset(dataset)
    pg = partition(g, 4, method="bfs")
    for q in queries:
        pat = Pattern.from_edges(QUERIES[q])
        plans = dict(rads=best_plan(pat),
                     ranm=min_rounds_unscored_plan(pat),
                     rans=random_star_plan(pat, seed=1))
        counts = set()
        for name, plan in plans.items():
            t0 = time.perf_counter()
            r = rads_enumerate(pg, pat, CFG, mode="sim", plan=plan,
                               return_embeddings=False)
            us = (time.perf_counter() - t0) * 1e6
            comm = r.stats["bytes_fetch"] + r.stats["bytes_verify"]
            counts.add(r.count)
            emit(f"plan/{dataset}/{q}/{name}", us,
                 f"count={r.count};comm_bytes={comm:.0f};"
                 f"rounds={plan.n_rounds}")
        assert len(counts) == 1, f"plan variants disagree on {q}"
