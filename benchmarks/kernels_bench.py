"""Kernel micro-benchmarks. On this CPU container the timed path is the
jnp/XLA reference (Pallas interpret mode is a Python emulator — correctness
only); the Pallas kernels are timed on real TPUs by the same harness."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call
from repro.kernels.flash_attn.ops import flash_attention_k
from repro.kernels.membership.ops import membership
from repro.kernels.moe_gemm.ops import moe_gemm
from repro.kernels.segment_spmm.ops import segment_spmm


def run():
    key = jax.random.PRNGKey(0)

    rows = jnp.sort(jax.random.randint(key, (4096, 64), 0, 100000), axis=1)
    vals = jax.random.randint(key, (4096, 16), 0, 100000)
    us = time_call(lambda: membership(rows, vals).block_until_ready())
    emit("kernel/membership/4096x64x16", us,
         f"checks_per_s={4096*16/us*1e6:.3e}")

    B, S, H, Hk, D = 1, 2048, 8, 2, 64
    q = jax.random.normal(key, (B, S, H, D), jnp.bfloat16)
    k = jax.random.normal(key, (B, S, Hk, D), jnp.bfloat16)
    v = jax.random.normal(key, (B, S, Hk, D), jnp.bfloat16)
    us = time_call(lambda: flash_attention_k(
        q, k, v, use_kernel=False).block_until_ready())
    fl = 2 * B * H * S * S * D * 2 / 2
    emit("kernel/flash_attn/2048", us, f"gflops={fl/us/1e3:.1f}")

    E, C, d, f = 8, 256, 512, 1024
    x = jax.random.normal(key, (E, C, d), jnp.bfloat16)
    wg = jax.random.normal(key, (E, d, f), jnp.bfloat16) * 0.05
    wu = jax.random.normal(key, (E, d, f), jnp.bfloat16) * 0.05
    wd = jax.random.normal(key, (E, f, d), jnp.bfloat16) * 0.05
    us = time_call(lambda: moe_gemm(x, wg, wu, wd,
                                    use_kernel=False).block_until_ready())
    fl = E * C * d * f * 3 * 2
    emit("kernel/moe_gemm/8x256x512x1024", us, f"gflops={fl/us/1e3:.1f}")

    Eg, N, Dg = 100000, 8192, 128
    msgs = jax.random.normal(key, (Eg, Dg), jnp.float32)
    dst = jax.random.randint(key, (Eg,), 0, N)
    us = time_call(lambda: segment_spmm(msgs, dst, N).block_until_ready())
    emit("kernel/segment_spmm/100k_edges", us,
         f"gbytes_per_s={Eg*Dg*4/us/1e3:.2f}")
