# Verification entry points. `make verify` is the fast hermetic tier;
# `make verify-slow` is the multi-device / subprocess tier. CI runs both
# (see .github/workflows/ci.yml) plus the collection gate, so a test module
# that stops importing (e.g. a missing optional dependency) fails loudly
# instead of silently shrinking the suite.
PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: verify verify-slow verify-all collect-check lint lint-baseline

## tier-1: every module must collect; fast tests must pass
verify: collect-check
	$(PY) -m pytest -x -q -m "not slow"

## radslint static analysis (tools/radslint): jit-safety, determinism,
## recompile triggers, stat threading, dtype hygiene over src/repro.
## Fails on any finding not in tools/radslint/baseline.json (the ratchet)
## or on an inline suppression without a justification.
lint:
	$(PY) -m tools.radslint

## regenerate the ratchet file — the baseline should only ever shrink;
## review the diff before committing it
lint-baseline:
	$(PY) -m tools.radslint --update-baseline

## multi-device / subprocess jobs (8 and 512 forced host devices)
verify-slow:
	$(PY) -m pytest -x -q -m slow

## the full suite, exactly what the roadmap's tier-1 command runs
verify-all:
	$(PY) -m pytest -x -q

## collection regression gate: every test module must import cleanly
collect-check:
	$(PY) -m pytest -q --collect-only >/dev/null

## ~30s enumeration benchmark subset; writes BENCH_enumeration.json
## (patterns x backends x storage formats x adjacency-cache on/off x wire
## raw/varint, compile vs steady wall split, peak_adj_bytes
## dense-vs-bucketed, cache hit-rate / bytes_saved_cache, actual
## bytes_wire_* columns, sync-vs-async overlap comparison).
## Fails if storage formats, cache configurations OR wire formats disagree
## on any count, if a varint row's actual wire bytes are not below raw, or
## if the actual coded fetch bytes exceed the modeled
## bytes_fetch_compressed baseline by more than 5%.
## Also writes trace_smoke.json (a Perfetto-loadable wave timeline of one
## warm run) and gates it on Chrome schema validity, paired
## dispatch->retire flow arrows, and >= 4 named track types.
## cross-process scalability smoke: dist backend at 1/2/4 OS processes on
## the bfs-partitioned powerlaw cell, gated on (a) per-process wire-byte
## sums equaling the in-process sim totals byte-for-byte, (b) dist counts
## == sim counts, (c) max-per-process comm bytes strictly decreasing as N
## grows. Writes BENCH_scalability.json; degrades to sim-only curves (and
## skips the gates) when jaxlib lacks gloo CPU collectives.
.PHONY: bench-scale
bench-scale:
	$(PY) -m benchmarks.run --only scale --smoke
	@$(PY) -c "import json; \
	d=json.load(open('BENCH_scalability.json')); \
	assert not d['gate_failures'], d['gate_failures']; \
	q=next(iter(d['queries'].values())); \
	print('bench-scale: dist_available=%s ndevs=%s count=%s ' \
	'max_dev=%s skew=%s' % (d['dist_available'], d['ndevs'], q['count'], \
	q['bytes_wire_max_dev'], q['comm_skew']))"

.PHONY: bench-smoke
bench-smoke:
	XLA_FLAGS="--xla_cpu_multi_thread_eigen=false" \
	$(PY) -m benchmarks.run --only enumeration --smoke
	@$(PY) -c "import json, collections; \
	d=json.load(open('BENCH_enumeration.json')); \
	t=d['sync_vs_async_total']; \
	rows=[r for r in d['results'] if r.get('storage')]; \
	byq=collections.defaultdict(set); \
	[byq[(r['dataset'], r['query'])].add(r['count']) for r in rows]; \
	bad={k: sorted(v) for k, v in byq.items() if len(v) != 1}; \
	assert not bad, \
	'storage/cache count divergence (dense vs bucketed vs cache-off): %r' \
	% bad; \
	mis=[r for r in rows if 'cache_enabled' in r \
	     and r['cache_enabled'] != (r.get('cache') == 'on')]; \
	assert not mis, 'cache config not honoured (silently on/off): %r' % mis; \
	vws=[r for r in rows if r.get('wire') == 'varint']; \
	assert vws, 'no varint wire rows in the smoke subset'; \
	bad_model=[r for r in vws \
	     if r['bytes_wire_fetch'] > 1.05 * r['bytes_fetch_compressed']]; \
	assert not bad_model, \
	'actual coded fetch bytes exceed modeled baseline by >5%%: %r' \
	% bad_model; \
	bad_wire=[r for r in vws \
	     if r['bytes_wire_fetch'] + r['bytes_wire_verify'] \
	        >= r['bytes_fetch'] + r['bytes_verify']]; \
	assert not bad_wire, \
	'varint wire bytes not below raw accounting: %r' % bad_wire; \
	adj={r['storage']: r['peak_adj_bytes'] for r in rows \
	     if r['system'] == 'rads-sim' and r.get('cache') == 'on'}; \
	con=[r for r in rows if r['system'] == 'rads-sim' \
	     and r.get('cache') == 'on']; \
	dead=[r for r in con if r.get('cache_hit_rate_warm', 1.0) <= 0.0]; \
	assert not dead, \
	'cache-on rows with zero warm hit-rate (probe/insert path broken): %r' \
	% dead; \
	hit=max((r['cache_hit_rate'] for r in con), default=0.0); \
	whit=max((r.get('cache_hit_rate_warm', 0.0) for r in con), default=0.0); \
	sav=max((r['bytes_saved_cache'] for r in con), default=0.0); \
	cw=[r for r in rows if r.get('compile_us_cold', 0) > 0]; \
	assert cw, 'no rows with cold compile time (executable store unused)'; \
	bad_cw=[(r['dataset'], r['query'], r['system'], r.get('wire'), \
	         r['compile_us_warm'], r['compile_us_cold'], \
	         r.get('compiles_warm')) for r in cw \
	        if r['compile_us_warm'] > 0.05 * r['compile_us_cold'] \
	        or r.get('compiles_warm', 0) > 0]; \
	assert not bad_cw, \
	'warm path re-jits (persistent executable store broken): %r' % bad_cw; \
	assert t['async_leq_sync'], \
	'async pipeline slower than sync: %r' % t; \
	wcold=max(r['compile_us_cold'] for r in cw); \
	wwarm=max(r['compile_us_warm'] for r in cw); \
	wv=vws[0]; \
	wcut=1.0 - (wv['bytes_wire_fetch'] + wv['bytes_wire_verify']) \
	     / max(wv['bytes_fetch'] + wv['bytes_verify'], 1.0); \
	print('bench-smoke: %d result rows, storage+cache+wire counts agree; ' \
	'adj bytes dense %d vs bucketed %d; cache hit-rate %.3f (warm %.3f) ' \
	'bytes_saved_cache %.0f; varint wire cut %.1f%%; ' \
	'compile cold max %.0fus warm max %.0fus (zero warm re-jits); ' \
	'sync %.0fus async %.0fus (async<=sync: %s)' \
	% (len(d['results']), adj.get('dense', -1), adj.get('bucketed', -1), \
	hit, whit, sav, 100 * wcut, wcold, wwarm, \
	t['sync_us'], t['async_us'], t['async_leq_sync']))"
	@$(PY) -c "import json; \
	doc=json.load(open('trace_smoke.json')); \
	evs=doc['traceEvents']; \
	assert evs, 'empty trace'; \
	bad=[e for e in evs \
	     if not {'name', 'ph', 'ts', 'pid', 'tid'} <= set(e)]; \
	assert not bad, 'events missing ph/ts/pid/tid: %r' % bad[:3]; \
	s={e['id'] for e in evs if e['ph'] == 's'}; \
	f={e['id'] for e in evs if e['ph'] == 'f'}; \
	assert s and s == f, 'dispatch->retire flow arrows unpaired: %r' \
	% sorted(s ^ f); \
	tracks={e['tid'] for e in evs \
	        if e['ph'] == 'M' and e['name'] == 'thread_name'}; \
	assert len(tracks) >= 4, 'fewer than 4 named track types: %r' % tracks; \
	print('trace-smoke: %d events, %d waves flow-paired, %d named tracks, ' \
	'%d dropped' % (len(evs), len(s), len(tracks), \
	doc['otherData']['dropped_records']))"
