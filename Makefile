# Verification entry points. `make verify` is the fast hermetic tier;
# `make verify-slow` is the multi-device / subprocess tier. CI runs both
# (see .github/workflows/ci.yml) plus the collection gate, so a test module
# that stops importing (e.g. a missing optional dependency) fails loudly
# instead of silently shrinking the suite.
PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: verify verify-slow verify-all collect-check

## tier-1: every module must collect; fast tests must pass
verify: collect-check
	$(PY) -m pytest -x -q -m "not slow"

## multi-device / subprocess jobs (8 and 512 forced host devices)
verify-slow:
	$(PY) -m pytest -x -q -m slow

## the full suite, exactly what the roadmap's tier-1 command runs
verify-all:
	$(PY) -m pytest -x -q

## collection regression gate: all 10 test modules must import cleanly
collect-check:
	$(PY) -m pytest -q --collect-only >/dev/null

## ~30s enumeration benchmark subset; writes BENCH_enumeration.json
## (patterns x backends x storage formats, compile vs steady wall split,
## peak_adj_bytes dense-vs-bucketed, sync-vs-async overlap comparison).
## Fails if the dense and bucketed storage formats disagree on any count.
.PHONY: bench-smoke
bench-smoke:
	XLA_FLAGS="--xla_cpu_multi_thread_eigen=false" \
	$(PY) -m benchmarks.run --only enumeration --smoke
	@$(PY) -c "import json, collections; \
	d=json.load(open('BENCH_enumeration.json')); \
	t=d['sync_vs_async_total']; \
	rows=[r for r in d['results'] if r.get('storage')]; \
	byq=collections.defaultdict(set); \
	[byq[(r['dataset'], r['query'])].add(r['count']) for r in rows]; \
	bad={k: sorted(v) for k, v in byq.items() if len(v) != 1}; \
	assert not bad, 'dense vs bucketed count divergence: %r' % bad; \
	adj={r['storage']: r['peak_adj_bytes'] for r in rows \
	     if r['system'] == 'rads-sim'}; \
	print('bench-smoke: %d result rows, storage counts agree; ' \
	'adj bytes dense %d vs bucketed %d; sync %.0fus async %.0fus (async<=sync: %s)' \
	% (len(d['results']), adj.get('dense', -1), adj.get('bucketed', -1), \
	t['sync_us'], t['async_us'], t['async_leq_sync']))"
