"""Observability layer: ring-buffer span recorder determinism, Chrome
trace-event schema validity (Perfetto-loadable, flow arrows pair up),
metrics-registry schema completeness, the zero-overhead-when-off gate
(tracing on vs off is byte-identical in counts and wire bytes), exporter
well-formedness, and dist wall-clock honesty through the stats merge."""
import json

import pytest

from repro.configs.rads import QUERIES, EngineConfig
from repro.core import Pattern, rads_enumerate
from repro.core.driver import merge_process_stats
from repro.graph import erdos_graph, partition
from repro.obs import (COUNTER, GAUGE, Instrument, MetricsRegistry,
                       NULL_TRACER, TRACK_PREWARM, TRACK_RETIRE, TRACK_SCHED,
                       TRACK_WAVE0, TraceRecorder, build_driver_registry,
                       merge_traces)

CFG = EngineConfig(frontier_cap=1 << 13, fetch_cap=512, verify_cap=2048,
                   region_group_budget=64, enable_sme=False)


# --------------------------------------------------------------------------- #
# recorder unit behavior
# --------------------------------------------------------------------------- #
def test_ring_overflow_drops_oldest():
    tr = TraceRecorder(capacity=8)
    for i in range(12):
        tr.instant(f"ev{i}", TRACK_SCHED)
    assert tr.n_recorded == 12
    assert tr.n_dropped == 4
    recs = tr.records()
    assert len(recs) == 8
    # oldest surviving record is ev4; order is preserved
    assert [r[1] for r in recs] == [f"ev{i}" for i in range(4, 12)]


def test_span_nesting_records_inner_first_and_stays_monotone():
    tr = TraceRecorder()
    with tr.span("outer", TRACK_SCHED, depth=2):
        with tr.span("inner", TRACK_SCHED):
            pass
    recs = tr.records()
    assert [r[1] for r in recs] == ["inner", "outer"]   # exit order
    (_, _, _, its, idur, _, _), (_, _, _, ots, odur, _, oargs) = recs
    assert ots <= its and its + idur <= ots + odur + 1e-6
    assert oargs == {"depth": 2}


def test_null_tracer_is_inert():
    assert NULL_TRACER.enabled is False
    with NULL_TRACER.span("x", TRACK_SCHED):
        pass
    with NULL_TRACER.device_span("x"):
        pass
    NULL_TRACER.complete("x", 1, 0.0)
    NULL_TRACER.instant("x", 1)
    NULL_TRACER.flow_start(0, 1)
    NULL_TRACER.flow_end(0, 1)


def test_merge_traces_concatenates_and_sums_drops():
    docs = []
    for pid in range(2):
        tr = TraceRecorder(capacity=8, pid=pid)
        for i in range(10):
            tr.instant(f"p{pid}e{i}", TRACK_SCHED)
        docs.append(tr.to_chrome())
    merged = merge_traces(docs)
    pids = {ev["pid"] for ev in merged["traceEvents"]}
    assert pids == {0, 1}
    assert merged["otherData"]["dropped_records"] == 4
    assert merged["otherData"]["merged_processes"] == 2


# --------------------------------------------------------------------------- #
# a real traced run (shared fixture: one traced + one untraced enumeration)
# --------------------------------------------------------------------------- #
@pytest.fixture(scope="module")
def traced_run():
    g = erdos_graph(150, 5.0, seed=3)
    pg = partition(g, 4, method="bfs")
    pat = Pattern.from_edges(QUERIES["q1"])
    tracer = TraceRecorder()
    on = rads_enumerate(pg, pat, CFG, mode="sim", return_embeddings=False,
                        tracer=tracer)
    off = rads_enumerate(pg, pat, CFG, mode="sim", return_embeddings=False)
    return tracer, on, off


def test_tracing_off_is_byte_identical(traced_run):
    """The zero-overhead contract: the recorder only observes — every
    count and wire byte is identical with tracing on vs off."""
    _, on, off = traced_run
    assert on.count == off.count
    for k in ("n_waves", "n_groups", "bytes_fetch", "bytes_verify",
              "bytes_wire_fetch", "bytes_wire_verify", "cache_hits",
              "cache_probes", "overflow_retries", "cap_escalations"):
        assert on.stats[k] == off.stats[k], k


def test_chrome_schema_valid(traced_run):
    tracer, _, _ = traced_run
    doc = tracer.to_chrome()
    assert doc["displayTimeUnit"] == "ms"
    assert doc["otherData"]["dropped_records"] == 0
    for ev in doc["traceEvents"]:
        assert {"name", "ph", "ts", "pid", "tid"} <= set(ev), ev
        if ev["ph"] == "X":
            assert "dur" in ev and ev["dur"] >= 0.0
        elif ev["ph"] == "i":
            assert ev["s"] == "t"
        elif ev["ph"] in ("s", "f"):
            assert isinstance(ev["id"], int)
    json.dumps(doc)   # JSON-serializable end to end


def test_flow_arrows_pair_and_land_in_retire_spans(traced_run):
    tracer, on, _ = traced_run
    evs = tracer.events()
    starts = {ev["id"]: ev for ev in evs if ev["ph"] == "s"}
    ends = {ev["id"]: ev for ev in evs if ev["ph"] == "f"}
    assert set(starts) == set(ends)
    assert len(starts) == on.stats["n_waves"]   # one arrow per wave
    retires = [ev for ev in evs
               if ev["ph"] == "X" and ev["name"] == "retire"]
    assert len(retires) == on.stats["n_waves"]
    for fid, fe in ends.items():
        assert fe["bp"] == "e"
        assert fe["tid"] == TRACK_RETIRE
        assert starts[fid]["ts"] <= fe["ts"]
        # flow end binds to an enclosing retire slice on the same track
        assert any(r["tid"] == fe["tid"] and
                   r["ts"] <= fe["ts"] <= r["ts"] + r["dur"]
                   for r in retires), fid
        assert starts[fid]["tid"] >= TRACK_WAVE0   # starts on a wave lane


def test_track_types_cover_the_pipeline(traced_run):
    """>= 4 distinct track types: scheduler, retire, prewarm-or-resolve,
    and per-wave lanes — all named via thread_name metadata."""
    tracer, on, _ = traced_run
    evs = tracer.events()
    named = {ev["tid"]: ev["args"]["name"] for ev in evs
             if ev["ph"] == "M" and ev["name"] == "thread_name"}
    assert named.get(TRACK_SCHED) == "scheduler"
    assert named.get(TRACK_RETIRE) == "retire"
    assert named.get(TRACK_PREWARM) == "prewarm"
    lanes = [t for t in named if t >= TRACK_WAVE0]
    assert lanes and len(named) >= 4
    by_track = {}
    for ev in evs:
        if ev["ph"] == "X":
            by_track.setdefault(ev["tid"], set()).add(ev["name"])
    # the scheduler lane carries phase + group-formation spans
    assert any(n.startswith("phase:") for n in by_track[TRACK_SCHED])
    assert "group_form" in by_track[TRACK_SCHED]
    # wave lanes carry the per-stage attribution spans
    lane_names = set().union(*(by_track.get(t, set()) for t in lanes))
    assert "init" in lane_names and "finalize" in lane_names
    assert any(n.startswith("fetch:u") for n in lane_names)
    assert any(n.startswith("expand:u") for n in lane_names)
    assert any(n.startswith("verify:u") for n in lane_names)
    assert "wave" in lane_names                  # the whole-life span
    # stage spans carry exec-cache attribution
    stage = [ev for ev in evs if ev["ph"] == "X"
             and ev["name"].startswith(("fetch:u", "expand:u", "verify:u"))]
    assert stage and all(
        ev["args"]["exec"] in ("slot", "store", "compile") for ev in stage)


def test_registry_schema_complete(traced_run):
    """Every stats key a real run emits is a declared instrument — the
    runtime counterpart of radslint's RL004 metric extension."""
    _, on, _ = traced_run
    declared = on.registry.declared_names()
    undeclared = set(on.stats) - declared
    assert not undeclared, f"undeclared stats keys: {sorted(undeclared)}"
    assert set(on.stats) == set(on.registry.to_stats())


def test_wall_clock_recorded_without_tracing(traced_run):
    """Satellite 1: the span-clock phase wall is a stats key, present and
    positive even when no tracer is attached."""
    _, on, off = traced_run
    for st in (on.stats, off.stats):
        assert st["sme_wall_us"] == 0.0          # enable_sme=False
        assert st["dist_wall_us"] > 0.0
        assert st["wall_us"] == st["sme_wall_us"] + st["dist_wall_us"]


# --------------------------------------------------------------------------- #
# metrics registry semantics + exporters
# --------------------------------------------------------------------------- #
def test_unset_instruments_absent_from_mapping_view():
    reg = build_driver_registry()
    assert "auto_depth" not in reg
    assert len(reg) == 0
    reg["n_waves"] = 3
    assert "n_waves" in reg and reg["n_waves"] == 3
    assert reg.get("auto_depth") is None
    with pytest.raises(KeyError):
        reg["auto_depth"]


def test_undeclared_write_auto_registers_untyped_gauge():
    reg = MetricsRegistry()
    reg["warm_pipeline_s"] = 0.5
    ins = {i.name: i for i in reg.instruments()}["warm_pipeline_s"]
    assert ins.kind == GAUGE and not ins.declared
    assert reg.inc("adhoc") == 1 and reg.inc("adhoc", 2) == 3


def test_redeclaring_kind_raises():
    reg = MetricsRegistry([Instrument("x", COUNTER)])
    with pytest.raises(ValueError, match="redeclared"):
        reg.register(Instrument("x", GAUGE))


def test_exporters_well_formed(traced_run, tmp_path):
    _, on, _ = traced_run
    reg = on.registry
    jpath = reg.export_json(str(tmp_path / "m.json"))
    with open(jpath) as f:
        doc = json.load(f)
    assert doc["n_waves"]["kind"] == "counter"
    assert doc["wall_us"]["unit"] == "us"
    assert doc["n_waves"]["value"] == on.stats["n_waves"]
    ppath = reg.export_prometheus(str(tmp_path / "m.prom"))
    text = open(ppath).read()
    assert "# TYPE rads_n_waves counter" in text
    assert f"rads_n_waves {float(on.stats['n_waves']):g}" in text
    assert 'rads_bytes_wire_fetch_dev{index="0"}' in text
    assert "rads_info{" in text                   # wire_format et al.
    for line in text.splitlines():
        assert line.startswith(("#", "rads_")), line


def test_summary_formats_by_unit():
    reg = MetricsRegistry([Instrument("compile_s", COUNTER, "s"),
                           Instrument("wall_us", COUNTER, "us"),
                           Instrument("bytes_fetch", COUNTER, "bytes"),
                           Instrument("prewarm", GAUGE),
                           Instrument("auto_depth", GAUGE)])
    reg["compile_s"] = 1.5
    reg["wall_us"] = 2_500_000.0
    reg["bytes_fetch"] = 3_000_000.0
    reg["prewarm"] = True
    s = reg.summary(("compile_s", "wall_us", "bytes_fetch", "prewarm",
                     "auto_depth"))
    assert s == "compile_s 1.50s | wall_us 2.50s | bytes_fetch 3.0MB | prewarm on"


# --------------------------------------------------------------------------- #
# dist wall-clock honesty through the merge
# --------------------------------------------------------------------------- #
def test_merge_process_stats_wall_honesty():
    base = dict(bytes_wire_fetch=10.0, bytes_wire_verify=4.0, n_waves=3)
    p0 = dict(base, wall_us=100.0, dist_wall_us=100.0, sme_wall_us=0.0)
    p1 = dict(base, wall_us=50.0, dist_wall_us=50.0, sme_wall_us=0.0)
    merged = merge_process_stats([p0, p1])
    assert merged["wall_us"] == 100.0            # max, not mean
    assert merged["dist_wall_us"] == 100.0
    assert merged["per_process_wall_us"] == [100.0, 50.0]
    assert merged["wall_skew"] == pytest.approx(100.0 / 75.0)
    # logical divergence still raises (the merge stays an assertion)
    with pytest.raises(ValueError, match="diverged"):
        merge_process_stats([p0, dict(p1, n_waves=4)])
