"""Property test on the system invariant: RADS (sim) == brute-force oracle
for random (graph, pattern) draws. Few examples — each draw compiles the
engine — but unconstrained in structure."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # hermetic container: vendored fallback
    from _hypothesis_compat import given, settings, strategies as st

from repro.configs.rads import EngineConfig
from repro.core import Pattern, canonicalize, enumerate_oracle, rads_enumerate
from repro.graph import erdos_graph, partition

CFG = EngineConfig(frontier_cap=1 << 12, fetch_cap=512, verify_cap=2048,
                   region_group_budget=1 << 11)


@st.composite
def pattern_and_graph(draw):
    n = draw(st.integers(3, 5))
    edges = set()
    for v in range(1, n):
        edges.add((draw(st.integers(0, v - 1)), v))
    for _ in range(draw(st.integers(0, 3))):
        a = draw(st.integers(0, n - 1))
        b = draw(st.integers(0, n - 1))
        if a != b:
            edges.add((min(a, b), max(a, b)))
    seed = draw(st.integers(0, 10))
    deg = draw(st.sampled_from([3.0, 5.0]))
    return Pattern.from_edges(edges), seed, deg


@given(pattern_and_graph())
@settings(max_examples=6, deadline=None)
def test_property_engine_equals_oracle(pg_draw):
    pattern, seed, deg = pg_draw
    g = erdos_graph(90, deg, seed=seed)
    pg = partition(g, 3, method="bfs")
    oracle = canonicalize(enumerate_oracle(g, pattern), pattern)
    res = rads_enumerate(pg, pattern, CFG, mode="sim")
    assert res.count == len(oracle)
    assert canonicalize(res.embeddings, pattern) == oracle


def test_gather_mode_matches_sim_and_oracle():
    """The meshless 'gather' backend runs the full distributed protocol on a
    single process and must agree with sim and the brute-force oracle."""
    pattern = Pattern.from_edges([(0, 1), (1, 2), (0, 2), (2, 3)])
    g = erdos_graph(70, 4.0, seed=11)
    pg = partition(g, 4, method="bfs")
    oracle = canonicalize(enumerate_oracle(g, pattern), pattern)
    sim = rads_enumerate(pg, pattern, CFG, mode="sim")
    gather = rads_enumerate(pg, pattern, CFG, mode="gather")
    assert sim.count == gather.count == len(oracle)
    assert canonicalize(gather.embeddings, pattern) == oracle
    # identical logical traffic accounting across backends
    assert gather.stats["bytes_fetch"] == sim.stats["bytes_fetch"]
    assert gather.stats["bytes_verify"] == sim.stats["bytes_verify"]
