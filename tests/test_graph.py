"""Graph substrate: CSR, partitioning, border distance, sampler."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # hermetic container: vendored fallback
    from _hypothesis_compat import given, settings, strategies as st

from repro.graph import (Graph, edge_cut, erdos_graph, icosahedral_mesh,
                         partition, powerlaw_graph, road_graph,
                         sample_capacities, sample_neighbors)


def test_csr_sorted_dedup():
    g = Graph.from_edges(5, [(0, 1), (1, 0), (0, 1), (2, 3), (3, 3)])
    assert g.n_edges == 2
    assert g.has_edge(1, 0) and not g.has_edge(3, 3)
    assert list(g.neighbors(0)) == [1]


@given(st.integers(2, 6), st.integers(10, 80))
@settings(max_examples=20, deadline=None)
def test_property_partition_preserves_graph(ndev, n):
    g = erdos_graph(n, 4.0, seed=n)
    pg = partition(g, ndev, method="bfs")
    # every original edge exists post-renumber, and degree is preserved
    assert pg.n_real == g.n
    for u in range(0, g.n, max(g.n // 10, 1)):
        nu = pg.old2new[u]
        assert set(pg.new2old[pg.neighbors(nu)]) == set(g.neighbors(u))
    # ownership map: every real vertex owned by exactly its block
    own = pg.old2new[np.arange(g.n)] // pg.stride
    assert own.min() >= 0 and own.max() < ndev


def test_border_distance_definition():
    g = road_graph(100, seed=0)
    pg = partition(g, 4, method="block")
    # Definition 1: BD==0 iff border vertex
    for t in range(4):
        nl = int(pg.n_local[t])
        bd = pg.border_dist[t, :nl]
        br = pg.border[t, :nl]
        assert np.all((bd == 0) == br)
        # BFS property: any vertex at BD=d has a neighbor at BD>=d-1
        for i in range(nl):
            if bd[i] > 0 and bd[i] < (1 << 29):
                nbrs = pg.neighbors(t * pg.stride + i)
                local = nbrs[nbrs // pg.stride == t] - t * pg.stride
                assert (bd[local].min() == bd[i] - 1)


def test_bfs_partition_cuts_fewer_edges_than_hash():
    g = road_graph(400, seed=0)
    from repro.graph.partition import assign_bfs, assign_hash
    cut_bfs = edge_cut(g, assign_bfs(g, 4))
    cut_hash = edge_cut(g, assign_hash(g, 4))
    assert cut_bfs < cut_hash


def test_sampler_shapes_and_validity():
    g = powerlaw_graph(300, 6, seed=2)
    rng = np.random.default_rng(0)
    seeds = rng.choice(g.n, 16, replace=False)
    sub = sample_neighbors(g, seeds, (5, 3), rng)
    mn, me = sample_capacities(16, (5, 3))
    assert sub.nodes.shape == (mn,) and sub.edge_src.shape == (me,)
    ne = int(sub.edge_mask.sum())
    # every sampled edge is a real graph edge
    for i in range(0, ne, max(ne // 20, 1)):
        u = int(sub.nodes[sub.edge_src[i]])
        v = int(sub.nodes[sub.edge_dst[i]])
        assert g.has_edge(u, v)


def test_icosahedral_multimesh_counts():
    for r in (0, 1, 2):
        v, e = icosahedral_mesh(r)
        assert v.shape[0] == 10 * 4 ** r + 2
        assert e.shape[0] == 30 * sum(4 ** i for i in range(r + 1))
        np.testing.assert_allclose(np.linalg.norm(v, axis=1), 1.0, rtol=1e-5)
