"""End-to-end behaviour of the paper's system: RADS == oracle on real
graph/query mixes, robustness knobs, and the engine==baselines agreement."""
import dataclasses

import numpy as np
import pytest

from repro.configs.rads import EngineConfig, QUERIES
from repro.core import Pattern, canonicalize, enumerate_oracle, rads_enumerate
from repro.core.baselines import crystal_lite, join_enumerate, psgl_enumerate
from repro.graph import erdos_graph, partition, road_graph

CFG = EngineConfig(frontier_cap=1 << 13, fetch_cap=512, verify_cap=2048,
                   region_group_budget=1 << 12)


@pytest.fixture(scope="module")
def erdos():
    g = erdos_graph(150, 5.0, seed=3)
    return g, partition(g, 4, method="bfs")


@pytest.fixture(scope="module")
def road():
    g = road_graph(400, seed=1)
    return g, partition(g, 4, method="block")


@pytest.mark.parametrize("qname", ["q1", "q2", "q3", "q5", "q8"])
def test_rads_matches_oracle_erdos(erdos, qname):
    g, pg = erdos
    pat = Pattern.from_edges(QUERIES[qname])
    oracle = canonicalize(enumerate_oracle(g, pat), pat)
    res = rads_enumerate(pg, pat, CFG, mode="sim")
    assert res.count == len(oracle)
    assert canonicalize(res.embeddings, pat) == oracle


@pytest.mark.parametrize("qname", ["q1", "q6"])
def test_rads_matches_oracle_road(road, qname):
    g, pg = road
    pat = Pattern.from_edges(QUERIES[qname])
    oracle = canonicalize(enumerate_oracle(g, pat), pat)
    res = rads_enumerate(pg, pat, CFG, mode="sim")
    assert canonicalize(res.embeddings, pat) == oracle
    # road graphs: block partition => most seeds are SM-E (Prop. 1 pays off)
    st = res.stats
    assert st["n_sme_seeds"] > 0


def test_sme_off_same_results(erdos):
    g, pg = erdos
    pat = Pattern.from_edges(QUERIES["q2"])
    a = rads_enumerate(pg, pat, CFG, mode="sim")
    b = rads_enumerate(pg, pat, dataclasses.replace(CFG, enable_sme=False),
                       mode="sim")
    assert canonicalize(a.embeddings, pat) == canonicalize(b.embeddings, pat)
    assert b.stats["n_sme_seeds"] == 0


def test_work_stealing_off_same_results(erdos):
    g, pg = erdos
    pat = Pattern.from_edges(QUERIES["q1"])
    a = rads_enumerate(pg, pat, CFG, mode="sim")
    b = rads_enumerate(pg, pat,
                       dataclasses.replace(CFG, enable_work_stealing=False),
                       mode="sim")
    assert canonicalize(a.embeddings, pat) == canonicalize(b.embeddings, pat)


def test_tiny_caps_trigger_robustness_loop(erdos):
    """Memory-control path: with absurdly small caps the driver must split
    region groups / escalate capacities and still return exact results."""
    g, pg = erdos
    pat = Pattern.from_edges(QUERIES["q1"])
    tiny = EngineConfig(frontier_cap=256, fetch_cap=64, verify_cap=128,
                        region_group_budget=64)
    oracle = canonicalize(enumerate_oracle(g, pat), pat)
    res = rads_enumerate(pg, pat, tiny, mode="sim")
    assert canonicalize(res.embeddings, pat) == oracle
    assert res.stats["overflow_retries"] + res.stats["cap_escalations"] >= 0


def test_partition_methods_agree(erdos):
    g, _ = erdos
    pat = Pattern.from_edges(QUERIES["q3"])
    oracle = canonicalize(enumerate_oracle(g, pat), pat)
    for method in ("bfs", "block", "hash"):
        pg = partition(g, 4, method=method)
        res = rads_enumerate(pg, pat, CFG, mode="sim")
        assert canonicalize(res.embeddings, pat) == oracle, method


def test_ndev_sweep(erdos):
    g, _ = erdos
    pat = Pattern.from_edges(QUERIES["q1"])
    oracle = canonicalize(enumerate_oracle(g, pat), pat)
    for ndev in (1, 2, 8):
        pg = partition(g, ndev, method="bfs")
        res = rads_enumerate(pg, pat, CFG, mode="sim")
        assert canonicalize(res.embeddings, pat) == oracle, ndev


@pytest.mark.parametrize("qname", ["q1", "q5"])
def test_baselines_match_oracle(erdos, qname):
    g, pg = erdos
    pat = Pattern.from_edges(QUERIES[qname])
    oracle = canonicalize(enumerate_oracle(g, pat), pat)
    assert canonicalize(psgl_enumerate(pg, pat).embeddings, pat) == oracle
    assert canonicalize(join_enumerate(pg, pat, "twintwig").embeddings,
                        pat) == oracle
    assert canonicalize(join_enumerate(pg, pat, "seed").embeddings,
                        pat) == oracle
    assert canonicalize(crystal_lite(pg, pat, g).embeddings, pat) == oracle


def test_rads_ships_less_than_join_baselines(erdos):
    """The paper's headline claim (Figures 8-11): RADS communication volume
    is far below the shuffle volume of join-based systems."""
    g, pg = erdos
    pat = Pattern.from_edges(QUERIES["q5"])
    r = rads_enumerate(pg, pat, CFG, mode="sim")
    tt = join_enumerate(pg, pat, "twintwig")
    rads_bytes = r.stats["bytes_fetch"] + r.stats["bytes_verify"]
    assert rads_bytes < tt.bytes_shuffled
