"""Device-resident foreign-adjacency cache (core/cache.py AdjCache):

* unit-level hit/miss bookkeeping and the benefit-based admission /
  eviction order (frequency × row size, aging on rejected candidates),
* cache-on == cache-off == oracle across exchange backends and storage
  formats, with the exact conservation law
  ``bytes_fetch(on) + bytes_saved_cache == bytes_fetch(off)``,
* hit-rate > 0 (and ``bytes_fetch`` strictly smaller) on a power-law
  graph driven through repeated region-group waves,
* the acceptance bar: >= 25% fetchV wire-byte reduction on the
  n=4096 / avg_deg=8 power-law graph with >= 2 distributed waves,
* cache state surviving capacity-escalation re-jits, sync == async
  counts, and the EngineConfig knob validation.

(spmd parity for the cache runs in the slow multi-device subprocess
suite, test_multidevice.py.)
"""
import dataclasses

import numpy as np
import jax.numpy as jnp
import pytest

from repro.configs.rads import QUERIES, EngineConfig
from repro.core import (Pattern, canonicalize, enumerate_oracle,
                        rads_enumerate)
from repro.core.cache import AdjCache, probe_dev
from repro.graph import partition, powerlaw_graph

# hash partition + enable_sme=False is the communication-heavy setting:
# every seed is distributed and ~3/4 of pivots are foreign.  Small caps
# keep the per-unit stage compiles cheap (the suite's cost is XLA compile
# time, not wave execution).
CFG = EngineConfig(frontier_cap=1 << 11, fetch_cap=256, verify_cap=1024,
                   region_group_budget=192, enable_sme=False,
                   cache_slots=512)


@pytest.fixture(scope="module")
def skewed():
    g = powerlaw_graph(192, 8, seed=2)
    return g, partition(g, 4, method="hash")


# --------------------------------------------------------------------------- #
# Config knobs
# --------------------------------------------------------------------------- #
def test_config_validates_cache_knobs():
    EngineConfig(cache_slots=1 << 8, cache_ways=1)        # fine
    EngineConfig(cache_decay=16)                          # fine
    with pytest.raises(ValueError, match="power of two"):
        EngineConfig(cache_slots=100)
    with pytest.raises(ValueError, match="power of two"):
        EngineConfig(cache_slots=0)
    with pytest.raises(ValueError, match="cache_ways"):
        EngineConfig(cache_ways=0)
    with pytest.raises(ValueError, match="cache_decay"):
        EngineConfig(cache_decay=-1)


def test_config_validates_comm_and_budget_knobs():
    EngineConfig(comm_pipeline=True, comm_chunks=8)       # fine
    EngineConfig(compile_cache_budget_bytes=1 << 30)      # fine
    with pytest.raises(ValueError, match="comm_pipeline"):
        EngineConfig(comm_pipeline=1)
    with pytest.raises(ValueError, match="comm_chunks"):
        EngineConfig(comm_chunks=0)
    with pytest.raises(ValueError, match="comm_chunks"):
        EngineConfig(comm_chunks=3)
    with pytest.raises(ValueError, match="compile_cache_budget_bytes"):
        EngineConfig(compile_cache_budget_bytes=-1)
    with pytest.raises(ValueError, match="compile_cache_budget_bytes"):
        EngineConfig(compile_cache_budget_bytes=True)


# --------------------------------------------------------------------------- #
# Unit level: probe / admission bookkeeping
# --------------------------------------------------------------------------- #
def _mk(slots=8, ways=2, n=64, width=4, decay=0):
    return AdjCache.build(ndev=1, slots=slots, ways=ways, n=n,
                          line_width=width, decay=decay)


def _rows_for(ids, n, width):
    """Deterministic fake adjacency row for vertex v: [v+1, n, n, ...]."""
    r = np.full((len(ids), width), n, np.int32)
    r[:, 0] = np.asarray(ids) + 1
    return jnp.asarray(r)


def _feed(c, ids, n):
    """One probe+update round over ``ids``; returns (cache', hit mask)."""
    ids = jnp.asarray(np.asarray(ids, np.int32))
    hit, way, crow = probe_dev(c.keys[0], c.rows[0], ids, n)
    rows = jnp.where(hit[:, None], crow, _rows_for(ids, n, c.line_width))
    c = c.updated(ids[None], hit[None], way[None], rows[None])
    return c, np.asarray(hit)


def test_probe_hit_miss_bookkeeping():
    n = 64
    c = _mk(n=n)                               # slots=8: sets 3, 4, 5
    c, hit = _feed(c, [3, 12, 5], n)
    assert not hit.any()                       # cold cache: all misses
    c, hit = _feed(c, [3, 12, 7], n)
    assert list(hit) == [True, True, False]    # admitted lines now hit
    # a hit returns the exact payload row inserted for that id
    h, _, row = probe_dev(c.keys[0], c.rows[0], jnp.asarray([12]), n)
    assert bool(h[0]) and int(row[0, 0]) == 13
    # sentinel ids never hit (and never insert)
    c, hit = _feed(c, [n], n)
    assert not hit.any()
    h, _, _ = probe_dev(c.keys[0], c.rows[0], jnp.asarray([n]), n)
    assert not bool(h[0])


def test_one_insert_per_set_per_batch():
    """Candidates of one set all pick the same (pre-update argmin) victim
    way, so a single batch admits at most one of them — the smallest id on
    equal benefit; the loser lands on a later batch via the empty way."""
    n = 64
    c = _mk(slots=8, ways=2, n=n)
    c, _ = _feed(c, [3, 11], n)                # same set (3 % 8 == 11 % 8)
    hit, _, _ = probe_dev(c.keys[0], c.rows[0], jnp.asarray([3, 11]), n)
    assert list(np.asarray(hit)) == [True, False]
    c, _ = _feed(c, [11], n)                   # retry fills the empty way
    hit, _, _ = probe_dev(c.keys[0], c.rows[0], jnp.asarray([3, 11]), n)
    assert list(np.asarray(hit)) == [True, True]


def test_set_associativity_and_direct_mapped():
    n = 64
    # ways=2: two ids in the same set (8 apart with slots=8) coexist
    c = _mk(slots=8, ways=2, n=n)
    c, _ = _feed(c, [1], n)
    c, _ = _feed(c, [9], n)
    c, hit = _feed(c, [1, 9], n)
    assert hit.all()
    # ways=1 degenerates to direct-mapped: the second id evicts the first
    c1 = _mk(slots=8, ways=1, n=n)
    c1, _ = _feed(c1, [1], n)
    c1, _ = _feed(c1, [9], n)
    hit9, _, _ = probe_dev(c1.keys[0], c1.rows[0], jnp.asarray([9]), n)
    hit1, _, _ = probe_dev(c1.keys[0], c1.rows[0], jnp.asarray([1]), n)
    assert bool(hit9[0]) and not bool(hit1[0])


def test_benefit_eviction_prefers_cold_line():
    """The paper's benefit rule: the frequently-hit line survives, the cold
    one is the victim when a new candidate arrives into a full set."""
    n = 64
    c = _mk(slots=1, ways=2, n=n)              # one set, two lines
    c, _ = _feed(c, [1], n)
    c, _ = _feed(c, [2], n)                    # set now full: {1, 2}
    for _ in range(4):                         # heat line 1
        c, hit = _feed(c, [1], n)
        assert hit.all()
    # new candidates age the cold victim (line 2) until one is admitted
    for cand in (3, 4):
        c, _ = _feed(c, [cand], n)
    keys = set(int(k) for k in np.asarray(c.keys).ravel())
    assert 1 in keys                           # hot line survived
    assert 2 not in keys                       # cold line was evicted


def test_benefit_prefers_large_rows():
    """Size is part of the benefit score: a long-row candidate is admitted
    over a short-row resident, not vice versa."""
    n, width = 64, 8
    c = AdjCache.build(ndev=1, slots=1, ways=1, n=n, line_width=width)
    short = np.full((1, width), n, np.int32)
    short[0, 0] = 9                            # deg 1 => benefit 2
    long_ = np.full((1, width), n, np.int32)
    long_[0, :] = np.arange(width)             # deg 8 => benefit 9
    ids = jnp.asarray([5], jnp.int32)
    no_hit = jnp.zeros((1, 1), bool)
    way0 = jnp.zeros((1, 1), jnp.int32)
    c = c.updated(ids[None], no_hit, way0, jnp.asarray(short)[None])
    c = c.updated(jnp.asarray([7], jnp.int32)[None], no_hit, way0,
                  jnp.asarray(long_)[None])
    assert int(c.keys[0, 0, 0]) == 7           # big row won the contest


def test_benefit_decay_schedule():
    """cache_decay halves live benefit counters every N update batches:
    the tick advances per batch, the halving hits exactly on the period,
    and empty ways keep their sentinel benefit (they must always lose)."""
    n = 64
    c = _mk(slots=1, ways=2, n=n, decay=2)
    c, _ = _feed(c, [1], n)                    # batch 1: insert, benefit 2
    assert int(c.tick[0]) == 1
    b1 = int(np.asarray(c.benefit)[0, 0, 0])
    empty_b = int(np.asarray(c.benefit)[0, 0, 1])
    for _ in range(3):                         # heat line 1
        c, hit = _feed(c, [1], n)
        assert hit.all()
    # batches 2 and 4 fired the decay (bump first, then halve): without it
    # benefit would be 2 + 3*2 = 8; with it (2+2)>>1 = 2, +2 = 4,
    # (4+2)>>1 = 3
    assert int(c.tick[0]) == 4
    assert int(np.asarray(c.benefit)[0, 0, 0]) == 3
    assert b1 == 2
    # the empty way never decays toward a winnable benefit
    assert int(np.asarray(c.benefit)[0, 0, 1]) == empty_b


def test_decay_unpins_stale_hot_line():
    """A line heated in an early phase loses its accumulated benefit under
    decay and is evicted by a fresh candidate; without decay the identical
    access pattern leaves it pinned."""
    n = 64

    def run(decay):
        c = AdjCache.build(ndev=1, slots=1, ways=1, n=n, line_width=4,
                           decay=decay)
        c, _ = _feed(c, [1], n)
        for _ in range(8):                     # phase 1: line 1 is hot
            c, _ = _feed(c, [1], n)
        for _ in range(6):                     # phase 2: line 1 goes stale
            c, _ = _feed(c, [2], n)            # fresh candidate, benefit 2
        return set(int(k) for k in np.asarray(c.keys).ravel() if k < n)

    assert run(decay=0) == {1}                 # pinned forever
    assert run(decay=1) == {2}                 # decayed out, fresh line in


def test_decay_engine_parity(skewed):
    """cache_decay > 0 changes wire traffic at most, never results."""
    g, pg = skewed
    pat = Pattern.from_edges(QUERIES["q1"])
    oracle = canonicalize(enumerate_oracle(g, pat), pat)
    cfg = dataclasses.replace(CFG, cache_decay=2)
    res = rads_enumerate(pg, pat, cfg, mode="sim")
    assert canonicalize(res.embeddings, pat) == oracle
    assert res.stats["cache_probes"] > 0


# --------------------------------------------------------------------------- #
# Engine level: parity, accounting, hit rates
# --------------------------------------------------------------------------- #
def test_cache_on_off_oracle_parity_matrix(skewed):
    """cache-on == cache-off == oracle for sim and gather across both
    storage formats, with the exact byte conservation law and identical
    accounting across backends/formats (spmd runs in the slow suite)."""
    g, pg = skewed
    pat = Pattern.from_edges(QUERIES["q1"])
    oracle = canonicalize(enumerate_oracle(g, pat), pat)
    on_key = off_key = None
    for fmt in ("dense", "bucketed"):
        for mode in ("sim", "gather"):
            cfg = dataclasses.replace(CFG, storage_format=fmt)
            on = rads_enumerate(pg, pat, cfg, mode=mode)
            off = rads_enumerate(
                pg, pat, dataclasses.replace(cfg, enable_cache=False),
                mode=mode)
            assert canonicalize(on.embeddings, pat) == oracle, (fmt, mode)
            assert canonicalize(off.embeddings, pat) == oracle, (fmt, mode)
            assert on.count == off.count
            # conservation: what the cache saved is exactly what the
            # uncached engine puts on the wire
            assert (on.stats["bytes_fetch"] + on.stats["bytes_saved_cache"]
                    == off.stats["bytes_fetch"]), (fmt, mode)
            assert not off.stats["cache_enabled"]
            assert off.stats["bytes_saved_cache"] == 0.0
            assert on.stats["cache_probes"] > 0
            # deterministic across backends and formats (identical wave
            # schedule => identical cache state sequence)
            k_on = (on.count, on.stats["bytes_fetch"],
                    on.stats["cache_hits"], on.stats["cache_probes"])
            k_off = (off.count, off.stats["bytes_fetch"])
            on_key = on_key or k_on
            off_key = off_key or k_off
            assert k_on == on_key, (fmt, mode)
            assert k_off == off_key, (fmt, mode)


def test_multiround_hits_and_escalation_survival():
    """The multi-unit q3 workload refetches pivots across rounds, waves,
    and overflow retries: the cache must produce hits and a *strictly*
    smaller ``bytes_fetch``, stay oracle-exact through the capacity
    escalations this tiny config forces (the cache pytree threads through
    every re-jit), and honour the byte conservation law."""
    g = powerlaw_graph(128, 6, seed=2)
    pg = partition(g, 4, method="hash")
    pat = Pattern.from_edges(QUERIES["q3"])
    oracle = canonicalize(enumerate_oracle(g, pat), pat)
    cfg = EngineConfig(frontier_cap=512, fetch_cap=128, verify_cap=512,
                       region_group_budget=256, enable_sme=False,
                       cache_slots=256)
    on = rads_enumerate(pg, pat, cfg, mode="sim")
    off = rads_enumerate(pg, pat,
                         dataclasses.replace(cfg, enable_cache=False),
                         mode="sim")
    assert canonicalize(on.embeddings, pat) == oracle
    assert canonicalize(off.embeddings, pat) == oracle
    assert on.count == off.count
    st = on.stats
    assert st["n_waves"] >= 2
    assert st["cap_escalations"] >= 1          # cache crossed >= 1 re-jit
    assert st["cache_probes"] > 0
    assert st["cache_hits"] > 0
    assert 0.0 < st["cache_hit_rate"] <= 1.0
    assert st["bytes_saved_cache"] > 0.0
    assert st["cache_enabled"] and st["cache_bytes"] > 0
    assert st["bytes_fetch"] < off.stats["bytes_fetch"]
    assert (st["bytes_fetch"] + st["bytes_saved_cache"]
            == off.stats["bytes_fetch"])


def test_bytes_fetch_compressed_accounting(skewed):
    """The modeled delta+varint id coding never exceeds the raw 4B/id
    accounting and is reported for cache-on and cache-off alike."""
    g, pg = skewed
    pat = Pattern.from_edges(QUERIES["q1"])
    for cache_on in (True, False):
        cfg = dataclasses.replace(CFG, enable_cache=cache_on)
        res = rads_enumerate(pg, pat, cfg, mode="sim")
        assert res.stats["bytes_fetch_compressed"] > 0.0
        assert (res.stats["bytes_fetch_compressed"]
                <= res.stats["bytes_fetch"])


def test_sync_equals_async_with_cache(skewed):
    """Counts and embeddings are cache-invariant under any pipeline depth
    (wire traffic is schedule-dependent by design — a warmer cache serves
    more hits — but results never are)."""
    g, pg = skewed
    pat = Pattern.from_edges(QUERIES["q1"])
    sync = rads_enumerate(pg, pat,
                          dataclasses.replace(CFG, pipeline_depth=1),
                          mode="sim")
    anc = rads_enumerate(pg, pat, CFG, mode="sim")
    assert sync.count == anc.count
    assert canonicalize(sync.embeddings, pat) == canonicalize(
        anc.embeddings, pat)


def test_direct_mapped_engine_parity(skewed):
    """ways=1 (the degenerate direct-mapped cache) stays oracle-exact."""
    g, pg = skewed
    pat = Pattern.from_edges(QUERIES["q1"])
    oracle = canonicalize(enumerate_oracle(g, pat), pat)
    cfg = dataclasses.replace(CFG, cache_ways=1, cache_slots=128)
    res = rads_enumerate(pg, pat, cfg, mode="sim")
    assert canonicalize(res.embeddings, pat) == oracle


@pytest.mark.slow
def test_acceptance_powerlaw_4096_bytes_drop():
    """Acceptance bar: on the n=4096 / avg_deg=8 power-law graph with
    >= 2 distributed region-group waves, enabling the cache cuts
    ``bytes_fetch`` by >= 25% while counts stay identical (and equal to an
    independent triangle count)."""
    g = powerlaw_graph(4096, 8, seed=1)
    pg = partition(g, 4, method="hash")      # worst-case communication
    pat = Pattern.from_edges(QUERIES["q1"])
    cfg = EngineConfig(frontier_cap=1 << 14, fetch_cap=1 << 12,
                       verify_cap=1 << 13, region_group_budget=1 << 12,
                       enable_sme=False)
    on = rads_enumerate(pg, pat, cfg, mode="sim", return_embeddings=False)
    off = rads_enumerate(pg, pat,
                         dataclasses.replace(cfg, enable_cache=False),
                         mode="sim", return_embeddings=False)
    assert on.stats["n_waves"] >= 2
    assert on.count == off.count
    # independent triangle count: sum over edges of |N(u) cap N(v)| / 3
    tri = 0
    for v in range(g.n):
        nv = g.neighbors(v)
        for w in nv[nv > v]:
            tri += np.intersect1d(nv, g.neighbors(w)).size
    assert on.count == tri // 3
    assert off.stats["bytes_fetch"] > 0
    saved = 1.0 - on.stats["bytes_fetch"] / off.stats["bytes_fetch"]
    assert saved >= 0.25, (on.stats["bytes_fetch"],
                           off.stats["bytes_fetch"])
