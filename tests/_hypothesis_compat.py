"""Minimal deterministic stand-in for ``hypothesis`` (vendored fallback).

The container this suite must run in has no network, so ``pip install
hypothesis`` is not an option; without this module 6 of 10 test modules
die at collection.  Affected modules import via:

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypothesis_compat import given, settings, strategies as st

so the real package wins whenever it is installed.

Scope (deliberately small): the strategy combinators this repo's tests
use — ``integers``, ``booleans``, ``floats``, ``lists``, ``tuples``,
``sampled_from``, ``composite``, ``data`` — plus ``@given`` and
``@settings``.  Sampling is a fixed-seed PRNG keyed on the test's
qualified name: runs are bit-reproducible across processes and machines
(no shrinking, no example database, no deadlines).  The per-test example
count is ``min(settings.max_examples, HYPOTHESIS_COMPAT_MAX_EXAMPLES)``
(env var, default 20) to keep the fallback fast in tier-1.
"""
from __future__ import annotations

import functools
import inspect
import os
import random
import zlib

_MAX_EXAMPLES_CAP = int(os.environ.get("HYPOTHESIS_COMPAT_MAX_EXAMPLES", "20"))
_DEFAULT_MAX_EXAMPLES = 20


# --------------------------------------------------------------------------- #
# Strategies
# --------------------------------------------------------------------------- #
class SearchStrategy:
    """A strategy is just a draw function over a ``random.Random``."""

    def __init__(self, draw_fn, name: str = "strategy"):
        self._draw_fn = draw_fn
        self._name = name

    def example_from(self, rng: random.Random):
        return self._draw_fn(rng)

    def __repr__(self):
        return self._name


class DataObject:
    """Handed out by ``st.data()``: interactive draws inside the test body."""

    def __init__(self, rng: random.Random):
        self._rng = rng

    def draw(self, strategy: SearchStrategy, label: str | None = None):
        return strategy.example_from(self._rng)


class _Strategies:
    """The ``strategies`` / ``st`` namespace."""

    SearchStrategy = SearchStrategy

    @staticmethod
    def integers(min_value: int, max_value: int) -> SearchStrategy:
        def draw(rng):
            # bias toward the boundaries, where off-by-ones live
            r = rng.random()
            if r < 0.05:
                return min_value
            if r < 0.10:
                return max_value
            return rng.randint(min_value, max_value)
        return SearchStrategy(draw, f"integers({min_value}, {max_value})")

    @staticmethod
    def booleans() -> SearchStrategy:
        return SearchStrategy(lambda rng: rng.random() < 0.5, "booleans()")

    @staticmethod
    def floats(min_value: float, max_value: float, *, allow_nan: bool = False,
               allow_infinity: bool = False) -> SearchStrategy:
        def draw(rng):
            r = rng.random()
            if r < 0.05:
                return float(min_value)
            if r < 0.10:
                return float(max_value)
            if r < 0.15 and min_value <= 0.0 <= max_value:
                return 0.0
            return rng.uniform(min_value, max_value)
        return SearchStrategy(draw, f"floats({min_value}, {max_value})")

    @staticmethod
    def lists(elements: SearchStrategy, *, min_size: int = 0,
              max_size: int = 10) -> SearchStrategy:
        def draw(rng):
            size = rng.randint(min_size, max_size)
            return [elements.example_from(rng) for _ in range(size)]
        return SearchStrategy(draw, f"lists({elements!r})")

    @staticmethod
    def tuples(*elems: SearchStrategy) -> SearchStrategy:
        return SearchStrategy(
            lambda rng: tuple(e.example_from(rng) for e in elems),
            f"tuples(<{len(elems)}>)")

    @staticmethod
    def sampled_from(seq) -> SearchStrategy:
        seq = list(seq)
        if not seq:
            raise ValueError("sampled_from requires a non-empty sequence")
        return SearchStrategy(lambda rng: seq[rng.randrange(len(seq))],
                              f"sampled_from(<{len(seq)}>)")

    @staticmethod
    def composite(fn):
        """``@st.composite``: ``fn(draw, *args)`` -> strategy factory."""
        @functools.wraps(fn)
        def factory(*args, **kwargs):
            def draw_fn(rng):
                return fn(lambda s: s.example_from(rng), *args, **kwargs)
            return SearchStrategy(draw_fn, fn.__name__)
        return factory

    @staticmethod
    def data() -> SearchStrategy:
        return SearchStrategy(lambda rng: DataObject(rng), "data()")


strategies = _Strategies()
st = strategies


# --------------------------------------------------------------------------- #
# settings / given
# --------------------------------------------------------------------------- #
class settings:
    """Records ``max_examples``; everything else is accepted and ignored."""

    def __init__(self, max_examples: int = _DEFAULT_MAX_EXAMPLES,
                 deadline=None, **_ignored):
        self.max_examples = max_examples

    def __call__(self, fn):
        # compose in either decorator order with @given
        target = getattr(fn, "__wrapped__", fn) if getattr(
            fn, "_hc_is_given_runner", False) else fn
        target._hc_settings = self
        return fn


def given(*arg_strategies: SearchStrategy, **kw_strategies: SearchStrategy):
    """Run the test once per drawn example, deterministically.

    The PRNG seed is ``crc32(test qualname)``, so a failing example
    reproduces with a bare re-run and is stable across machines."""
    def deco(fn):
        # positional strategies fill the test's *rightmost* parameters (the
        # real hypothesis does the same, leaving leading fixtures to pytest)
        n_given = len(arg_strategies)
        params = list(inspect.signature(fn).parameters.values())
        given_names = [p.name for p in params[-n_given:]] if n_given else []
        remaining = params[:-n_given] if n_given else params
        remaining = [p for p in remaining if p.name not in kw_strategies]

        @functools.wraps(fn)
        def runner(*args, **kwargs):
            s = getattr(fn, "_hc_settings", None) or settings()
            seed = zlib.crc32(fn.__qualname__.encode())
            rng = random.Random(seed)
            n = max(1, min(s.max_examples, _MAX_EXAMPLES_CAP))
            for i in range(n):
                drawn = {name: strat.example_from(rng)
                         for name, strat in zip(given_names, arg_strategies)}
                drawn.update((k, v.example_from(rng))
                             for k, v in kw_strategies.items())
                try:
                    fn(*args, **drawn, **kwargs)
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example #{i} (fixed seed {seed}) for "
                        f"{fn.__qualname__}: {drawn!r}") from e
        # hide the strategy-filled parameters from pytest's fixture resolution
        runner.__signature__ = inspect.Signature(remaining)
        runner._hc_is_given_runner = True
        runner.__wrapped__ = fn
        return runner
    return deco
