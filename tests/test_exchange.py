"""Static-shape primitives + the sim-mode exchange semantics."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.exchange import (Exchange, compact, membership, unique_ids,
                                 unique_pairs)


@given(st.lists(st.booleans(), min_size=1, max_size=40),
       st.integers(1, 12))
@settings(max_examples=50, deadline=None)
def test_property_compact(mask_list, cap):
    mask = jnp.array(mask_list)
    arr = jnp.arange(len(mask_list)) * 7
    nm, ov, out = compact(mask, cap, arr, fill=-1)
    want = [int(a) for a, m in zip(arr, mask_list) if m][:cap]
    got = [int(x) for x, m in zip(out, nm) if m]
    assert got == want
    assert bool(ov) == (sum(mask_list) > cap)


@given(st.lists(st.integers(0, 50), min_size=1, max_size=40))
@settings(max_examples=50, deadline=None)
def test_property_unique_ids(ids_list):
    ids = jnp.array(ids_list)
    mask = ids < 40
    uids, umask = unique_ids(ids, mask, sentinel=99)
    want = sorted({i for i in ids_list if i < 40})
    got = [int(x) for x, m in zip(uids, umask) if m]
    assert got == want


@given(st.lists(st.tuples(st.integers(0, 8), st.integers(0, 8),
                          st.booleans()), min_size=1, max_size=30))
@settings(max_examples=50, deadline=None)
def test_property_unique_pairs(items):
    a = jnp.array([x[0] for x in items])
    b = jnp.array([x[1] for x in items])
    m = jnp.array([x[2] for x in items])
    ua, ub, um, rank = unique_pairs(a, b, m, sentinel=9)
    want = sorted({(int(x), int(y)) for x, y, keep in items if keep})
    got = [(int(x), int(y)) for x, y, mm in zip(ua, ub, um) if mm]
    assert got == want
    # every masked input pair's rank points at its own pair
    for i, (x, y, keep) in enumerate(items):
        if keep:
            r = int(rank[i])
            assert (int(ua[r]), int(ub[r])) == (x, y)


def test_membership_matches_searchsorted():
    rng = np.random.default_rng(0)
    rows = np.sort(rng.integers(0, 100, (23, 17)), axis=1)
    vals = rng.integers(0, 100, (23, 5))
    got = membership(jnp.asarray(rows), jnp.asarray(vals))
    want = np.array([[v in set(r) for v in vv] for r, vv in zip(rows, vals)])
    assert np.array_equal(np.asarray(got), want)


def test_sim_a2a_is_transpose_involution():
    ex = Exchange("sim")
    x = jnp.arange(3 * 3 * 4).reshape(3, 3, 4)
    y = ex.a2a(x)
    assert jnp.array_equal(ex.a2a(y), x)
    # out[t, s] == x[s, t]
    for t in range(3):
        for s in range(3):
            assert jnp.array_equal(y[t, s], x[s, t])
