"""Static-shape primitives + exchange-backend semantics and registry."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # hermetic container: vendored fallback
    from _hypothesis_compat import given, settings, strategies as st

from repro.core.exchange import (Exchange, ExchangeBackend, compact,
                                 exchange_backends, membership,
                                 register_exchange_backend, unique_ids,
                                 unique_pairs)


@given(st.lists(st.booleans(), min_size=1, max_size=40),
       st.integers(1, 12))
@settings(max_examples=50, deadline=None)
def test_property_compact(mask_list, cap):
    mask = jnp.array(mask_list)
    arr = jnp.arange(len(mask_list)) * 7
    nm, ov, out = compact(mask, cap, arr, fill=-1)
    want = [int(a) for a, m in zip(arr, mask_list) if m][:cap]
    got = [int(x) for x, m in zip(out, nm) if m]
    assert got == want
    assert bool(ov) == (sum(mask_list) > cap)


@given(st.lists(st.integers(0, 50), min_size=1, max_size=40))
@settings(max_examples=50, deadline=None)
def test_property_unique_ids(ids_list):
    ids = jnp.array(ids_list)
    mask = ids < 40
    uids, umask = unique_ids(ids, mask, sentinel=99)
    want = sorted({i for i in ids_list if i < 40})
    got = [int(x) for x, m in zip(uids, umask) if m]
    assert got == want


@given(st.lists(st.tuples(st.integers(0, 8), st.integers(0, 8),
                          st.booleans()), min_size=1, max_size=30))
@settings(max_examples=50, deadline=None)
def test_property_unique_pairs(items):
    a = jnp.array([x[0] for x in items])
    b = jnp.array([x[1] for x in items])
    m = jnp.array([x[2] for x in items])
    ua, ub, um, rank = unique_pairs(a, b, m, sentinel=9)
    want = sorted({(int(x), int(y)) for x, y, keep in items if keep})
    got = [(int(x), int(y)) for x, y, mm in zip(ua, ub, um) if mm]
    assert got == want
    # every masked input pair's rank points at its own pair
    for i, (x, y, keep) in enumerate(items):
        if keep:
            r = int(rank[i])
            assert (int(ua[r]), int(ub[r])) == (x, y)


def test_membership_matches_searchsorted():
    rng = np.random.default_rng(0)
    rows = np.sort(rng.integers(0, 100, (23, 17)), axis=1)
    vals = rng.integers(0, 100, (23, 5))
    got = membership(jnp.asarray(rows), jnp.asarray(vals))
    want = np.array([[v in set(r) for v in vv] for r, vv in zip(rows, vals)])
    assert np.array_equal(np.asarray(got), want)


def test_sim_a2a_is_transpose_involution():
    ex = Exchange("sim")
    x = jnp.arange(3 * 3 * 4).reshape(3, 3, 4)
    y = ex.a2a(x)
    assert jnp.array_equal(ex.a2a(y), x)
    # out[t, s] == x[s, t]
    for t in range(3):
        for s in range(3):
            assert jnp.array_equal(y[t, s], x[s, t])


def test_unique_pairs_rank_duplicate_heavy():
    """Regression for the dead-code cleanup in unique_pairs: rank[i] must
    index the unique slot holding input pair i even when almost every pair
    is a duplicate."""
    a = jnp.array([3, 3, 1, 3, 1, 7, 3, 1, 3, 3])
    b = jnp.array([0, 0, 2, 0, 2, 7, 0, 2, 0, 0])
    m = jnp.ones(10, bool)
    ua, ub, um, rank = unique_pairs(a, b, m, sentinel=9)
    got = [(int(x), int(y)) for x, y, mm in zip(ua, ub, um) if mm]
    assert got == [(1, 2), (3, 0), (7, 7)]
    for i in range(10):
        r = int(rank[i])
        assert (int(ua[r]), int(ub[r])) == (int(a[i]), int(b[i]))


def test_unique_pairs_all_masked():
    """All-masked input: no uniques, every output slot is sentinel, and rank
    stays a safe index (the engine gathers through it before masking)."""
    n = 8
    a = jnp.arange(n)
    b = jnp.arange(n)[::-1]
    m = jnp.zeros(n, bool)
    ua, ub, um, rank = unique_pairs(a, b, m, sentinel=50)
    assert int(um.sum()) == 0
    assert jnp.all(ua == 50) and jnp.all(ub == 50)
    assert jnp.all((rank >= 0) & (rank < n))


def test_exchange_registry_and_unknown_mode():
    assert {"sim", "spmd", "gather", "dist"} <= set(exchange_backends())
    with pytest.raises(ValueError, match="unknown exchange mode"):
        Exchange("no-such-backend")
    with pytest.raises(ValueError, match="needs a mesh"):
        Exchange("spmd")
    with pytest.raises(ValueError, match="needs a mesh"):
        Exchange("dist")
    with pytest.raises(ValueError, match="comm_chunks"):
        Exchange("sim", comm_chunks=0)


def test_comm_chunked_a2a_bit_identical():
    """comm_chunks > 1 splits the exchange along the per-peer capacity axis
    (axis 2) into back-to-back sub-exchanges; the concatenated result must
    be bit-identical to the one-shot transpose, and shapes that cannot be
    split evenly (or 2-D length matrices) fall back to one shot."""
    x = jnp.arange(4 * 4 * 8 * 3, dtype=jnp.int32).reshape(4, 4, 8, 3)
    base = Exchange("sim").a2a(x)
    for mode in ("sim", "gather"):
        for c in (2, 4, 8):
            assert jnp.array_equal(Exchange(mode, comm_chunks=c).a2a(x),
                                   base)
    chunky = Exchange("sim", comm_chunks=4)
    y = jnp.arange(4 * 4 * 7, dtype=jnp.int32).reshape(4, 4, 7)
    assert jnp.array_equal(chunky.a2a(y), Exchange("sim").a2a(y))
    m = jnp.arange(16.0).reshape(4, 4)
    assert jnp.array_equal(chunky.a2a(m), Exchange("sim").a2a(m))
    # involution survives chunking
    assert jnp.array_equal(chunky.a2a(chunky.a2a(x)), x)


def test_per_dev_sent_bytes_sums_to_scalar_accounting():
    """Row sums of the diagonal-masked byte matrix: summing the per-device
    vector recovers off_device_payload_bytes exactly (the invariant the
    scalability harness's skew gates rely on)."""
    bm = jnp.array([[5., 2., 1.], [3., 7., 0.], [4., 4., 4.]]) * 9.0
    for mode in ("sim", "gather"):
        ex = Exchange(mode)
        dev = ex.per_dev_sent_bytes(bm)
        assert dev.shape == (3,)
        assert dev.dtype == jnp.float32
        assert float(dev.sum()) == float(ex.off_device_payload_bytes(bm))
        assert [float(v) for v in dev] == [27.0, 27.0, 72.0]


def test_register_custom_backend():
    from repro.core import exchange as exchange_mod

    @register_exchange_backend("_test_double")
    class DoubleBytes(ExchangeBackend):
        def a2a(self, x):
            return jnp.swapaxes(x, 0, 1)

        def off_device_bytes(self, counts, elem_bytes):
            return super().off_device_bytes(counts, 2 * elem_bytes)

    try:
        ex = Exchange("_test_double")
        counts = jnp.array([[5, 2], [3, 7]])
        assert float(ex.off_device_bytes(counts, 4)) == 2 * (2 + 3) * 4
        assert ex.mode == "_test_double"
    finally:
        exchange_mod._BACKENDS.pop("_test_double", None)
    assert "_test_double" not in exchange_backends()


def test_gather_backend_matches_sim():
    sim, ga = Exchange("sim"), Exchange("gather")
    x = jnp.arange(4 * 4 * 3, dtype=jnp.float32).reshape(4, 4, 3)
    assert jnp.array_equal(sim.a2a(x), ga.a2a(x))
    assert jnp.array_equal(sim.all_reduce_sum(x), ga.all_reduce_sum(x))
    # a2a is an involution on both
    assert jnp.array_equal(ga.a2a(ga.a2a(x)), x)


def test_off_device_bytes_comparable_across_backends():
    """The diagonal (self-traffic) is free; off-diagonal entries cost
    elem_bytes each — identically on every built-in backend, so
    bytes_fetch/bytes_verify stats are comparable when swapping modes."""
    counts = jnp.array([[5, 2, 1], [3, 7, 0], [4, 4, 4]])
    want = (2 + 1 + 3 + 0 + 4 + 4) * 9.0
    from repro.launch.mesh import make_engine_mesh
    backends = [Exchange("sim"), Exchange("gather"),
                Exchange("spmd", mesh=make_engine_mesh(1))]
    for ex in backends:
        assert float(ex.off_device_bytes(counts, 9)) == want


def test_off_device_payload_bytes_and_varint_model():
    """Variable-size payload accounting (the modeled delta+varint fetchV id
    coding): the diagonal stays free and the per-peer byte matrix is summed
    as-is; the varint model sizes sorted-with-holes id streams correctly."""
    from repro.core.engine import _varint_id_bytes

    bm = jnp.array([[10.0, 3.0], [4.0, 20.0]])
    assert float(Exchange("sim").off_device_payload_bytes(bm)) == 3.0 + 4.0
    # one stream: first id absolute (200 -> 2 bytes), then deltas 1 and
    # 16000 (1 and 2 bytes); sentinel holes (n=10**6) contribute nothing
    n = 10 ** 6
    wire = jnp.array([[[200, 201, n, 16201, n]]], dtype=jnp.int32)
    got = _varint_id_bytes(wire, n)
    assert got.shape == (1, 1)
    assert int(got[0, 0]) == 2 + 1 + 2
