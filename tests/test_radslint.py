"""radslint self-tests: every planted fixture violation is caught, every
known-good twin passes, and src/repro itself is clean modulo the committed
baseline (the zero-findings ratchet CI enforces)."""
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]
if str(REPO) not in sys.path:
    sys.path.insert(0, str(REPO))

from tools.radslint.api import lint_project, load_default_config  # noqa: E402
from tools.radslint.config import Config, read_toml_section  # noqa: E402

FIX = "tests/radslint_fixtures"


def run_fixture(sub: str, **overrides):
    cfg = Config(project_root=REPO, roots=[f"{FIX}/{sub}"],
                 import_roots=[FIX],
                 baseline=f"{FIX}/_no_such_baseline.json", **overrides)
    return lint_project(cfg, use_baseline=False)


def in_file(findings, name, checker=None):
    return [f for f in findings if f.file.endswith(name)
            and (checker is None or f.checker == checker)]


# --------------------------------------------------------------------------- #
# RL001 — host sync / tracer leak
# --------------------------------------------------------------------------- #
def test_rl001_bad_fixture_caught():
    res = run_fixture("rl001", hot_loops=["rl001.bad.wave_loop",
                                          "rl001.good.wave_loop"],
                      hot_traced_calls=["fetch"])
    bad = in_file(res.findings, "rl001/bad.py", "RL001")
    msgs = " | ".join(f.message for f in bad)
    assert "`if` branches on a traced value" in msgs
    assert "`int()` on a traced value" in msgs
    assert "`.item()`" in msgs
    assert "`np.asarray`" in msgs
    assert "`for` iterates a traced value" in msgs
    assert "`bool()` on a traced value" in msgs      # the hot-loop finding
    assert len(bad) >= 6


def test_rl001_good_twin_clean():
    res = run_fixture("rl001", hot_loops=["rl001.good.wave_loop"],
                      hot_traced_calls=["fetch"])
    assert not in_file(res.findings, "rl001/good.py")


# --------------------------------------------------------------------------- #
# RL002 — recompile triggers
# --------------------------------------------------------------------------- #
def test_rl002_bad_fixture_caught():
    res = run_fixture("rl002")
    bad = in_file(res.findings, "rl002/bad.py", "RL002")
    msgs = " | ".join(f.message for f in bad)
    assert "without static_argnames" in msgs
    assert "closes over mutable `LUT`" in msgs
    assert "off the power-of-2 escalation ladder" in msgs
    assert len(bad) >= 3


def test_rl002_good_twin_clean():
    res = run_fixture("rl002")
    assert not in_file(res.findings, "rl002/good.py")


# --------------------------------------------------------------------------- #
# RL003 — determinism
# --------------------------------------------------------------------------- #
def test_rl003_bad_fixture_caught():
    res = run_fixture("rl003")
    bad = in_file(res.findings, "rl003/bad.py", "RL003")
    msgs = " | ".join(f.message for f in bad)
    assert "jnp.unique without size=" in msgs
    assert ".at[].add scatter" in msgs
    assert "set/dict iteration order" in msgs
    assert "iteration order of a set/dict" in msgs
    assert len(bad) >= 4


def test_rl003_good_twin_clean():
    res = run_fixture("rl003")
    assert not in_file(res.findings, "rl003/good.py")


# --------------------------------------------------------------------------- #
# RL004 — stat threading
# --------------------------------------------------------------------------- #
def test_rl004_dropped_stat_caught():
    res = run_fixture(
        "rl004",
        stat_state="rl004.state.WaveState",
        stat_finalizer="rl004.state.finalize",
        stat_consumers=[f"{FIX}/rl004/consumer.py"])
    bad = in_file(res.findings, "rl004/state.py", "RL004")
    assert any("bytes_dropped" in f.message and "never reaches" in f.message
               for f in bad)
    assert any("bytes_dropped" in f.message and "not consumed" in f.message
               for f in bad)
    # the threaded fields are clean
    assert not any("bytes_fetch" in f.message or "cache_hits" in f.message
                   for f in bad)


def test_rl004_orphan_metric_instrument_caught():
    res = run_fixture(
        "rl004",
        metric_schema="rl004.metrics_schema",
        metric_consumers=[f"{FIX}/rl004/consumer.py"])
    bad = in_file(res.findings, "rl004/metrics_schema.py", "RL004")
    assert any("`orphan_gauge`" in f.message and "never exported"
               in f.message for f in bad)
    # consumed instruments are clean
    assert not any("`bytes_fetch`" in f.message or "`cache_hits`"
                   in f.message for f in bad)


def test_pyproject_metric_schema_fully_exported():
    """The real tree's declared instruments all reach a consumer (the
    live half of the zero-findings ratchet for the metric extension)."""
    cfg = load_default_config(REPO)
    assert cfg.metric_schema == "repro.obs.schema"
    res = lint_project(cfg, use_baseline=False)
    assert not [f for f in res.findings
                if "metric instrument" in f.message], res.render()


# --------------------------------------------------------------------------- #
# RL005 — dtype hygiene
# --------------------------------------------------------------------------- #
def test_rl005_bad_fixture_caught():
    res = run_fixture("rl005")
    bad = in_file(res.findings, "rl005/bad.py", "RL005")
    msgs = " | ".join(f.message for f in bad)
    assert "'int64'" in msgs
    assert "float64" in msgs
    assert len(bad) >= 3


def test_rl005_good_twin_clean():
    res = run_fixture("rl005")
    assert not in_file(res.findings, "rl005/good.py")


# --------------------------------------------------------------------------- #
# suppression grammar
# --------------------------------------------------------------------------- #
def test_justified_suppression_silences():
    res = run_fixture("suppress")
    assert not in_file(res.findings, "suppress/ok.py")
    assert res.suppressed >= 1


def test_unjustified_suppression_is_rl000_and_does_not_silence():
    res = run_fixture("suppress")
    bad = in_file(res.findings, "suppress/bad.py")
    assert any(f.checker == "RL000" for f in bad)
    assert any(f.checker == "RL003" for f in bad)


# --------------------------------------------------------------------------- #
# the ratchet on the real tree
# --------------------------------------------------------------------------- #
def test_pyproject_config_block_parses():
    raw = read_toml_section(REPO / "pyproject.toml")
    assert raw["roots"] == ["src/repro"]
    assert "repro.core.engine.fetch_stage" in raw["entrypoints"]
    assert raw["ladder_base"] == 2


def test_self_lint_src_repro_clean_modulo_baseline():
    cfg = load_default_config(REPO)
    res = lint_project(cfg)
    assert res.n_reachable > 50, "call graph lost the engine roots"
    assert res.ok, "new radslint findings:\n" + res.render()


def test_engine_config_rejects_off_ladder_caps():
    sys.path.insert(0, str(REPO / "src"))
    from repro.configs.rads import EngineConfig
    with pytest.raises(ValueError, match="power of two"):
        EngineConfig(fetch_cap=1000)
    with pytest.raises(ValueError, match="power of two"):
        EngineConfig(frontier_cap=0)
    EngineConfig(fetch_cap=1 << 10)      # on the ladder: fine
