"""On-the-wire exchange codecs (core/wire.py):

* property-style encode∘decode == identity roundtrips for the delta+varint
  id codec, the degree+delta row codec, the Elias-Fano pair codec and the
  bit-packed bool codec — including sentinel holes, empty lanes and
  max-degree rows — with coded length <= raw length in every case,
* the actual coded fetchV id length matches the PR 4 modeled
  ``_varint_id_bytes`` column exactly (for universes < 2^28),
* the per-lane raw escape fires on incompressible lanes,
* wire='varint' == wire='raw' == oracle across exchange backends, storage
  formats and cache on/off, with identical counts/embeddings and the exact
  per-run identity ``bytes_wire_fetch <= bytes_fetch``,
* escalation survival (stream caps re-jit alongside the engine caps) and
  the Pallas-gated codec path,
* (slow) the acceptance bar: >= 30% verifyE and >= 25% total wire-byte
  reduction on the n=4096 / avg_deg=8 power-law graph.

(spmd wire parity runs in the slow multi-device suite,
test_multidevice.py.)
"""
import dataclasses

import numpy as np
import jax.numpy as jnp
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # hermetic container: vendored fallback
    from _hypothesis_compat import given, settings, strategies as st

from repro.configs.rads import QUERIES, EngineConfig
from repro.core import (Pattern, canonicalize, enumerate_oracle,
                        rads_enumerate)
from repro.core import wire
from repro.core.engine import _varint_id_bytes
from repro.graph import partition, powerlaw_graph

CFG = EngineConfig(frontier_cap=1 << 11, fetch_cap=256, verify_cap=1024,
                   region_group_budget=192, enable_sme=False,
                   cache_slots=512, wire_format="varint")


@pytest.fixture(scope="module")
def skewed():
    g = powerlaw_graph(192, 8, seed=2)
    return g, partition(g, 4, method="hash")


# --------------------------------------------------------------------------- #
# Codec roundtrips (property-style)
# --------------------------------------------------------------------------- #
@given(st.lists(st.integers(0, 10 ** 6 - 1), min_size=0, max_size=48),
       st.lists(st.booleans(), min_size=48, max_size=48),
       st.integers(0, 1))
@settings(max_examples=60, deadline=None)
def test_property_ids_roundtrip(vals, holes, _):
    """Sorted-unique ids at arbitrary hole positions: decode recovers the
    exact id set (compacted), coded bytes <= 4/id, and the actual length
    equals the PR 4 modeled varint column."""
    n = 10 ** 6
    m = 48
    vals = sorted(set(vals))[:m]
    ids = np.full(m, n, np.int32)
    pos = [i for i, h in enumerate(holes) if h][:len(vals)]
    vals = vals[:len(pos)]
    ids[pos] = vals
    s, ln, raw, ov = wire.encode_ids(jnp.asarray(ids), n, 4 * m)
    dec, mask = wire.decode_ids(s, ln, raw, m, n)
    got = [int(x) for x, mm in zip(dec, mask) if mm]
    assert got == vals
    assert int(ln) <= 4 * len(vals)
    assert not bool(ov)
    model = int(_varint_id_bytes(jnp.asarray(ids)[None, None], n)[0, 0])
    assert int(ln) == min(model, 4 * len(vals))


@given(st.integers(0, 12), st.integers(1, 16), st.integers(0, 10))
@settings(max_examples=40, deadline=None)
def test_property_rows_roundtrip(k, D, seed):
    """Adjacency-window lanes: sorted rows of any degree 0..D (including
    max-degree rows and the empty lane) decode bit-identically, with coded
    bytes <= the raw padded 4·D/row."""
    n = 10 ** 5
    m = 12
    rng = np.random.default_rng(seed * 131 + k * 7 + D)
    rows = np.full((m, D), n, np.int32)
    valid = np.zeros(m, bool)
    valid[:k] = True
    for i in range(k):
        d = int(rng.integers(0, D + 1))
        rows[i, :d] = np.sort(rng.choice(n, size=d, replace=False))
    dcap, icap = 2 * m, 4 * D * m
    dg, dl, ids_s, il, raw, ov = wire.encode_rows(
        jnp.asarray(rows), jnp.asarray(valid), n, dcap, icap)
    dec = wire.decode_rows(dg, dl, ids_s, il, raw, m, D, n)
    assert np.array_equal(np.asarray(dec)[:k], rows[:k])
    assert np.all(np.asarray(dec)[k:] == n)
    assert int(dl) + int(il) <= 4 * D * k
    assert not bool(ov)


@given(st.lists(st.tuples(st.integers(0, 400), st.integers(0, 10 ** 5 - 1)),
                min_size=0, max_size=40))
@settings(max_examples=60, deadline=None)
def test_property_pairs_roundtrip(items):
    """verifyE lanes: lexicographically sorted unique (a, b) pairs survive
    the Elias-Fano + run-delta coding exactly, at <= the raw 8 B/pair."""
    n = 10 ** 5
    m = 40
    pairs = sorted(set(items))
    k = len(pairs)
    pa = np.full(m, n, np.int32)
    pb = np.full(m, n, np.int32)
    if k:
        pa[:k] = [p[0] for p in pairs]
        pb[:k] = [p[1] for p in pairs]
    a_s, al, b_s, bl, raw, ov = wire.encode_pairs(
        jnp.asarray(pa), jnp.asarray(pb), n, 4 * m, 4 * m)
    da, db, mask = wire.decode_pairs(a_s, al, b_s, bl, raw, jnp.int32(k),
                                     m, n, n)
    assert np.array_equal(np.asarray(da)[:k], pa[:k])
    assert np.array_equal(np.asarray(db)[:k], pb[:k])
    assert int(mask.sum()) == k
    assert int(al) + int(bl) <= 8 * k
    assert not bool(ov)


@given(st.lists(st.booleans(), min_size=1, max_size=64), st.integers(0, 64))
@settings(max_examples=40, deadline=None)
def test_property_bools_roundtrip(bits, count):
    m = len(bits)
    count = min(count, m)
    s, ln = wire.pack_bools(jnp.asarray(bits), jnp.int32(count),
                            (m + 7) // 8)
    dec = wire.unpack_bools(s, jnp.int32(count), m)
    want = [b if i < count else False for i, b in enumerate(bits)]
    assert [bool(x) for x in dec] == want
    assert int(ln) == (count + 7) // 8


def test_raw_escape_on_incompressible_lane():
    """A lane whose varints would exceed 4 B/id (delta >= 2^28) falls back
    to the raw int32 layout — the `<= raw` guarantee is unconditional."""
    n = 1 << 30
    # both the absolute first id and the delta need 5-byte LEB128 (>= 2^28)
    ids = np.array([(1 << 28) + 1, (1 << 29) + 7], np.int32)
    lane = np.concatenate([ids, np.full(6, n, np.int32)])
    s, ln, raw, ov = wire.encode_ids(jnp.asarray(lane), n, 32)
    assert bool(raw)
    assert int(ln) == 4 * 2
    dec, mask = wire.decode_ids(s, ln, raw, 8, n)
    assert [int(x) for x, m in zip(dec, mask) if m] == list(ids)
    # a single 5-byte delta alone stays coded (6 bytes < raw 8) and decodes
    lane2 = np.concatenate([np.array([5, (1 << 29) + 7], np.int32),
                            np.full(6, n, np.int32)])
    s2, ln2, raw2, _ = wire.encode_ids(jnp.asarray(lane2), n, 32)
    assert not bool(raw2) and int(ln2) == 6
    dec2, mask2 = wire.decode_ids(s2, ln2, raw2, 8, n)
    assert [int(x) for x, m in zip(dec2, mask2) if m] == [5, (1 << 29) + 7]


def test_stream_caps_derive_from_engine_caps():
    """Stream capacities double alongside fetch/verify caps, so a
    StageRunner escalation re-jits the codecs at the wider streams."""
    r1, d1, i1 = wire.fetch_stream_caps(256, 16)
    r2, d2, i2 = wire.fetch_stream_caps(512, 16)
    assert (r2, d2, i2) == (2 * r1, 2 * d1, 2 * i1)
    a1, b1, s1 = wire.verify_stream_caps(1024)
    a2, b2, s2 = wire.verify_stream_caps(2048)
    assert (a2, b2) == (2 * a1, 2 * b1) and s2 == 2 * s1


# --------------------------------------------------------------------------- #
# Engine level: raw == varint == oracle, accounting identities
# --------------------------------------------------------------------------- #
def test_wire_parity_matrix(skewed):
    """wire='varint' == wire='raw' == oracle for sim and gather across both
    storage formats and cache on/off, with identical coded byte accounting
    across backends/formats and the exact identity
    ``bytes_wire_fetch <= bytes_fetch`` (spmd runs in the slow suite)."""
    g, pg = skewed
    pat = Pattern.from_edges(QUERIES["q1"])
    oracle = canonicalize(enumerate_oracle(g, pat), pat)
    raw_ref = rads_enumerate(
        pg, pat, dataclasses.replace(CFG, wire_format="raw"), mode="sim")
    assert canonicalize(raw_ref.embeddings, pat) == oracle
    key = None
    for fmt, mode, cache_on in [("dense", "sim", True),
                                ("bucketed", "sim", True),
                                ("dense", "gather", True),
                                ("bucketed", "gather", True),
                                ("dense", "sim", False),
                                ("bucketed", "gather", False)]:
        cfg = dataclasses.replace(CFG, storage_format=fmt,
                                  enable_cache=cache_on)
        res = rads_enumerate(pg, pat, cfg, mode=mode)
        tag = (fmt, mode, cache_on)
        assert canonicalize(res.embeddings, pat) == oracle, tag
        assert res.count == raw_ref.count, tag
        st = res.stats
        assert st["wire_format"] == "varint"
        # raw-equivalent accounting is wire-format-invariant
        assert st["bytes_verify"] == raw_ref.stats["bytes_verify"], tag
        # the coded stream is strictly smaller than the raw wire here
        assert st["bytes_wire_verify"] < st["bytes_verify"], tag
        assert st["bytes_wire_fetch"] <= st["bytes_fetch"], tag
        # actual coded fetch bytes never exceed the PR 4 modeled column
        assert st["bytes_wire_fetch"] <= st["bytes_fetch_compressed"], tag
        if cache_on:   # deterministic across backends and formats
            k = (res.count, st["bytes_wire_fetch"], st["bytes_wire_verify"])
            key = key or k
            assert k == key, tag
    # raw mode reports its own wire bytes == the raw accounting
    assert (raw_ref.stats["bytes_wire_fetch"]
            == raw_ref.stats["bytes_fetch"])
    assert (raw_ref.stats["bytes_wire_verify"]
            == raw_ref.stats["bytes_verify"])


def test_wire_escalation_survival():
    """Tiny caps force overflow splits + capacity escalations; the coded
    stream caps re-jit alongside and the run stays oracle-exact."""
    g = powerlaw_graph(128, 6, seed=2)
    pg = partition(g, 4, method="hash")
    pat = Pattern.from_edges(QUERIES["q3"])
    oracle = canonicalize(enumerate_oracle(g, pat), pat)
    cfg = EngineConfig(frontier_cap=512, fetch_cap=128, verify_cap=512,
                       region_group_budget=256, enable_sme=False,
                       cache_slots=256, wire_format="varint")
    res = rads_enumerate(pg, pat, cfg, mode="sim")
    assert canonicalize(res.embeddings, pat) == oracle
    assert res.stats["cap_escalations"] >= 1
    assert res.stats["bytes_wire_verify"] < res.stats["bytes_verify"]
    assert res.stats["bytes_wire_fetch"] <= res.stats["bytes_fetch"]


def test_wire_pallas_path(skewed):
    """The Pallas-gated codec path (delta/varint-size kernel in the fetch
    encoder + membership/intersect kernels) stays oracle-exact with
    byte-identical wire accounting."""
    g, pg = skewed
    pat = Pattern.from_edges(QUERIES["q1"])
    oracle = canonicalize(enumerate_oracle(g, pat), pat)
    ref = rads_enumerate(pg, pat, CFG, mode="sim")
    cfg = dataclasses.replace(CFG, use_pallas_kernels=True,
                              storage_format="bucketed")
    res = rads_enumerate(pg, pat, cfg, mode="sim")
    assert canonicalize(res.embeddings, pat) == oracle
    assert res.stats["bytes_wire_fetch"] == ref.stats["bytes_wire_fetch"]
    assert res.stats["bytes_wire_verify"] == ref.stats["bytes_wire_verify"]


def test_sync_equals_async_wire(skewed):
    """Results are wire-format- and schedule-invariant together."""
    g, pg = skewed
    pat = Pattern.from_edges(QUERIES["q1"])
    sync = rads_enumerate(pg, pat,
                          dataclasses.replace(CFG, pipeline_depth=1),
                          mode="sim")
    anc = rads_enumerate(pg, pat, CFG, mode="sim")
    assert sync.count == anc.count
    assert canonicalize(sync.embeddings, pat) == canonicalize(
        anc.embeddings, pat)


def test_config_validates_wire_format():
    EngineConfig(wire_format="varint")
    with pytest.raises(ValueError, match="wire_format"):
        EngineConfig(wire_format="zstd")
    from repro.core.exchange import Exchange
    with pytest.raises(ValueError, match="wire format"):
        Exchange("sim", wire_format="zstd")


@pytest.mark.slow
def test_acceptance_powerlaw_4096_wire_drop():
    """Acceptance bar: on the n=4096 / avg_deg=8 power-law graph,
    wire='varint' cuts the actual verifyE wire bytes by >= 30% and the
    total exchange bytes by >= 25% vs wire='raw', with identical counts
    and the exact per-run identity bytes_wire_fetch <= bytes_fetch."""
    g = powerlaw_graph(4096, 8, seed=1)
    pg = partition(g, 4, method="hash")      # worst-case communication
    pat = Pattern.from_edges(QUERIES["q1"])
    cfg = EngineConfig(frontier_cap=1 << 14, fetch_cap=1 << 12,
                       verify_cap=1 << 13, region_group_budget=1 << 12,
                       enable_sme=False)
    raw = rads_enumerate(pg, pat, cfg, mode="sim", return_embeddings=False)
    var = rads_enumerate(pg, pat,
                         dataclasses.replace(cfg, wire_format="varint"),
                         mode="sim", return_embeddings=False)
    assert var.count == raw.count
    assert var.stats["n_waves"] >= 2
    rs, vs = raw.stats, var.stats
    assert vs["bytes_wire_fetch"] <= vs["bytes_fetch"]
    assert vs["bytes_wire_verify"] > 0
    verify_cut = 1.0 - vs["bytes_wire_verify"] / rs["bytes_wire_verify"]
    total_raw = rs["bytes_wire_fetch"] + rs["bytes_wire_verify"]
    total_var = vs["bytes_wire_fetch"] + vs["bytes_wire_verify"]
    total_cut = 1.0 - total_var / total_raw
    assert verify_cut >= 0.30, (vs["bytes_wire_verify"],
                                rs["bytes_wire_verify"])
    assert total_cut >= 0.25, (total_var, total_raw)
    # actual coded fetch bytes within the modeled baseline (+5% bench gate)
    assert vs["bytes_wire_fetch"] <= 1.05 * vs["bytes_fetch_compressed"]
