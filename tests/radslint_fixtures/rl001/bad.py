"""RL001 planted violations: host syncs / tracer leaks inside jit code.

Never imported at runtime — parsed by tools/radslint in tests only.
"""
import jax
import jax.numpy as jnp
import numpy as np


@jax.jit
def leaky(x: jnp.ndarray) -> jnp.ndarray:
    if x.sum() > 0:                  # RL001: Python `if` on a traced value
        x = x + 1
    n = int(x.sum())                 # RL001: int() cast forces a sync
    v = x.sum().item()               # RL001: .item() forces a sync
    h = np.asarray(x)                # RL001: np.* pulls the array to host
    for r in x:                      # RL001: Python `for` over a traced value
        v = v + r
    return x * n + v + h.shape[0]


def fetch(i):
    return i


def wave_loop():
    """Host-side hot loop (configured via hot_loops in the test)."""
    st = fetch(0)
    done = bool(st[0])               # RL001: blocking scalar read per wave
    return done
