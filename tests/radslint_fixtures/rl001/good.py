"""RL001 known-good twin: same shapes, no host syncs."""
import jax
import jax.numpy as jnp


@jax.jit
def clean(x: jnp.ndarray) -> jnp.ndarray:
    total = jnp.where(x.sum() > 0, x + 1, x)     # branch stays on device
    for i in range(x.shape[0]):                  # static shape-derived loop
        total = total + i
    k = int(x.shape[0])                          # shape reads are static
    return total * k


def fetch(i):
    return i


def wave_loop():
    st = fetch(0)
    host = jax.device_get(st)                    # one sanctioned batched sync
    return bool(host[0])
