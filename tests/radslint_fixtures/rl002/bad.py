"""RL002 planted violations: recompile triggers."""
import jax
import jax.numpy as jnp


@jax.jit
def kernel(x: jnp.ndarray, cap: int):    # RL002: scalar param re-traces
    return x[:cap]


LUT = [1, 2, 3]                          # mutable module state ...
fn = jax.jit(lambda x: x + LUT[0])       # RL002: ... captured by a jit lambda

fetch_cap = 1000                         # RL002: off the power-of-two ladder


def run(x):
    return kernel(x, fetch_cap)
