"""RL002 known-good twin: statics declared, immutable capture, ladder caps."""
from functools import partial

import jax
import jax.numpy as jnp


@partial(jax.jit, static_argnames=("cap",))
def kernel(x: jnp.ndarray, cap: int):
    return x[:cap]


LUT = (1, 2, 3)                          # immutable capture is fine
fn = jax.jit(lambda x: x + LUT[0])

fetch_cap = 1 << 10                      # on the ladder


def run(x):
    return kernel(x, fetch_cap)
