"""RL005 known-good twin: 32-bit dtypes only."""
import jax
import jax.numpy as jnp


@jax.jit
def narrow(x: jnp.ndarray):
    a = x.astype("int32")
    b = jnp.zeros((4,), jnp.float32)
    c = jnp.arange(4, dtype="float32")
    return a, b, c
