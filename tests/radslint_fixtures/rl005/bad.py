"""RL005 planted violations: 64-bit dtypes inside jit code (x64 is off)."""
import jax
import jax.numpy as jnp


@jax.jit
def widen(x: jnp.ndarray):
    a = x.astype("int64")                    # RL005: astype to a wide dtype
    b = jnp.zeros((4,), jnp.float64)         # RL005: jnp.float64 reference
    c = jnp.arange(4, dtype="float64")       # RL005: dtype= string
    return a, b, c
