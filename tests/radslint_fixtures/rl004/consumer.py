"""RL004 fixture consumer: surfaces two of the three stat fields."""


def consume(st: dict, stats: dict) -> None:
    stats["bytes_fetch"] += float(st["bytes_fetch"])
    stats["cache_hits"] += float(st["cache_hits"])
