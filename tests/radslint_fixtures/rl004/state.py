"""RL004 fixture: a stat-carrying state with one dropped counter."""
import jax.numpy as jnp


class WaveState:
    bytes_fetch: jnp.ndarray
    bytes_dropped: jnp.ndarray    # RL004: never finalized, never consumed
    cache_hits: jnp.ndarray
    rows: jnp.ndarray             # not a stat field: no pattern match


def finalize(state: WaveState) -> dict:
    return dict(bytes_fetch=state.bytes_fetch,
                cache_hits=state.cache_hits)
