"""RL004 metric-extension fixture: a declared schema with one orphan.

``n_waves`` and ``wall_us`` appear in ``consumer.py`` / this package's
exporter stand-ins; ``orphan_gauge`` is declared but surfaced nowhere —
the metric half of RL004 must flag exactly it.
"""


def counter(name, unit="", desc=""):
    return (name, "counter", unit, desc)


def gauge(name, unit="", desc=""):
    return (name, "gauge", unit, desc)


SCHEMA = (
    counter("bytes_fetch", "bytes", "consumed in consumer.py"),
    counter("cache_hits", "", "consumed in consumer.py"),
    gauge("orphan_gauge", "", "declared but never exported anywhere"),
)
