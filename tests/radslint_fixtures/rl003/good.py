"""RL003 known-good twin: static shapes, annotated scatters, sorted sets."""
import jax
import jax.numpy as jnp


@jax.jit
def det(ids: jnp.ndarray, seg: jnp.ndarray):
    u = jnp.unique(ids, size=8, fill_value=-1)           # static output shape
    counts = jnp.zeros((8,), jnp.float32)
    counts = counts.at[seg].add(1.0, mode="drop")        # annotated scatter
    tags = jnp.array(sorted({3, 1, 2}))                  # order pinned
    for k in (0, 1):                                     # ordered sequence
        counts = counts + k
    return u, counts, tags
