"""RL003 planted violations: determinism hazards inside jit code."""
import jax
import jax.numpy as jnp


@jax.jit
def undet(ids: jnp.ndarray, seg: jnp.ndarray):
    u = jnp.unique(ids)                                  # RL003: no size=
    counts = jnp.zeros((8,), jnp.float32)
    counts = counts.at[seg].add(1.0)                     # RL003: dup scatter
    tags = jnp.array({3, 1, 2})                          # RL003: set order
    for k in {0, 1}:                                     # RL003: set iter
        counts = counts + k
    return u, counts, tags
