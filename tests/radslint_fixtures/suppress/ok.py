"""Suppression fixture: a justified inline allow silences the finding."""
import jax
import jax.numpy as jnp


@jax.jit
def seg_sum(seg: jnp.ndarray) -> jnp.ndarray:
    # radslint: allow[RL003] integer segment-sum; order-independent adds
    return jnp.zeros((4,), jnp.int32).at[seg].add(1)
