"""Suppression fixture: an allow with no justification is itself RL000
and does not silence the underlying finding."""
import jax
import jax.numpy as jnp


@jax.jit
def seg_sum(seg: jnp.ndarray) -> jnp.ndarray:
    # radslint: allow[RL003]
    return jnp.zeros((4,), jnp.int32).at[seg].add(1)
