"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attn.kernel import flash_attention_pallas
from repro.kernels.flash_attn.ref import flash_attention_ref
from repro.kernels.intersect.kernel import intersect_pallas
from repro.kernels.intersect.ref import intersect_ref
from repro.kernels.membership.kernel import membership_pallas
from repro.kernels.membership.ref import membership_ref
from repro.kernels.moe_gemm.kernel import moe_gemm_pallas
from repro.kernels.moe_gemm.ref import moe_gemm_ref
from repro.kernels.segment_spmm.ops import segment_spmm_tiled
from repro.kernels.segment_spmm.ref import segment_sum_dense


@pytest.mark.parametrize("B,M,K", [(7, 16, 3), (64, 130, 9), (256, 64, 1),
                                   (3, 257, 17)])
def test_membership_sweep(B, M, K):
    rng = np.random.default_rng(B * M + K)
    rows = np.sort(rng.integers(0, 300, (B, M)).astype(np.int32), axis=1)
    vals = rng.integers(0, 300, (B, K)).astype(np.int32)
    got = membership_pallas(jnp.asarray(rows), jnp.asarray(vals))
    want = membership_ref(jnp.asarray(rows), jnp.asarray(vals))
    assert np.array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("B,M", [(5, 20), (33, 129), (128, 64)])
def test_intersect_sweep(B, M):
    rng = np.random.default_rng(B + M)
    sent = 500
    a = np.sort(rng.integers(0, sent, (B, M)).astype(np.int32), axis=1)
    b = np.sort(rng.integers(0, sent, (B, M)).astype(np.int32), axis=1)
    m1, c1 = intersect_pallas(jnp.asarray(a), jnp.asarray(b), sent)
    m2, c2 = intersect_ref(jnp.asarray(a), jnp.asarray(b), sent)
    assert np.array_equal(np.asarray(m1), np.asarray(m2))
    assert np.array_equal(np.asarray(c1), np.asarray(c2))


@pytest.mark.parametrize("block_b,m_chunk", [(None, None), (64, 8),
                                             (128, 32), (512, 128),
                                             (256, 16)])
@pytest.mark.parametrize("B,M", [(17, 8), (40, 65), (9, 200)])
def test_intersect_tile_parity(B, M, block_b, m_chunk):
    """The exposed block_b/m_chunk tiling kwargs (and the bucket-cap-tuned
    defaults, block_b=None/m_chunk=None) never change the result — any
    tile shape is bit-identical to the jnp reference."""
    from repro.kernels.intersect.ops import intersect
    rng = np.random.default_rng(B * M + (block_b or 0))
    sent = 300
    a = np.sort(rng.integers(0, sent, (B, M)).astype(np.int32), axis=1)
    b = np.sort(rng.integers(0, sent, (B, M)).astype(np.int32), axis=1)
    m1, c1 = intersect(jnp.asarray(a), jnp.asarray(b), sent,
                       use_kernel=True, interpret=True,
                       block_b=block_b, m_chunk=m_chunk)
    m2, c2 = intersect(jnp.asarray(a), jnp.asarray(b), sent,
                       use_kernel=False)
    assert np.array_equal(np.asarray(m1), np.asarray(m2))
    assert np.array_equal(np.asarray(c1), np.asarray(c2))


def test_intersect_tile_defaults():
    """The tuned defaults narrow the chunk for small bucket caps (and
    widen the batch tile to compensate); wide windows keep the 128-lane
    chunk."""
    from repro.kernels.intersect.ops import tile_defaults
    assert tile_defaults(8) == (512, 8)
    assert tile_defaults(1) == (512, 1)
    assert tile_defaults(200) == (256, 128)
    assert tile_defaults(64) == (256, 64)


@pytest.mark.parametrize("B,M", [(3, 16), (7, 130), (260, 64), (1, 300)])
def test_varint_delta_vlen_sweep(B, M):
    """The fused delta+LEB128-size Pallas kernel (the wire-codec fast
    path) matches the jnp reference over sorted-with-holes id lanes."""
    from repro.kernels.varint.kernel import delta_vlen_pallas
    from repro.kernels.varint.ref import delta_vlen_ref
    rng = np.random.default_rng(B * M)
    n = 1 << 27
    ids = np.full((B, M), n, np.int32)
    for r in range(B):
        k = int(rng.integers(0, M + 1))
        vals = np.sort(rng.choice(n, size=k, replace=False)).astype(np.int32)
        ids[r, np.sort(rng.choice(M, k, replace=False))] = vals
    d1, v1 = delta_vlen_pallas(jnp.asarray(ids), n)
    d2, v2 = delta_vlen_ref(jnp.asarray(ids), n)
    assert np.array_equal(np.asarray(d1), np.asarray(d2))
    assert np.array_equal(np.asarray(v1), np.asarray(v2))


@pytest.mark.parametrize("E,N,D,tn,te", [(300, 50, 8, 16, 64),
                                         (1000, 128, 32, 32, 128),
                                         (64, 7, 4, 8, 32)])
def test_segment_spmm_sweep(E, N, D, tn, te):
    rng = np.random.default_rng(E + N)
    msgs = jnp.asarray(rng.normal(size=(E, D)).astype(np.float32))
    dst = rng.integers(0, N, E).astype(np.int32)
    got = segment_spmm_tiled(msgs, dst, N, tn=tn, te=te, use_kernel=True)
    want = segment_sum_dense(msgs, jnp.asarray(dst), N)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype,rtol", [(jnp.float32, 2e-5),
                                        (jnp.bfloat16, 2e-2)])
@pytest.mark.parametrize("S,H,Hk,D", [(64, 4, 2, 32), (128, 2, 2, 16)])
def test_flash_attention_sweep(S, H, Hk, D, dtype, rtol):
    key = jax.random.PRNGKey(S + H)
    ks = jax.random.split(key, 3)
    BH = 3
    q = jax.random.normal(ks[0], (BH, S, D), dtype)
    k = jax.random.normal(ks[1], (BH, S, D), dtype)
    v = jax.random.normal(ks[2], (BH, S, D), dtype)
    got = flash_attention_pallas(q, k, v, causal=True, bq=32, bk=32)
    want = flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=rtol, atol=rtol)


@pytest.mark.parametrize("dtype,rtol", [(jnp.float32, 1e-5),
                                        (jnp.bfloat16, 5e-2)])
@pytest.mark.parametrize("E,C,d,f", [(4, 64, 32, 64), (2, 128, 16, 128)])
def test_moe_gemm_sweep(E, C, d, f, dtype, rtol):
    key = jax.random.PRNGKey(E * C)
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (E, C, d), dtype)
    wg = (jax.random.normal(ks[1], (E, d, f), dtype) * 0.1).astype(dtype)
    wu = (jax.random.normal(ks[2], (E, d, f), dtype) * 0.1).astype(dtype)
    wd = (jax.random.normal(ks[3], (E, f, d), dtype) * 0.1).astype(dtype)
    got = moe_gemm_pallas(x, wg, wu, wd, bc=32, bf=32)
    want = moe_gemm_ref(x, wg, wu, wd)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=rtol, atol=rtol)


def test_flash_matches_model_reference():
    """The in-model pure-JAX flash (models.layers.flash_attention) and the
    Pallas kernel agree — kernel swap-in safety."""
    from repro.models.layers import flash_attention as model_flash
    key = jax.random.PRNGKey(0)
    B, S, H, Hk, D = 2, 64, 4, 2, 16
    q = jax.random.normal(key, (B, S, H, D))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, Hk, D))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, Hk, D))
    a = model_flash(q, k, v, causal=True, q_chunk=32, kv_chunk=32)
    from repro.kernels.flash_attn.ops import flash_attention_k
    b = flash_attention_k(q, k, v, causal=True, use_kernel=True, bq=32, bk=32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-5, atol=2e-5)
