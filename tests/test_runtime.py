"""Runtime: fault tolerance, checkpoint/elastic restore, compression,
optimizer, data pipelines."""
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # hermetic container: vendored fallback
    from _hypothesis_compat import given, settings, strategies as st

from repro.checkpoint import latest_step, load_checkpoint, save_checkpoint
from repro.configs import get_reduced
from repro.data import Prefetcher, din_batch_stream, lm_token_stream
from repro.distributed.compression import (compress_roundtrip,
                                           init_error_feedback)
from repro.models.transformer import init_lm_params, lm_loss
from repro.optim import AdamWConfig, adamw_update, init_opt_state
from repro.runtime import FaultInjector, Trainer, TrainerConfig


def _mk_trainer(tmp, ckpt_every=5, seed=0):
    cfg = get_reduced("qwen1.5-0.5b")
    params = init_lm_params(jax.random.PRNGKey(seed), cfg)
    loss_fn = lambda p, b: lm_loss(p, cfg, jnp.asarray(b["tokens"]),
                                   jnp.asarray(b["labels"]))
    opt = AdamWConfig(lr=1e-3, warmup_steps=3, total_steps=30)
    return cfg, Trainer(loss_fn, params, opt,
                        TrainerConfig(ckpt_dir=tmp, ckpt_every=ckpt_every,
                                      log_every=1000))


def test_fault_recovery_bit_exact(tmp_path):
    cfg, tr = _mk_trainer(str(tmp_path / "a"))
    hist = tr.run(lm_token_stream(cfg.vocab, 4, 24, seed=7), 14,
                  fault=FaultInjector(fail_at={8}), log=lambda s: None)
    cfg, tr2 = _mk_trainer(str(tmp_path / "b"))
    hist2 = tr2.run(lm_token_stream(cfg.vocab, 4, 24, seed=7), 14,
                    log=lambda s: None)
    l1 = {h["step"]: h["loss"] for h in hist}
    l2 = {h["step"]: h["loss"] for h in hist2}
    for s in range(10, 15):
        assert abs(l1[s] - l2[s]) < 1e-6
    assert hist2[-1]["loss"] < hist2[0]["loss"]    # actually learns


def test_multiple_faults(tmp_path):
    cfg, tr = _mk_trainer(str(tmp_path / "c"), ckpt_every=3)
    hist = tr.run(lm_token_stream(cfg.vocab, 4, 24, seed=7), 12,
                  fault=FaultInjector(fail_at={4, 7, 10}), log=lambda s: None)
    assert tr.step == 12


def test_checkpoint_roundtrip(tmp_path):
    tree = dict(a=jnp.arange(8, dtype=jnp.bfloat16),
                b=[jnp.ones((3, 3)), jnp.zeros((), jnp.int32)])
    save_checkpoint(str(tmp_path), 7, tree, blocking=True)
    assert latest_step(str(tmp_path)) == 7
    like = jax.tree.map(lambda x: jnp.zeros_like(x), tree)
    back, step = load_checkpoint(str(tmp_path), like)
    assert step == 7
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert x.dtype == y.dtype
        np.testing.assert_array_equal(np.asarray(x, np.float32),
                                      np.asarray(y, np.float32))


def test_checkpoint_elastic_resharding(tmp_path):
    """Checkpoint written 'on one mesh' restores onto a different sharding
    (here: device_put to the single device with a fresh layout)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((1,), ("data",))
    tree = dict(w=jnp.arange(16.0).reshape(4, 4))
    save_checkpoint(str(tmp_path), 1, tree, blocking=True)
    sh = dict(w=NamedSharding(mesh, P("data", None)))
    back, _ = load_checkpoint(str(tmp_path), tree, shardings=sh)
    assert back["w"].sharding == sh["w"]


@given(st.lists(st.floats(-100, 100, allow_nan=False), min_size=4,
                max_size=64))
@settings(max_examples=30, deadline=None)
def test_property_compression_error_bounded(vals):
    g = dict(w=jnp.asarray(np.array(vals, np.float32)))
    err = init_error_feedback(g)
    gh, new_err = compress_roundtrip(g, err)
    scale = max(abs(v) for v in vals) / 127.0 if any(vals) else 0.0
    # quantization error bounded by half an int8 step
    assert float(jnp.abs(gh["w"] - g["w"]).max()) <= scale / 2 + 1e-6
    # error feedback stores exactly the residual
    np.testing.assert_allclose(np.asarray(new_err["w"]),
                               np.asarray(g["w"] - gh["w"]), atol=1e-6)


def test_compression_error_feedback_converges():
    """EF property: the *running sum* of compressed grads tracks the true
    sum (bias cancels) — the reason int8+EF trains to the same loss."""
    rng = np.random.default_rng(0)
    g_true = [rng.normal(size=32).astype(np.float32) for _ in range(50)]
    err = init_error_feedback(dict(w=jnp.zeros(32)))
    acc_hat = np.zeros(32)
    for g in g_true:
        gh, err = compress_roundtrip(dict(w=jnp.asarray(g)), err)
        acc_hat += np.asarray(gh["w"])
    acc_true = np.sum(g_true, axis=0)
    # residual is at most one quantization step, NOT O(n_steps)
    assert np.abs(acc_hat - acc_true).max() < 0.1


def test_adamw_quadratic_convergence():
    params = dict(w=jnp.array([5.0, -3.0]))
    opt = AdamWConfig(lr=0.3, weight_decay=0.0, warmup_steps=0,
                      total_steps=200, min_lr_frac=1.0)
    state = init_opt_state(params, opt)
    for _ in range(150):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, state, _ = adamw_update(params, g, state, opt)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_data_streams_deterministic():
    a = list(lm_token_stream(100, 2, 8, seed=3, n_steps=3))
    b = list(lm_token_stream(100, 2, 8, seed=3, n_steps=3))
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x["tokens"], y["tokens"])
    d = next(iter(din_batch_stream(50, 5, 20, 4, 6, seed=1, n_steps=1)))
    assert d["hist_items"].shape == (4, 6)


def test_prefetcher_order():
    src = (dict(i=i) for i in range(10))
    out = [x["i"] for x in Prefetcher(src)]
    assert out == list(range(10))
