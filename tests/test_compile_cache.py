"""Persistent stage-executable cache (runtime/compile_cache.py): a
serialize -> deserialize roundtrip is byte-identical with a fresh jit, any
key-layer mismatch forces recompilation (never a wrong-executable hit),
and corrupt/stale store files degrade to a warning + tracing fallback
instead of a crash."""
import dataclasses
import os
import pickle

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from repro.configs.rads import EngineConfig
from repro.runtime.compile_cache import (StageExecCache, arg_signature,
                                         build_exec_cache, stage_context)

pytestmark = pytest.mark.skipif(
    not compat.HAS_EXECUTABLE_SERIALIZATION,
    reason="this jax build cannot serialize compiled executables")


def _f(x, y):
    return jnp.dot(x, y) + jnp.float32(1.0)


ARGS = (jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        jnp.ones((4, 2), jnp.float32))


def _store_one(cache, cfg=None, args=ARGS):
    cfg = cfg or EngineConfig()
    sig = arg_signature(args)
    ctx = stage_context(("fetch", 0), cfg, "sim", "planA")
    d = cache.digest(("fetch", 0), sig, ctx)
    compiled = jax.jit(_f).lower(*args).compile()
    assert cache.store(d, sig, ctx, compiled)
    return d, sig, ctx, compiled


def test_roundtrip_byte_identical(tmp_path):
    cache = StageExecCache(str(tmp_path))
    d, sig, ctx, compiled = _store_one(cache)
    StageExecCache.clear_memory_memo()       # force disk deserialization
    loaded = cache.load(d, sig, ctx)
    assert loaded is not None
    assert cache.stats["hits"] == 1 and cache.stats["errors"] == 0
    want = np.asarray(jax.jit(_f)(*ARGS))    # fresh-jit reference
    assert np.asarray(compiled(*ARGS)).tobytes() == want.tobytes()
    assert np.asarray(loaded(*ARGS)).tobytes() == want.tobytes()
    # second load comes from the in-process memo, still a hit
    assert cache.load(d, sig, ctx) is loaded
    assert cache.stats["hits"] == 2


def test_key_mismatch_forces_recompile(tmp_path):
    cache = StageExecCache(str(tmp_path))
    cfg = EngineConfig()
    sig = arg_signature(ARGS)

    def dig(key, c, plan="planA", s=sig):
        return cache.digest(key, s, stage_context(key, c, "sim", plan))

    base = dig(("fetch", 0), cfg)
    # capacity tuple, wire format, plan/pattern, and argument shapes each
    # land on a distinct digest -> a changed run can never hit a stale entry
    assert dig(("fetch", 0),
               dataclasses.replace(cfg, fetch_cap=2 * cfg.fetch_cap)) != base
    assert dig(("fetch", 0),
               dataclasses.replace(cfg, wire_format="varint")) != base
    assert dig(("fetch", 0), cfg, plan="planB") != base
    sig2 = arg_signature((jnp.zeros((6, 4), jnp.float32), ARGS[1]))
    assert dig(("fetch", 0), cfg, s=sig2) != base
    # ...but wire-agnostic stages genuinely share: expand's context ignores
    # wire_format, so raw/varint benchmark cells reuse one expand entry
    k = ("expand", 0, False)
    assert dig(k, cfg) == dig(k, dataclasses.replace(cfg,
                                                     wire_format="varint"))
    # a digest never stored is a plain miss, not an error
    ctx = stage_context(("fetch", 0), cfg, "sim", "planA")
    assert cache.load(base, sig, ctx) is None
    assert cache.stats == dict(hits=0, misses=1, stores=0, errors=0,
                               evictions=0)


def test_corrupt_file_warns_and_falls_back(tmp_path):
    cache = StageExecCache(str(tmp_path))
    d, sig, ctx, _ = _store_one(cache)
    with open(cache._file(d), "wb") as f:
        f.write(b"not a pickle")
    StageExecCache.clear_memory_memo()
    with pytest.warns(RuntimeWarning, match="unusable entry"):
        assert cache.load(d, sig, ctx) is None
    assert cache.stats["errors"] == 1
    assert cache.entries() == []             # the bad file was removed


def test_stale_envelope_rejected(tmp_path):
    """A well-formed pickle from another build (mismatched key material)
    must be refused at load time, warned about, and dropped."""
    cache = StageExecCache(str(tmp_path))
    d, sig, ctx, _ = _store_one(cache)
    with open(cache._file(d), "rb") as f:
        env = pickle.load(f)
    env["material"] = "jax=0.0.0;some-other-build"
    with open(cache._file(d), "wb") as f:
        pickle.dump(env, f)
    StageExecCache.clear_memory_memo()
    with pytest.warns(RuntimeWarning, match="unusable entry"):
        assert cache.load(d, sig, ctx) is None
    assert cache.stats["errors"] == 1 and cache.entries() == []


def test_build_exec_cache_gating(tmp_path):
    assert build_exec_cache(EngineConfig()) is None
    c = build_exec_cache(EngineConfig(
        compile_cache_dir=str(tmp_path / "execs")))
    assert isinstance(c, StageExecCache) and c.enabled
    assert c.entries() == []
    assert c.budget_bytes == 0               # unbounded by default
    b = build_exec_cache(EngineConfig(
        compile_cache_dir=str(tmp_path / "execs2"),
        compile_cache_budget_bytes=1 << 20))
    assert b.budget_bytes == 1 << 20


def test_budget_gc_evicts_oldest(tmp_path):
    """LRU garbage collection: with a byte budget fitting only two of three
    envelopes, the oldest-mtime entry is evicted and the survivors load."""
    cache = StageExecCache(str(tmp_path))    # unbounded while seeding
    entries = []
    for fc in (1 << 8, 1 << 9, 1 << 10):     # distinct caps -> digests
        cfg = EngineConfig(fetch_cap=fc)
        entries.append(_store_one(cache, cfg=cfg))
    files = [cache._file(d) for d, _, _, _ in entries]
    sizes = [os.path.getsize(f) for f in files]
    for i, f in enumerate(files):            # deterministic LRU order
        os.utime(f, (1000 + i, 1000 + i))
    cache.budget_bytes = sizes[1] + sizes[2]
    assert cache._gc() == 1
    assert cache.stats["evictions"] == 1
    assert not os.path.exists(files[0])
    assert os.path.exists(files[1]) and os.path.exists(files[2])
    StageExecCache.clear_memory_memo()
    d1, sig1, ctx1, _ = entries[1]
    assert cache.load(d1, sig1, ctx1) is not None   # survivor still loads
    d0, sig0, ctx0, _ = entries[0]
    assert cache.load(d0, sig0, ctx0) is None       # evicted -> plain miss


def test_store_triggers_gc_and_disk_hit_refreshes_lru(tmp_path):
    """A store over budget immediately evicts the LRU entry, and a disk
    *load* refreshes an entry's mtime so hot entries never look cold."""
    cache = StageExecCache(str(tmp_path))
    d0, sig0, ctx0, _ = _store_one(cache, cfg=EngineConfig())
    f0 = cache._file(d0)
    os.utime(f0, (1000, 1000))
    # a disk hit must bump the mtime (the LRU touch)
    StageExecCache.clear_memory_memo()
    assert cache.load(d0, sig0, ctx0) is not None
    assert os.path.getmtime(f0) > 1000
    os.utime(f0, (1000, 1000))               # age it again, then overflow
    cache.budget_bytes = os.path.getsize(f0) + 16
    d1, *_ = _store_one(cache, cfg=EngineConfig(fetch_cap=1 << 9))
    assert cache.entries() == sorted([d1])   # d0 evicted by the store's gc
    assert cache.stats["evictions"] == 1


def test_prewarm_signature_matches_concrete():
    """The abstract pre-warm path must resolve to the same slot a concrete
    dispatch hits: ShapeDtypeStruct and device-array signatures agree."""
    abstract = tuple(jax.ShapeDtypeStruct(a.shape, a.dtype) for a in ARGS)
    assert arg_signature(abstract) == arg_signature(ARGS)
