"""Execution-plan computation (§4): invariants + property tests."""
import itertools

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # hermetic container: vendored fallback
    from _hypothesis_compat import given, settings, strategies as st

from repro.configs.rads import CLIQUE_QUERIES, QUERIES
from repro.core import (Pattern, best_plan, bfs_fallback_plan, minimum_cds,
                        min_rounds_unscored_plan, random_star_plan)
from repro.core.plan import compute_matching_order

ALL_QUERIES = {**QUERIES, **CLIQUE_QUERIES}


@pytest.mark.parametrize("qname", list(ALL_QUERIES))
def test_best_plan_valid_and_minimum_rounds(qname):
    p = Pattern.from_edges(ALL_QUERIES[qname])
    plan = best_plan(p)
    plan.validate()
    c_p = len(minimum_cds(p)[0])
    assert plan.n_rounds == c_p, "Theorem 1: rounds == connected-domination #"
    assert plan.matching_order[0] == plan.units[0].piv


@pytest.mark.parametrize("qname", list(QUERIES))
def test_matching_order_is_total_order(qname):
    p = Pattern.from_edges(QUERIES[qname])
    plan = best_plan(p)
    order = compute_matching_order(plan)
    assert sorted(order) == list(range(p.n))
    # Def. 10 (1): pivots appear in unit order
    pos = {u: i for i, u in enumerate(order)}
    pivs = [u.piv for u in plan.units]
    assert all(pos[a] < pos[b] for a, b in zip(pivs, pivs[1:]))


def test_span_and_border_distance_examples():
    # Figure 4-style: span differs by choice of pivot
    p = Pattern.from_edges(QUERIES["q5"])
    spans = [p.span(u) for u in range(p.n)]
    assert min(spans) >= 1 and max(spans) <= p.n - 1


def test_pivots_form_connected_dominating_set():
    for qname, edges in ALL_QUERIES.items():
        p = Pattern.from_edges(edges)
        plan = best_plan(p)
        pivs = tuple(sorted({u.piv for u in plan.units}))
        from repro.core.plan import _is_dominating, _is_connected_subset
        assert _is_dominating(p, pivs)
        assert _is_connected_subset(p, pivs)


def test_baseline_plans_valid():
    for qname, edges in ALL_QUERIES.items():
        p = Pattern.from_edges(edges)
        random_star_plan(p, seed=3).validate()
        min_rounds_unscored_plan(p).validate()
        bfs_fallback_plan(p).validate()


def test_score_prefers_early_verification_edges():
    # paper Example 5: PL1 (2,1,2 verification edges) beats PL2 (1,2,2)
    from repro.core.plan import Plan, Unit
    edges = [(0, 1), (0, 2), (1, 2), (1, 3), (1, 4), (3, 4), (4, 5), (2, 5),
             (2, 6), (0, 7), (0, 8), (0, 9), (8, 9)]
    p = Pattern.from_edges(edges)
    pl1 = Plan(pattern=p, units=(Unit(0, (1, 2, 7, 8, 9)), Unit(1, (3, 4)),
                                 Unit(2, (5, 6))))
    pl2 = Plan(pattern=p, units=(Unit(1, (0, 3, 4)), Unit(0, (2, 7, 8, 9)),
                                 Unit(2, (5, 6))))
    pl1.validate()
    pl2.validate()
    assert pl1.score(rho=1.0) > pl2.score(rho=1.0)


# ---------------------------------------------------------------------- #
# property: random connected patterns
# ---------------------------------------------------------------------- #
@st.composite
def connected_pattern(draw):
    n = draw(st.integers(3, 6))
    # random spanning tree + extra edges
    edges = set()
    for v in range(1, n):
        u = draw(st.integers(0, v - 1))
        edges.add((u, v))
    extra = draw(st.lists(
        st.tuples(st.integers(0, n - 1), st.integers(0, n - 1)),
        max_size=5))
    for a, b in extra:
        if a != b:
            edges.add((min(a, b), max(a, b)))
    return Pattern.from_edges(edges)


@given(connected_pattern())
@settings(max_examples=30, deadline=None)
def test_property_best_plan_always_valid(p):
    plan = best_plan(p)
    plan.validate()
    assert plan.n_rounds == len(minimum_cds(p)[0])
    order = plan.matching_order
    assert sorted(order) == list(range(p.n))


@given(connected_pattern())
@settings(max_examples=20, deadline=None)
def test_property_symmetry_constraints_acyclic(p):
    cons = p.symmetry_constraints()
    # constraints must form a DAG (no contradiction f(a)<f(b)<f(a))
    import networkx as nx
    g = nx.DiGraph(cons)
    assert nx.is_directed_acyclic_graph(g)
