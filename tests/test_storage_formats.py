"""Pluggable on-device storage formats (graph/storage.py DeviceGraph):

* dense vs bucketed rows are byte-identical (the engine contract),
* backend parity on *skewed* (power-law) graphs — sim == gather == oracle
  across both formats with identical traffic accounting (spmd parity runs
  in the slow multi-device subprocess suite, test_multidevice.py),
* the bucketed footprint beats dense by >= 4x on the acceptance-scale
  power-law graph (memory decoupled from the worst hub vertex),
* the Pallas ``intersect`` candidate-generation path (bucketed layout,
  interpret mode on CPU) changes nothing.
"""
import dataclasses

import numpy as np
import pytest

from repro.configs.rads import QUERIES, EngineConfig
from repro.core import Pattern, canonicalize, enumerate_oracle, rads_enumerate
from repro.graph import (device_formats, device_graph, partition,
                         partition_device, powerlaw_graph)

CFG = EngineConfig(frontier_cap=1 << 13, fetch_cap=512, verify_cap=2048,
                   region_group_budget=1 << 12)


@pytest.fixture(scope="module")
def skewed():
    g = powerlaw_graph(256, 8, seed=2)
    return g, partition(g, 4, method="bfs")


def test_device_formats_registered():
    assert {"dense", "bucketed"} <= set(device_formats())


def test_rows_byte_identical(skewed):
    """The DeviceGraph contract: every format reassembles the same
    sentinel-padded adjacency windows (incl. deg-0 and padding rows)."""
    _, pg = skewed
    dense = device_graph(pg, "dense")
    bucketed = device_graph(pg, "bucketed")
    li = np.arange(pg.stride)
    for t in range(pg.ndev):
        assert np.array_equal(np.asarray(dense.rows_at(t, li)),
                              np.asarray(bucketed.rows_at(t, li))), t
        assert np.array_equal(np.asarray(dense.deg_at(t, li)),
                              np.asarray(bucketed.deg_at(t, li))), t
    # multi-dim index shape (the exchange answer paths gather 2-D blocks)
    li2 = np.arange(min(16, pg.stride)).reshape(4, -1)
    assert np.array_equal(np.asarray(dense.rows_at(1, li2)),
                          np.asarray(bucketed.rows_at(1, li2)))


@pytest.mark.parametrize("qname", ["q1", "q3"])
def test_backend_parity_powerlaw(skewed, qname):
    """sim == gather == oracle on a skewed graph, for both storage
    formats, with byte-identical counts and traffic accounting."""
    g, pg = skewed
    pat = Pattern.from_edges(QUERIES[qname])
    oracle = canonicalize(enumerate_oracle(g, pat), pat)
    ref = None
    for fmt in ("dense", "bucketed"):
        for mode in ("sim", "gather"):
            cfg = dataclasses.replace(CFG, storage_format=fmt)
            res = rads_enumerate(pg, pat, cfg, mode=mode)
            assert canonicalize(res.embeddings, pat) == oracle, (fmt, mode)
            key = (res.count, res.stats["bytes_fetch"],
                   res.stats["bytes_verify"])
            ref = ref or key
            assert key == ref, (fmt, mode)
            assert res.stats["storage_format"] == fmt


def test_bucketed_memory_at_most_quarter_of_dense():
    """Acceptance bar: on a power-law graph (n=4096, avg_deg=8) the
    bucketed adjacency footprint is <= 1/4 of dense."""
    g = powerlaw_graph(4096, 8, seed=1)
    pg, bucketed = partition_device(g, 4, method="bfs", fmt="bucketed")
    dense = device_graph(pg, "dense")
    assert bucketed.adj_bytes * 4 <= dense.adj_bytes, (
        bucketed.adj_bytes, dense.adj_bytes)


def test_pallas_intersect_candidate_generation(skewed):
    """use_pallas_kernels on the bucketed layout routes the back-edge
    candidate refinement through the Pallas intersect kernel (interpret
    mode on CPU) — results must not change."""
    g, pg = skewed
    pat = Pattern.from_edges(QUERIES["q3"])
    oracle = canonicalize(enumerate_oracle(g, pat), pat)
    cfg = dataclasses.replace(CFG, storage_format="bucketed",
                              use_pallas_kernels=True)
    res = rads_enumerate(pg, pat, cfg, mode="sim")
    assert res.count == len(oracle)
    assert canonicalize(res.embeddings, pat) == oracle


def test_auto_pipeline_depth_matches_oracle(skewed):
    """pipeline_depth='auto' (depth steered by per-wave timing stats) must
    stay oracle-exact and record the chosen depth."""
    g, pg = skewed
    pat = Pattern.from_edges(QUERIES["q1"])
    oracle = canonicalize(enumerate_oracle(g, pat), pat)
    cfg = dataclasses.replace(CFG, region_group_budget=64, enable_sme=False,
                              pipeline_depth="auto",
                              storage_format="bucketed")
    res = rads_enumerate(pg, pat, cfg, mode="sim")
    assert canonicalize(res.embeddings, pat) == oracle
    assert res.stats.get("auto_depth", 0) >= 1
    assert res.stats["n_waves"] >= 4


def test_priors_cache_skips_escalations(skewed, tmp_path):
    """Run 1 with tiny caps escalates and persists priors; run 2 preloads
    them and completes with zero mid-enumeration re-jits."""
    g, pg = skewed
    pat = Pattern.from_edges(QUERIES["q1"])
    oracle = canonicalize(enumerate_oracle(g, pat), pat)
    pp = str(tmp_path / "priors.json")
    tiny = EngineConfig(frontier_cap=8, fetch_cap=16, verify_cap=16,
                        region_group_budget=64, priors_path=pp)
    first = rads_enumerate(pg, pat, tiny, mode="sim")
    assert canonicalize(first.embeddings, pat) == oracle
    assert first.stats["cap_escalations"] >= 1
    assert not first.stats["priors_preloaded"]
    second = rads_enumerate(pg, pat, tiny, mode="sim")
    assert canonicalize(second.embeddings, pat) == oracle
    assert second.stats["priors_preloaded"]
    assert second.stats["cap_escalations"] == 0
    caps = second.stats["final_caps"]
    assert caps["frontier"] >= first.stats["final_caps"]["frontier"]


def test_priors_v2_hist_and_depth_roundtrip(skewed, tmp_path):
    """Priors v2: run 1 persists the per-seed node_counts histogram and the
    learned auto pipeline depth; run 2 preloads both (skew-aware p90 wave
    sizing + auto-depth warm start) and stays oracle-exact."""
    from repro.core.priors import hist_percentile, load_priors, priors_key

    g, pg = skewed
    pat = Pattern.from_edges(QUERIES["q1"])
    oracle = canonicalize(enumerate_oracle(g, pat), pat)
    pp = str(tmp_path / "priors.json")
    cfg = dataclasses.replace(CFG, region_group_budget=64, enable_sme=False,
                              pipeline_depth="auto", priors_path=pp)
    first = rads_enumerate(pg, pat, cfg, mode="sim")
    assert canonicalize(first.embeddings, pat) == oracle
    entry = load_priors(pp)[priors_key(pat, pg)]
    assert sum(entry["node_hist"]) > 0          # histogram persisted
    assert entry["pipeline_depth"] >= 1         # learned depth persisted
    assert sum(first.stats["node_hist"]) == sum(entry["node_hist"])
    second = rads_enumerate(pg, pat, cfg, mode="sim")
    assert canonicalize(second.embeddings, pat) == oracle
    assert second.stats["priors_preloaded"]
    assert second.stats["prior_cost_p90"] == hist_percentile(
        entry["node_hist"], 0.90)
