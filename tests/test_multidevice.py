"""Multi-device correctness via subprocess (8 forced host devices):
* SPMD engine (real all_to_all under shard_map) == sim engine == oracle,
  across both DeviceGraph storage formats (dense / bucketed) incl. a
  skewed power-law graph, with foreign-adjacency-cache on/off parity
  (identical counts, sim==spmd hit accounting, byte conservation)
* sharded train step == single-device train step
* compressed_psum == plain psum within quantization error
Each test spawns one python subprocess so the main pytest process keeps the
single real device (see conftest note).
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(code: str) -> dict:
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=1800)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.slow
def test_spmd_engine_matches_oracle():
    res = run_sub(textwrap.dedent("""
        import json, jax, numpy as np
        from repro.graph import erdos_graph, partition
        from repro.core import Pattern, rads_enumerate, enumerate_oracle, canonicalize
        from repro.configs.rads import QUERIES, EngineConfig
        from repro.launch.mesh import make_engine_mesh
        mesh = make_engine_mesh(8)
        cfg = EngineConfig(frontier_cap=1<<12, fetch_cap=256, verify_cap=1024,
                           region_group_budget=1<<11)
        g = erdos_graph(120, 5.0, seed=5)
        pg = partition(g, 8, method='bfs')
        ok = True
        for q in ['q1', 'q2', 'q6']:
            pat = Pattern.from_edges(QUERIES[q])
            oracle = canonicalize(enumerate_oracle(g, pat), pat)
            spmd = rads_enumerate(pg, pat, cfg, mode='spmd', mesh=mesh)
            sim = rads_enumerate(pg, pat, cfg, mode='sim')
            ok &= canonicalize(spmd.embeddings, pat) == oracle
            ok &= canonicalize(sim.embeddings, pat) == oracle
            ok &= spmd.stats['bytes_fetch'] == sim.stats['bytes_fetch']
            ok &= spmd.stats['bytes_verify'] == sim.stats['bytes_verify']
        # storage-format parity on a skewed graph: the bucketed DeviceGraph
        # must be byte-identical to dense through the real all_to_all path
        # (sim/gather x format parity is covered by the fast suite)
        import dataclasses
        from repro.graph import powerlaw_graph
        gp = powerlaw_graph(160, 6, seed=5)
        pgp = partition(gp, 8, method='bfs')
        pat = Pattern.from_edges(QUERIES['q1'])
        oracle = canonicalize(enumerate_oracle(gp, pat), pat)
        ref_bytes = None
        for fmt in ['dense', 'bucketed']:
            cf = dataclasses.replace(cfg, storage_format=fmt)
            spmd = rads_enumerate(pgp, pat, cf, mode='spmd', mesh=mesh)
            ok &= canonicalize(spmd.embeddings, pat) == oracle
            b = (spmd.stats['bytes_fetch'], spmd.stats['bytes_verify'])
            ref_bytes = ref_bytes or b
            ok &= b == ref_bytes
        # multi-group workload: the async staged scheduler must pipeline
        # >= 2 waves through the real all_to_all spmd backend
        import dataclasses
        many = dataclasses.replace(cfg, region_group_budget=64,
                                   enable_sme=False)
        pat = Pattern.from_edges(QUERIES['q1'])
        oracle = canonicalize(enumerate_oracle(g, pat), pat)
        spmd = rads_enumerate(pg, pat, many, mode='spmd', mesh=mesh)
        ok &= canonicalize(spmd.embeddings, pat) == oracle
        inflight = spmd.stats['max_inflight_waves']
        # adjacency-cache parity through the sharded (shard_map) path:
        # cache-on == cache-off == oracle, the sim/spmd hit accounting is
        # identical (same host wave schedule), and the conservation law
        # bytes_fetch(on) + bytes_saved_cache == bytes_fetch(off) holds
        pat = Pattern.from_edges(QUERIES['q3'])
        oracle = canonicalize(enumerate_oracle(gp, pat), pat)
        ccfg = dataclasses.replace(cfg, enable_sme=False,
                                   region_group_budget=256,
                                   storage_format='bucketed')
        c_on = rads_enumerate(pgp, pat, ccfg, mode='spmd', mesh=mesh)
        c_off = rads_enumerate(
            pgp, pat, dataclasses.replace(ccfg, enable_cache=False),
            mode='spmd', mesh=mesh)
        c_sim = rads_enumerate(pgp, pat, ccfg, mode='sim')
        ok &= canonicalize(c_on.embeddings, pat) == oracle
        ok &= canonicalize(c_off.embeddings, pat) == oracle
        ok &= c_on.count == c_off.count == c_sim.count
        ok &= (c_on.stats['bytes_fetch'] + c_on.stats['bytes_saved_cache']
               == c_off.stats['bytes_fetch'])
        ok &= c_on.stats['cache_hits'] == c_sim.stats['cache_hits']
        ok &= c_on.stats['bytes_fetch'] == c_sim.stats['bytes_fetch']
        cache_hits = c_on.stats['cache_hits']
        print(json.dumps(dict(ok=bool(ok), inflight=int(inflight),
                              cache_hits=float(cache_hits))))
    """))
    assert res["ok"]
    assert res["inflight"] >= 2
    assert res["cache_hits"] > 0


@pytest.mark.slow
def test_spmd_wire_varint_matches_sim():
    """wire='varint' through the real all_to_all shard_map path: coded u8
    streams cross the collective, results match sim/oracle, and the actual
    stream-byte accounting is identical to the sim backend (deterministic
    codecs + identical wave schedule)."""
    res = run_sub(textwrap.dedent("""
        import dataclasses, json
        from repro.graph import partition, powerlaw_graph
        from repro.core import (Pattern, rads_enumerate, enumerate_oracle,
                                canonicalize)
        from repro.configs.rads import QUERIES, EngineConfig
        from repro.launch.mesh import make_engine_mesh
        mesh = make_engine_mesh(8)
        g = powerlaw_graph(160, 6, seed=5)
        pg = partition(g, 8, method='hash')
        cfg = EngineConfig(frontier_cap=1<<12, fetch_cap=256,
                           verify_cap=1024, region_group_budget=256,
                           enable_sme=False, wire_format='varint')
        ok = True
        for q in ['q1', 'q3']:
            pat = Pattern.from_edges(QUERIES[q])
            oracle = canonicalize(enumerate_oracle(g, pat), pat)
            spmd = rads_enumerate(pg, pat, cfg, mode='spmd', mesh=mesh)
            sim = rads_enumerate(pg, pat, cfg, mode='sim')
            raw = rads_enumerate(
                pg, pat, dataclasses.replace(cfg, wire_format='raw'),
                mode='spmd', mesh=mesh)
            ok &= canonicalize(spmd.embeddings, pat) == oracle
            ok &= canonicalize(sim.embeddings, pat) == oracle
            ok &= spmd.count == sim.count == raw.count
            ok &= (spmd.stats['bytes_wire_fetch']
                   == sim.stats['bytes_wire_fetch'])
            ok &= (spmd.stats['bytes_wire_verify']
                   == sim.stats['bytes_wire_verify'])
            ok &= (spmd.stats['bytes_wire_fetch']
                   <= spmd.stats['bytes_fetch'])
            ok &= (spmd.stats['bytes_wire_verify']
                   < raw.stats['bytes_wire_verify'])
        print(json.dumps(dict(ok=bool(ok))))
    """))
    assert res["ok"]


@pytest.mark.slow
@pytest.mark.parametrize("wire,cache", [("raw", False), ("raw", True),
                                        ("varint", False),
                                        ("varint", True)])
def test_dist_two_process_matches_sim(wire, cache):
    """The ``dist`` backend across two real OS processes (jax.distributed +
    gloo CPU collectives) is byte-identical to the in-process ``sim``
    backend on the same partitioned graph: counts, ``bytes_wire_*`` scalar
    totals, per-device attribution sums, and cache hit accounting.  Skips
    cleanly when the jaxlib build cannot bootstrap multi-process CPU."""
    import dataclasses

    from repro.configs.rads import QUERIES
    from repro.core import Pattern, rads_enumerate
    from repro.graph import load_dataset, partition
    from repro.launch.dist_worker import (build_argparser, dist_available,
                                          launch_local, worker_config)

    if not dist_available():
        pytest.skip("jaxlib lacks gloo CPU collectives")
    wargs = ["--dataset", "dblp_bench", "--query", "q1",
             "--partition", "hash", "--wire", wire,
             "--frontier-cap", str(1 << 12), "--fetch-cap", str(1 << 9),
             "--verify-cap", str(1 << 11), "--region-budget", str(1 << 11)]
    if not cache:
        wargs.append("--no-cache")
    workers = launch_local(2, wargs, timeout_s=900.0)
    if workers is None:
        pytest.skip("multi-process bootstrap unavailable at runtime")
    assert len(workers) == 2

    cfg = worker_config(build_argparser().parse_args(wargs))
    if cfg.pipeline_depth == "auto":
        cfg = dataclasses.replace(cfg, pipeline_depth=2)
    pg = partition(load_dataset("dblp_bench"), 2, method="hash")
    sim = rads_enumerate(pg, Pattern.from_edges(QUERIES["q1"]), cfg,
                         mode="sim", return_embeddings=False)
    assert sim.count > 0
    for w in workers:
        st = w["stats"]
        assert int(w["count"]) == sim.count
        for phase in ("fetch", "verify"):
            assert (float(st[f"bytes_wire_{phase}"])
                    == float(sim.stats[f"bytes_wire_{phase}"]))
            # per-device attribution is complete: rows sum to the total
            assert (float(sum(st[f"bytes_wire_{phase}_dev"]))
                    == float(sim.stats[f"bytes_wire_{phase}"]))
        assert float(st["bytes_fetch"]) == float(sim.stats["bytes_fetch"])
        assert float(st["cache_hits"]) == float(sim.stats["cache_hits"])
        if cache:
            assert (float(st["bytes_fetch"])
                    + float(st["bytes_saved_cache"])
                    == float(sim.stats["bytes_fetch"])
                    + float(sim.stats["bytes_saved_cache"]))


@pytest.mark.slow
def test_sharded_train_matches_single_device():
    res = run_sub(textwrap.dedent("""
        import json, jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_reduced
        from repro.models.transformer import init_lm_params, lm_loss
        from repro.distributed.sharding import param_shardings
        from repro.launch.mesh import make_mesh
        cfg = get_reduced('qwen3-4b')
        params = init_lm_params(jax.random.PRNGKey(0), cfg)
        toks = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, cfg.vocab)
        lbls = jnp.roll(toks, -1, axis=1)
        loss_plain = float(lm_loss(params, cfg, toks, lbls))
        mesh = make_mesh((4, 2), ('data', 'model'))
        with mesh:
            psh = param_shardings(params, 'lm', mesh)
            pp = jax.tree.map(jax.device_put, params, psh)
            tsh = NamedSharding(mesh, P('data', None))
            lg = NamedSharding(mesh, P('data', None, 'model'))
            hd = NamedSharding(mesh, P('data', None, None))
            loss_sh = float(jax.jit(
                lambda p, t, l: lm_loss(p, cfg, t, l, logits_sharding=lg,
                                        hidden_sharding=hd))(
                pp, jax.device_put(toks, tsh), jax.device_put(lbls, tsh)))
        rel = abs(loss_plain - loss_sh) / max(abs(loss_plain), 1e-9)
        print(json.dumps(dict(rel=rel)))
    """))
    assert res["rel"] < 2e-2   # bf16 reduction-order tolerance


@pytest.mark.slow
def test_compressed_psum_close_to_exact():
    res = run_sub(textwrap.dedent("""
        import json, jax, jax.numpy as jnp, numpy as np
        from repro.distributed.compression import compressed_psum
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((8,), ('pod',))
        x = jax.random.normal(jax.random.PRNGKey(0), (8, 64))
        from jax.sharding import NamedSharding, PartitionSpec as P
        xs = jax.device_put(x, NamedSharding(mesh, P('pod', None)))
        # exact: every row becomes the column-sum
        want = np.broadcast_to(np.asarray(x).sum(0, keepdims=True), x.shape)
        got = np.asarray(compressed_psum(xs, 'pod', mesh))
        err = np.abs(got - want).max() / np.abs(want).max()
        print(json.dumps(dict(err=float(err))))
    """))
    assert res["err"] < 0.05


@pytest.mark.slow
def test_dryrun_entrypoint_smallest_cell():
    """The actual dryrun module runs end to end (512 devices) for one cell."""
    env = dict(os.environ, PYTHONPATH=SRC,
               DRYRUN_ARTIFACTS="/tmp/dryrun_test_artifacts")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "gat-cora",
         "--shape", "molecule", "--mesh", "single"],
        env=env, capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "all dry-runs passed" in out.stdout
