"""Embedding trie (§5): paper Example 6 fixture + property tests."""
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # hermetic container: vendored fallback
    from _hypothesis_compat import given, settings, strategies as st

from repro.core.trie import EmbeddingTrie, compression_report


def test_paper_example_6():
    rows = np.array([[0, 1, 2], [0, 1, 9], [0, 9, 11]])
    t = EmbeddingTrie.from_rows(rows)
    # Figure 5(a): 1 root + 2 level-1 + 3 level-2 nodes
    assert [lv.n_alive for lv in t.levels] == [1, 2, 3]
    assert t.n_nodes == 6
    got = {tuple(r) for r in t.materialize().tolist()}
    assert got == {(0, 1, 2), (0, 1, 9), (0, 9, 11)}
    # remove (0,1,9) -> Figure 5(b): 5 nodes
    for lid in np.flatnonzero(t.levels[-1].alive):
        cur, path = int(lid), []
        for lvl in range(2, -1, -1):
            path.append(int(t.levels[lvl].vertex[cur]))
            cur = t.levels[lvl].parent[cur]
        if path[::-1] == [0, 1, 9]:
            t.remove_result(int(lid))
            break
    assert t.n_nodes == 5
    got = {tuple(r) for r in t.materialize().tolist()}
    assert got == {(0, 1, 2), (0, 9, 11)}


def test_cascade_removal_frees_whole_branch():
    rows = np.array([[0, 1, 2], [5, 6, 7]])
    t = EmbeddingTrie.from_rows(rows)
    assert t.n_nodes == 6
    leaf = int(np.flatnonzero(t.levels[-1].alive)[0])
    t.remove_result(leaf)
    assert t.n_nodes == 3         # entire branch cascaded away
    assert t.n_results == 1


@given(st.lists(st.tuples(st.integers(0, 8), st.integers(0, 8),
                          st.integers(0, 8)),
                min_size=1, max_size=60))
@settings(max_examples=50, deadline=None)
def test_property_roundtrip(rows_list):
    rows = np.unique(np.array(rows_list, dtype=np.int32), axis=0)
    t = EmbeddingTrie.from_rows(rows)
    back = t.materialize()
    assert {tuple(r) for r in back.tolist()} == \
        {tuple(r) for r in rows.tolist()}
    assert t.n_results == rows.shape[0]
    # prefix sharing: level sizes == distinct prefixes
    for lvl in range(rows.shape[1]):
        assert t.levels[lvl].n_alive == \
            np.unique(rows[:, :lvl + 1], axis=0).shape[0]


@given(st.lists(st.tuples(st.integers(0, 5), st.integers(0, 5),
                          st.integers(0, 5)),
                min_size=2, max_size=40),
       st.data())
@settings(max_examples=30, deadline=None)
def test_property_removal_consistency(rows_list, data):
    rows = np.unique(np.array(rows_list, dtype=np.int32), axis=0)
    t = EmbeddingTrie.from_rows(rows)
    alive = list(np.flatnonzero(t.levels[-1].alive))
    kill = data.draw(st.sampled_from(alive))
    # identify the row being killed
    cur, path = int(kill), []
    for lvl in range(rows.shape[1] - 1, -1, -1):
        path.append(int(t.levels[lvl].vertex[cur]))
        cur = t.levels[lvl].parent[cur]
    victim = tuple(path[::-1])
    t.remove_result(int(kill))
    got = {tuple(r) for r in t.materialize().tolist()}
    assert got == {tuple(r) for r in rows.tolist()} - {victim}
    # childCount invariant: alive inner node => childCount == alive children
    for lvl in range(rows.shape[1] - 1):
        cc = np.zeros(len(t.levels[lvl].vertex), dtype=int)
        nxt = t.levels[lvl + 1]
        for j in np.flatnonzero(nxt.alive):
            cc[nxt.parent[j]] += 1
        for i in np.flatnonzero(t.levels[lvl].alive):
            assert cc[i] == t.levels[lvl].child_count[i]


def test_compression_on_shared_prefixes():
    # rows with heavy prefix sharing compress well (Tables 3-4 behaviour);
    # a trie node costs 12B vs 4B per flat entry, so wins need depth
    base = np.arange(256)
    rows = np.stack([np.zeros(256, int), base // 64, base // 16,
                     base // 4, base], axis=1)
    rep = compression_report(rows)
    assert rep["et_bytes"] < rep["el_bytes"]
