"""Async wave scheduler: double-buffered pipelining, the overflow
split / capacity-escalation robustness loop, steal-from-longest queue
rebalancing, and the Pallas membership gate — all against the oracle."""
import dataclasses

import numpy as np
import pytest

from repro import compat
from repro.configs.rads import QUERIES, EngineConfig
from repro.core import (Pattern, PipelineScheduler, StageRunner, best_plan,
                        canonicalize, enumerate_oracle, rads_enumerate)
from repro.core.engine import build_plan_data
from repro.core.exchange import Exchange
from repro.graph import device_graph, erdos_graph, partition

# region_group_budget=64 => many small region groups per device — the
# multi-group workload the pipeline needs to show overlap.
CFG = EngineConfig(frontier_cap=1 << 13, fetch_cap=512, verify_cap=2048,
                   region_group_budget=64, enable_sme=False)


@pytest.fixture(scope="module")
def erdos():
    g = erdos_graph(150, 5.0, seed=3)
    return g, partition(g, 4, method="bfs")


def test_async_pipeline_two_inflight_matches_oracle(erdos):
    """The tentpole invariant: with pipeline_depth=2 and a multi-group
    workload, >= 2 waves are in flight and results stay oracle-exact."""
    g, pg = erdos
    pat = Pattern.from_edges(QUERIES["q1"])
    oracle = canonicalize(enumerate_oracle(g, pat), pat)
    res = rads_enumerate(pg, pat, CFG, mode="sim")
    assert res.count == len(oracle)
    assert canonicalize(res.embeddings, pat) == oracle
    assert res.stats["n_waves"] >= 4
    assert res.stats["max_inflight_waves"] >= 2
    assert res.stats["wave_s_total"] > 0.0
    assert res.stats["dist_pipeline_s"] > 0.0


@pytest.mark.parametrize("qname", ["q1", "q3"])
def test_sync_equals_async(erdos, qname):
    """pipeline_depth=1 (the old synchronous loop) and depth=2 must be
    byte-identical: counts, embeddings, and logical traffic accounting.

    The adjacency cache is disabled here: cache state is sequenced through
    fetches in *dispatch* order, so the wire traffic (never the results —
    see test_cache.py::test_sync_equals_async_with_cache) legitimately
    depends on the pipeline depth when the cache is on."""
    g, pg = erdos
    pat = Pattern.from_edges(QUERIES[qname])
    nocache = dataclasses.replace(CFG, enable_cache=False)
    sync = rads_enumerate(pg, pat,
                          dataclasses.replace(nocache, pipeline_depth=1),
                          mode="sim")
    anc = rads_enumerate(pg, pat, nocache, mode="sim")
    assert sync.count == anc.count
    assert canonicalize(sync.embeddings, pat) == canonicalize(
        anc.embeddings, pat)
    assert sync.stats["bytes_fetch"] == anc.stats["bytes_fetch"]
    assert sync.stats["bytes_verify"] == anc.stats["bytes_verify"]
    assert sync.stats["max_inflight_waves"] == 1


def test_gather_async_matches_oracle(erdos):
    g, pg = erdos
    pat = Pattern.from_edges(QUERIES["q2"])
    oracle = canonicalize(enumerate_oracle(g, pat), pat)
    res = rads_enumerate(pg, pat, CFG, mode="gather")
    assert canonicalize(res.embeddings, pat) == oracle


def test_robustness_split_and_escalation(erdos):
    """Deliberately tiny capacities must force >= 1 region-group split AND
    >= 1 capacity escalation — and the final result stays oracle-exact
    (§6: memory control is a robustness mechanism, not an error path)."""
    g, pg = erdos
    pat = Pattern.from_edges(QUERIES["q1"])
    tiny = EngineConfig(frontier_cap=8, fetch_cap=16, verify_cap=16,
                        region_group_budget=64)
    oracle = canonicalize(enumerate_oracle(g, pat), pat)
    res = rads_enumerate(pg, pat, tiny, mode="sim")
    assert canonicalize(res.embeddings, pat) == oracle
    assert res.stats["overflow_retries"] >= 1
    assert res.stats["cap_escalations"] >= 1
    assert res.stats["final_caps"]["frontier"] > 8


def test_steal_from_longest_queue():
    """Drive the scheduler directly with deliberately imbalanced per-device
    group queues: the drained devices must steal from the longest queue
    (checkR/shareR) and the union of wave counts must equal the oracle."""
    g = erdos_graph(120, 5.0, seed=9)
    pg = partition(g, 4, method="bfs")
    pat = Pattern.from_edges(QUERIES["q1"])
    oracle = canonicalize(enumerate_oracle(g, pat), pat)
    plan = best_plan(pat)
    pd = build_plan_data(plan)
    cfg = EngineConfig(frontier_cap=1 << 13, fetch_cap=512, verify_cap=2048)
    runner = StageRunner(device_graph(pg, "dense"), pd, cfg, Exchange("sim"))

    # every candidate seed exactly once, packed into groups of 8 that all
    # start on device 0 — devices 1..3 drain immediately and must steal
    seeds = np.flatnonzero(pg.deg.reshape(-1) >= pd.start_deg)
    groups = [seeds[i:i + 8].astype(np.int64)
              for i in range(0, len(seeds), 8)]
    queues = [list(groups), [], [], []]

    total = 0
    stats = dict(overflow_retries=0, cap_escalations=0, n_waves=0,
                 max_inflight_waves=0, steal_events=0, wave_s_total=0.0)

    def consume(rows, alive, counts, st, phase):
        nonlocal total
        total += int(np.asarray(counts).sum())

    sched = PipelineScheduler(runner, stats, consume)
    sched.run(queues, scap=16, local_only=False, phase="dist")
    assert total == len(oracle)
    assert stats["steal_events"] >= 1
    assert stats["max_inflight_waves"] >= 2


def test_steal_disabled_same_results(erdos):
    g, pg = erdos
    pat = Pattern.from_edges(QUERIES["q1"])
    a = rads_enumerate(pg, pat, CFG, mode="sim")
    b = rads_enumerate(pg, pat,
                       dataclasses.replace(CFG, steal_from_longest=False),
                       mode="sim")
    assert canonicalize(a.embeddings, pat) == canonicalize(b.embeddings, pat)


@pytest.mark.skipif(not compat.HAS_EXECUTABLE_SERIALIZATION,
                    reason="jax build cannot serialize executables")
def test_warm_run_zero_compiles(erdos, tmp_path):
    """With a populated persistent executable store, a brand-new
    StageRunner performs ZERO stage traces/compiles — the whole warm run
    is executable dispatch (the PR-7 latency-floor invariant), and the
    results stay byte-identical with the traced cold run."""
    from repro.runtime.compile_cache import StageExecCache

    g, pg = erdos
    pat = Pattern.from_edges(QUERIES["q1"])
    cfg = dataclasses.replace(CFG, compile_cache_dir=str(tmp_path / "ex"))
    cold = rads_enumerate(pg, pat, cfg, mode="sim")
    assert cold.stats["exec_cache_enabled"]
    assert cold.stats["compiles"] > 0
    assert cold.stats["exec_cache"]["stores"] == cold.stats["compiles"]
    StageExecCache.clear_memory_memo()       # force on-disk deserialization
    warm = rads_enumerate(pg, pat, cfg, mode="sim")
    assert warm.count == cold.count
    assert canonicalize(warm.embeddings, pat) == canonicalize(
        cold.embeddings, pat)
    assert warm.stats["compiles"] == 0
    assert warm.stats["compile_s"] == 0.0
    assert warm.stats["compile_cache_hits"] > 0
    assert warm.stats["exec_cache"]["misses"] == 0


def test_prewarm_escalation_rung_precompiles_next_caps():
    """``prewarm(escalation_rungs=1)`` resolves the stage ladder one
    capacity rung *above* the live caps; a subsequent ``escalate()`` then
    finds every stage already in the slot table (zero new compiles on the
    escalation path), and the old rung's slots survive for in-flight
    waves."""
    g = erdos_graph(80, 4.0, seed=2)
    pg = partition(g, 2, method="bfs")
    pat = Pattern.from_edges(QUERIES["q1"])
    pd = build_plan_data(best_plan(pat))
    cfg = EngineConfig(frontier_cap=1 << 8, fetch_cap=64, verify_cap=128,
                       region_group_budget=256)
    runner = StageRunner(device_graph(pg, "dense"), pd, cfg, Exchange("sim"))

    n0 = runner.prewarm(scap=16, local_only=False)
    assert n0 > 0
    base_key = (cfg.frontier_cap, cfg.fetch_cap, cfg.verify_cap)
    assert base_key in {k[1] for k in runner._slots if k[1]}

    n1 = runner.prewarm(scap=16, local_only=False, escalation_rungs=1)
    assert n1 > n0                  # base rung re-walked + one rung above
    esc = runner._escalated(cfg)
    esc_key = (esc.frontier_cap, esc.fetch_cap, esc.verify_cap)
    assert esc_key in {k[1] for k in runner._slots if k[1]}

    compiles_before = runner.compiles
    assert runner.escalate()
    # the slot table survives escalation: both rungs still resolvable
    keys = {k[1] for k in runner._slots if k[1]}
    assert base_key in keys and esc_key in keys
    # re-warming the escalated rung is pure slot hits — no new compiles
    assert runner.prewarm(scap=16, local_only=False) > 0
    assert runner.compiles == compiles_before
    """use_pallas_kernels routes the back-edge / verifyE membership tests
    through the Pallas kernel (interpret mode on CPU) — results must not
    change."""
    g = erdos_graph(60, 4.0, seed=7)
    pg = partition(g, 3, method="bfs")
    pat = Pattern.from_edges(QUERIES["q3"])
    oracle = canonicalize(enumerate_oracle(g, pat), pat)
    cfg = EngineConfig(frontier_cap=1 << 11, fetch_cap=256, verify_cap=512,
                       region_group_budget=1 << 10, use_pallas_kernels=True)
    res = rads_enumerate(pg, pat, cfg, mode="sim")
    assert res.count == len(oracle)
    assert canonicalize(res.embeddings, pat) == oracle
