"""Per-architecture smoke tests (deliverable f): reduced config, one
forward/train step on CPU, asserting output shapes + no NaNs. The FULL
configs are exercised only via the dry-run."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_reduced

LM_ARCHS = ["qwen1.5-0.5b", "qwen3-14b", "qwen3-4b", "olmoe-1b-7b",
            "deepseek-v3-671b"]
GNN_ARCHS = ["graphcast", "schnet", "pna", "gat-cora"]


def _finite(tree):
    return all(bool(jnp.isfinite(x.astype(jnp.float32)).all())
               for x in jax.tree.leaves(tree) if hasattr(x, "dtype")
               and jnp.issubdtype(x.dtype, jnp.floating))


def test_registry_covers_assignment():
    assert len(ARCH_IDS) == 10
    assert sum(len(get_config(a).shapes) for a in ARCH_IDS) == 40


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke(arch):
    from repro.models.transformer import (decode_step, init_lm_params,
                                          lm_forward, lm_loss, prefill)
    cfg = get_reduced(arch)
    key = jax.random.PRNGKey(0)
    params = init_lm_params(key, cfg)
    tokens = jax.random.randint(key, (2, 16), 0, cfg.vocab)
    labels = jnp.roll(tokens, -1, axis=1)
    logits, aux, hidden = jax.jit(
        lambda p, t: lm_forward(p, cfg, t))(params, tokens)
    assert logits.shape == (2, 16, cfg.vocab)
    assert _finite(dict(l=logits.astype(jnp.float32)))
    loss, grads = jax.value_and_grad(
        lambda p: lm_loss(p, cfg, tokens, labels))(params)
    assert jnp.isfinite(loss) and _finite(grads)
    lg, cache = jax.jit(lambda p, t: prefill(p, cfg, t, max_len=20))(
        params, tokens)
    step_lg, cache = jax.jit(
        lambda p, c, t: decode_step(p, cfg, c, t, jnp.int32(16)))(
        params, cache, tokens[:, -1])
    assert step_lg.shape == (2, cfg.vocab)
    assert _finite(dict(x=step_lg.astype(jnp.float32)))


def test_mla_absorbed_decode_matches_naive():
    from repro.models.transformer import (decode_step, init_lm_params, prefill)
    cfg = get_reduced("deepseek-v3-671b")
    params = init_lm_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab)
    _, cache = prefill(params, cfg, tokens, max_len=16)
    a, _ = decode_step(params, cfg, cache, tokens[:, -1], jnp.int32(12),
                       absorbed=False)
    b, _ = decode_step(params, cfg, cache, tokens[:, -1], jnp.int32(12),
                       absorbed=True)
    a32, b32 = np.asarray(a, np.float32), np.asarray(b, np.float32)
    rel = np.abs(a32 - b32).max() / max(np.abs(a32).max(), 1e-6)
    assert rel < 0.05  # bf16 path, different contraction order


@pytest.mark.parametrize("arch", GNN_ARCHS)
def test_gnn_smoke(arch):
    from repro.models.gnn import GraphBatch, gnn_forward, gnn_loss, init_gnn
    cfg = get_reduced(arch)
    key = jax.random.PRNGKey(0)
    N, E, F, n_out = 40, 160, 12, 7
    gb = GraphBatch(
        node_feats=jax.random.normal(key, (N, F)),
        edge_src=jax.random.randint(key, (E,), 0, N),
        edge_dst=jax.random.randint(jax.random.PRNGKey(1), (E,), 0, N),
        edge_mask=jnp.ones((E,), bool),
        labels=(jax.random.normal(key, (N, cfg.n_vars))
                if cfg.kind == "graphcast"
                else jax.random.normal(key, (N,)) if cfg.kind == "schnet"
                else jax.random.randint(key, (N,), 0, n_out)),
        label_mask=jnp.ones((N,), bool),
        positions=jax.random.normal(key, (N, 3)) * 2.0)
    params = init_gnn(key, cfg, F, n_out)
    out = jax.jit(lambda p: gnn_forward(p, cfg, gb))(params)
    expect_last = (cfg.n_vars if cfg.kind == "graphcast"
                   else None if cfg.kind == "schnet" else n_out)
    if cfg.kind == "schnet":
        assert out.shape == (N,)
    else:
        assert out.shape == (N, expect_last)
    loss, grads = jax.value_and_grad(lambda p: gnn_loss(p, cfg, gb))(params)
    assert jnp.isfinite(loss) and _finite(grads)


def test_schnet_molecule_batch_readout():
    from repro.models.gnn import GraphBatch, gnn_loss, init_gnn
    cfg = get_reduced("schnet")
    key = jax.random.PRNGKey(0)
    B, n, e = 4, 10, 18
    N, E = B * n, B * e
    src = jnp.concatenate([jax.random.randint(key, (e,), 0, n) + b * n
                           for b in range(B)])
    dst = jnp.concatenate([jax.random.randint(
        jax.random.PRNGKey(b), (e,), 0, n) + b * n for b in range(B)])
    gb = GraphBatch(
        node_feats=jax.random.normal(key, (N, 8)),
        edge_src=src, edge_dst=dst, edge_mask=jnp.ones((E,), bool),
        labels=jax.random.normal(key, (B,)),      # per-graph energy
        label_mask=jnp.ones((N,), bool),
        positions=jax.random.normal(key, (N, 3)) * 2.0,
        graph_id=jnp.repeat(jnp.arange(B), n))
    params = init_gnn(key, cfg, 8, 1)
    loss, grads = jax.value_and_grad(lambda p: gnn_loss(p, cfg, gb))(params)
    assert jnp.isfinite(loss) and _finite(grads)


def test_din_smoke():
    from repro.models.recsys import (DINBatch, din_logits, din_loss, init_din,
                                     retrieval_scores)
    cfg = get_reduced("din")
    key = jax.random.PRNGKey(0)
    B, T = 16, cfg.seq_len
    batch = DINBatch(
        user_feats=jax.random.randint(key, (B, 4), 0, cfg.n_user_feats),
        target_item=jax.random.randint(key, (B,), 0, cfg.n_items),
        target_cate=jax.random.randint(key, (B,), 0, cfg.n_cates),
        hist_items=jax.random.randint(key, (B, T), 0, cfg.n_items),
        hist_cates=jax.random.randint(key, (B, T), 0, cfg.n_cates),
        hist_mask=jnp.ones((B, T), bool),
        labels=jax.random.bernoulli(key, 0.5, (B,)).astype(jnp.float32))
    params = init_din(key, cfg)
    lg = jax.jit(lambda p: din_logits(p, cfg, batch))(params)
    assert lg.shape == (B,) and _finite(dict(x=lg.astype(jnp.float32)))
    loss, grads = jax.value_and_grad(lambda p: din_loss(p, cfg, batch))(params)
    assert jnp.isfinite(loss) and _finite(grads)
    sc = retrieval_scores(params, cfg, batch, jnp.arange(64),
                          jnp.arange(64) % cfg.n_cates)
    assert sc.shape == (B, 64)


def test_embedding_bag_modes():
    from repro.models.recsys import embedding_bag
    table = jnp.arange(20, dtype=jnp.float32).reshape(10, 2)
    ids = jnp.array([1, 2, 3, 7])
    seg = jnp.array([0, 0, 1, 1])
    s = embedding_bag(table, ids, seg, 2, mode="sum")
    m = embedding_bag(table, ids, seg, 2, mode="mean")
    np.testing.assert_allclose(np.asarray(s[0]), np.asarray(table[1] + table[2]))
    np.testing.assert_allclose(np.asarray(m[1]),
                               np.asarray((table[3] + table[7]) / 2))
