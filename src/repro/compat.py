"""Single source for version-drifting JAX APIs.

JAX moves fast and this repo has to run on whatever the container ships:

* ``shard_map`` lived in ``jax.experimental.shard_map`` before being
  promoted to ``jax.shard_map``;
* ``jax.make_mesh`` predates its ``axis_types`` kwarg, and
  ``jax.sharding.AxisType`` does not exist at all on 0.4.x;
* ``Compiled.cost_analysis()`` returned a one-element *list* of dicts on
  0.4.x and a plain dict later;
* the ``jax.tree`` namespace (``jax.tree.map`` & co) replaced the older
  ``jax.tree_util`` spellings;
* compiled-executable persistence moved around: 0.4.x ships
  ``jax.experimental.serialize_executable`` (pickles the underlying XLA
  executable — true zero-recompile loads) while ``jax.export`` (also
  present on 0.4.37) round-trips StableHLO that still needs an XLA compile
  on load.  The AOT stage-executable cache
  (:mod:`repro.runtime.compile_cache`) needs the former; both probes
  degrade to ``None``/``False`` so the cache silently disables itself on
  JAX builds without executable serialization;
* multi-process CPU collectives: 0.4.x CPU backends only run cross-process
  computations when the gloo TCP collectives implementation is selected
  *before the backend client is created* (``jax.config.update(
  "jax_cpu_collectives_implementation", "gloo")``) — newer builds default
  to it, older ones lack it entirely.  The ``dist`` exchange backend
  (:mod:`repro.core.exchange`) and its launcher go through
  :func:`enable_cpu_collectives` / :func:`distributed_initialize` so the
  whole bootstrap quirk surface stays in this file, and
  ``host_local_array_to_global_array`` (the only blessed way to build a
  process-global array from per-host values on 0.4.x) is wrapped by
  :func:`global_shard` / :func:`global_replicate`.

Every call-site in this repo imports the resolved symbol from here, so a
JAX upgrade touches exactly this file.  Probes run once at import time and
degrade gracefully (stub or fallback) rather than raising.

Supported range: jax>=0.4.30,<0.6 (see pyproject.toml).
"""
from __future__ import annotations

import inspect

import jax
from jax.sharding import Mesh, PartitionSpec

__all__ = [
    "AxisType", "HAS_AXIS_TYPES", "default_axis_types", "make_mesh",
    "shard_map", "tree_map", "tree_leaves", "tree_reduce",
    "tree_map_with_path", "with_sharding_constraint", "cost_analysis",
    "memory_analysis", "HAS_EXECUTABLE_SERIALIZATION", "serialize_compiled",
    "deserialize_compiled", "version_stamp", "HAS_MULTIPROCESS_CPU",
    "enable_cpu_collectives", "distributed_initialize", "process_index",
    "process_count", "global_shard", "global_replicate",
]


# --------------------------------------------------------------------------- #
# shard_map: jax.shard_map (>=0.5) vs jax.experimental.shard_map (0.4.x)
# --------------------------------------------------------------------------- #
if hasattr(jax, "shard_map"):                                # pragma: no cover
    _shard_map_impl = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map as _shard_map_impl


def shard_map(f, *, mesh: Mesh, in_specs, out_specs):
    """Version-stable ``shard_map``: keyword-only, the common-subset
    signature both implementations accept."""
    return _shard_map_impl(f, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs)


# --------------------------------------------------------------------------- #
# Mesh construction: AxisType landed well after jax.make_mesh
# --------------------------------------------------------------------------- #
try:
    from jax.sharding import AxisType  # type: ignore[attr-defined]
    HAS_AXIS_TYPES = True
except ImportError:
    class AxisType:  # minimal stand-in so callers can always name the enum
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"
    HAS_AXIS_TYPES = False


def default_axis_types(n_axes: int) -> tuple:
    """``(AxisType.Auto,) * n_axes`` — or the stub equivalent pre-AxisType."""
    return (AxisType.Auto,) * n_axes


_MAKE_MESH_KWARGS = (set(inspect.signature(jax.make_mesh).parameters)
                     if hasattr(jax, "make_mesh") else set())


def make_mesh(axis_shapes: tuple[int, ...], axis_names: tuple[str, ...],
              *, axis_types: tuple | None = None, devices=None) -> Mesh:
    """``jax.make_mesh`` across versions.

    ``axis_types`` is forwarded only where the installed JAX understands it
    (it is a compiler hint, not a semantics change — dropping it is safe on
    versions where every axis is implicitly Auto).  Pre-``jax.make_mesh``
    versions fall back to ``mesh_utils.create_device_mesh`` + ``Mesh``.
    """
    if hasattr(jax, "make_mesh"):
        kw = {}
        if devices is not None:
            kw["devices"] = devices
        if axis_types is not None and HAS_AXIS_TYPES \
                and "axis_types" in _MAKE_MESH_KWARGS:
            kw["axis_types"] = axis_types
        return jax.make_mesh(axis_shapes, axis_names, **kw)
    from jax.experimental import mesh_utils                  # pragma: no cover
    devs = mesh_utils.create_device_mesh(axis_shapes, devices=devices)
    return Mesh(devs, axis_names)


# --------------------------------------------------------------------------- #
# Tree utilities: jax.tree namespace vs jax.tree_util
# --------------------------------------------------------------------------- #
if hasattr(jax, "tree") and hasattr(jax.tree, "map"):
    tree_map = jax.tree.map
    tree_leaves = jax.tree.leaves
    tree_reduce = jax.tree.reduce
else:                                                        # pragma: no cover
    tree_map = jax.tree_util.tree_map
    tree_leaves = jax.tree_util.tree_leaves
    tree_reduce = jax.tree_util.tree_reduce

tree_map_with_path = jax.tree_util.tree_map_with_path


# --------------------------------------------------------------------------- #
# Sharding constraint that degrades to identity outside a mesh context
# --------------------------------------------------------------------------- #
def with_sharding_constraint(x, *spec):
    """``lax.with_sharding_constraint`` or identity when no mesh is active
    (single-device tests) — the historical behaviour also differs across
    versions in *which* exception is raised, hence the broad except."""
    try:
        return jax.lax.with_sharding_constraint(x, PartitionSpec(*spec))
    except Exception:
        return x


# --------------------------------------------------------------------------- #
# Compiled-artifact introspection (dryrun / benchmarks)
# --------------------------------------------------------------------------- #
def cost_analysis(compiled) -> dict | None:
    """Normalized ``Compiled.cost_analysis()``: always a dict (or None).

    0.4.x returns ``[{...}]`` — one dict per partition — while newer JAX
    returns the dict directly; callers doing ``key in cost`` silently read
    nothing on the list form.
    """
    try:
        cost = compiled.cost_analysis()
    except Exception:
        return None
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else None
    return cost if isinstance(cost, dict) else None


def memory_analysis(compiled):
    """``Compiled.memory_analysis()`` or None where unsupported."""
    try:
        return compiled.memory_analysis()
    except Exception:
        return None


# --------------------------------------------------------------------------- #
# Compiled-executable persistence (the AOT stage-executable cache)
# --------------------------------------------------------------------------- #
try:
    from jax.experimental.serialize_executable import (  # type: ignore
        deserialize_and_load as _deserialize_and_load, serialize as
        _serialize_executable)
    HAS_EXECUTABLE_SERIALIZATION = True
except ImportError:                                          # pragma: no cover
    _serialize_executable = _deserialize_and_load = None
    HAS_EXECUTABLE_SERIALIZATION = False


def serialize_compiled(compiled):
    """``jax.stages.Compiled`` -> picklable ``(payload, in_tree, out_tree)``.

    The triple is exactly what :func:`deserialize_compiled` needs; the
    PyTreeDefs pickle as long as every custom node type (``WaveState``,
    ``DeviceGraph``, ``AdjCache``) is import-registered at load time, which
    module import guarantees.  Raises on unsupported JAX builds — callers
    should gate on :data:`HAS_EXECUTABLE_SERIALIZATION`.
    """
    if _serialize_executable is None:                        # pragma: no cover
        raise RuntimeError("this JAX build cannot serialize executables")
    return _serialize_executable(compiled)


def deserialize_compiled(triple):
    """Inverse of :func:`serialize_compiled`: returns a loaded executable
    callable with the original (pytree) calling convention — no tracing,
    no XLA compilation."""
    if _deserialize_and_load is None:                        # pragma: no cover
        raise RuntimeError("this JAX build cannot deserialize executables")
    payload, in_tree, out_tree = triple
    return _deserialize_and_load(payload, in_tree, out_tree)


def version_stamp() -> str:
    """Environment fingerprint every persisted executable is keyed under:
    a pickled executable is only valid on the exact jax/jaxlib pair and
    backend that produced it."""
    import jaxlib

    return (f"jax={jax.__version__};jaxlib={jaxlib.__version__};"
            f"backend={jax.default_backend()};ndev={jax.device_count()}")


# --------------------------------------------------------------------------- #
# Multi-process bootstrap (the `dist` exchange backend)
# --------------------------------------------------------------------------- #
def _probe_multiprocess_cpu() -> bool:
    """Does this jaxlib ship the gloo TCP collectives the CPU backend needs
    for cross-process computations?  (0.4.36 does; much older builds raise
    "Multiprocess computations aren't implemented on the CPU backend".)"""
    try:
        from jax._src.lib import xla_client

        return hasattr(xla_client._xla, "make_gloo_tcp_collectives")
    except Exception:                                        # pragma: no cover
        return False


HAS_MULTIPROCESS_CPU = _probe_multiprocess_cpu()


def enable_cpu_collectives() -> bool:
    """Select the gloo CPU collectives implementation.

    MUST run before the CPU backend client is created (i.e. before any
    computation or ``jax.devices()`` call) — the flag is read once at
    client construction.  Returns False (no-op) on builds without gloo or
    without the config knob; callers treat False as "multi-process
    unavailable" and skip."""
    if not HAS_MULTIPROCESS_CPU:
        return False
    try:
        jax.config.update("jax_cpu_collectives_implementation", "gloo")
        return True
    except Exception:                                        # pragma: no cover
        return False


def distributed_initialize(coordinator_address: str, num_processes: int,
                           process_id: int) -> bool:
    """``jax.distributed.initialize`` with the kwargs this build accepts.

    Returns False instead of raising when the build has no distributed
    runtime or the bootstrap fails (coordinator unreachable) — the caller
    degrades to single-process / skips."""
    try:
        init = jax.distributed.initialize
    except AttributeError:                                   # pragma: no cover
        return False
    kw = dict(coordinator_address=coordinator_address,
              num_processes=num_processes, process_id=process_id)
    accepted = set(inspect.signature(init).parameters)
    try:
        init(**{k: v for k, v in kw.items() if k in accepted})
        return True
    except Exception:
        return False


def process_index() -> int:
    try:
        return int(jax.process_index())
    except Exception:                                        # pragma: no cover
        return 0


def process_count() -> int:
    try:
        return int(jax.process_count())
    except Exception:                                        # pragma: no cover
        return 1


def _spans_processes(mesh: Mesh) -> bool:
    me = process_index()
    return any(d.process_index != me for d in mesh.devices.flat)


def global_shard(tree, mesh: Mesh, axis: str = "data"):
    """Shard every leaf of ``tree`` along its leading axis over
    ``mesh[axis]`` — the one entry point that works both single-process
    (plain ``device_put``) and multi-process (each process contributes its
    contiguous block of the leading axis through
    ``multihost_utils.host_local_array_to_global_array``, the 0.4.x way to
    assemble a global array; ``device_put`` onto non-addressable devices
    raises there).  Every process must hold the FULL host value and call
    with identical shapes — the per-process slice is taken here."""
    import numpy as np
    from jax.sharding import NamedSharding

    multi = _spans_processes(mesh)
    if multi:
        from jax.experimental import multihost_utils

        devs = list(mesh.devices.flat)
        mine = [i for i, d in enumerate(devs)
                if d.process_index == process_index()]
        lo, hi = mine[0], mine[-1] + 1

    def put(x):
        spec = PartitionSpec(axis, *([None] * (x.ndim - 1)))
        if not multi:
            return jax.device_put(x, NamedSharding(mesh, spec))
        return multihost_utils.host_local_array_to_global_array(
            np.asarray(x)[lo:hi], mesh, spec)

    return tree_map(put, tree)


def global_replicate(tree, mesh: Mesh):
    """Fully-replicated process-global arrays from identical host values
    (every process must pass the same data — the callers are deterministic
    host computations, which is the `dist` backend's standing contract)."""
    import numpy as np
    from jax.sharding import NamedSharding

    multi = _spans_processes(mesh)
    if multi:
        from jax.experimental import multihost_utils

    def put(x):
        if not multi:
            return jax.device_put(x, NamedSharding(mesh, PartitionSpec()))
        return multihost_utils.host_local_array_to_global_array(
            np.asarray(x), mesh, PartitionSpec())

    return tree_map(put, tree)
