from repro.distributed.sharding import param_shardings, data_shardings, dp_axes
from repro.distributed.compression import (compress_roundtrip,
                                           init_error_feedback,
                                           compressed_psum)
