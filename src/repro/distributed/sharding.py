"""Sharding rules: param pytree + family -> NamedSharding pytree.

Scheme (single pod (data=16, model=16); multi-pod adds a leading 'pod' axis
that joins the FSDP group):

* LM: Megatron TP over 'model' (column-parallel wq/wk/wv/wg/wu, row-parallel
  wo/wd), FSDP (ZeRO-3 style) over 'data' (+'pod') on the complementary dim,
  experts EP over 'model', embeddings vocab-sharded over 'model' + FSDP'd.
* GNN: params replicated (tiny), edge/node arrays sharded over 'data'
  (edge parallelism; segment_sum lowers to reduce-scatter of partials).
* RecSys: embedding tables row-sharded over every axis (they dominate),
  dense MLPs replicated.

Inputs (`data_sharding`): batch dims over the DP axes; long-context decode
shards the KV-cache *sequence* dim instead (batch=1).
"""
from __future__ import annotations

from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import tree_map_with_path


def dp_axes(mesh: Mesh) -> tuple[str, ...]:
    """All data-parallel axes: ('pod', 'data') on multi-pod, ('data',) else."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def _lm_spec(path: str, ndim: int, fsdp) -> P:
    """Leading axis of stacked-layer params is the layer axis (unsharded)."""
    lead = (None,) * (ndim - 2)
    if "router" in path or path.endswith("_norm") or "ln" in path \
            or "norm" in path or path.endswith(("bq", "bk", "bv")) or ndim <= 1 + len(lead):
        return P()
    if "embed" in path or "lm_head" in path:
        # vocab over 'model' only: the head matmul then propagates to
        # (batch 'data', seq, vocab 'model') logits with no resharding of
        # the contraction dim (d replicated) — see EXPERIMENTS.md §Perf
        return P("model", None) if "embed" in path else P(None, "model")
    col = ("wq", "wk", "wv", "wg", "wu", "w_uq", "w_uk", "w_uv", "w_dq",
           "w_dkv", "w_kr", "shared_wg", "shared_wu", "proj")
    row = ("wo", "wd", "shared_wd")
    name = path.rsplit("/", 1)[-1]
    if ndim == 4:  # stacked experts (L, E, d, f)
        from repro.distributed import ctx
        if ctx.CURRENT.moe_tp:
            # TP-MoE: every device holds all experts' f-shard; token
            # dispatch never crosses the model axis (§Perf deepseek iter 2)
            if name in ("wg", "wu"):
                return P(None, None, fsdp, "model")
            if name == "wd":
                return P(None, None, "model", fsdp)
            return P()
        if name in ("wg", "wu"):
            return P(None, "model", fsdp, None)
        if name == "wd":
            return P(None, "model", None, fsdp)
        return P()
    if name in col:
        return P(*lead, fsdp, "model")
    if name in row:
        return P(*lead, "model", fsdp)
    return P()


def param_shardings(params, family: str, mesh: Mesh):
    dp = dp_axes(mesh)
    fsdp = dp if len(dp) > 1 else (dp[0] if dp else None)

    def spec_of(path_parts, leaf):
        path = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path_parts)
        nd = leaf.ndim if hasattr(leaf, "ndim") else 0
        if family == "lm":
            sp = _lm_spec(path, nd, fsdp)
        elif family == "recsys":
            if "table" in path and nd == 2:
                axes = tuple(mesh.axis_names)
                sp = P(axes, None)
            else:
                sp = P()
        else:  # gnn — replicate
            sp = P()
        # drop axes that don't divide the dim (safety for reduced configs)
        shape = getattr(leaf, "shape", ())
        fixed = []
        for i, ax in enumerate(sp):
            if ax is None or i >= len(shape):
                fixed.append(None)
                continue
            size = 1
            for a in (ax if isinstance(ax, tuple) else (ax,)):
                size *= mesh.shape[a]
            fixed.append(ax if shape[i] % size == 0 else None)
        return NamedSharding(mesh, P(*fixed) if fixed else P())

    return tree_map_with_path(spec_of, params)


def data_shardings(family: str, kind: str, mesh: Mesh):
    """Returns a function: array-ndim/dim-role -> NamedSharding for inputs.
    Used by dryrun's input_specs; see launch/specs.py for per-cell wiring."""
    dp = dp_axes(mesh)
    batch_axes = dp if len(dp) > 1 else (dp[0] if dp else None)

    def batch0(ndim):
        return NamedSharding(mesh, P(batch_axes, *([None] * (ndim - 1))))

    return batch0


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())
