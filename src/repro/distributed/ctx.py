"""Optimization-flag context for the perf loop (§Perf).

The model code is shared between the single-device smoke tests and the
512-chip dry-run; sharding-sensitive optimizations are toggled here (set by
``launch.specs.build_cell(variant=...)``) so the paper-faithful baseline
stays reproducible and every hillclimb change is one flag.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro import compat


@dataclass
class OptFlags:
    dp_axes: tuple = ("data",)      # data-parallel mesh axes
    moe_ep_constrain: bool = False  # explicit EP dispatch shardings (MoE)
    gnn_bf16_msgs: bool = False     # bf16 edge messages/partials (GNN)
    moe_capacity_factor: float | None = None  # override cf (dispatch volume)
    moe_tp: bool = False            # TP-MoE: shard experts over d_ff, not E
    gnn_replicate_nodes: bool = False  # replicate node feats (kill gathers)


CURRENT = OptFlags()


def set_flags(**kw):
    global CURRENT
    for k, v in kw.items():
        setattr(CURRENT, k, v)


def reset():
    global CURRENT
    CURRENT = OptFlags()


def constrain(x, *spec):
    """with_sharding_constraint that degrades to identity outside a mesh
    context (single-device tests)."""
    return compat.with_sharding_constraint(x, *spec)
