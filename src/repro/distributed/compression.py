"""Gradient compression for the slow cross-pod links: int8 quantization with
error feedback (EF-SGD style). Two entry points:

* ``compress_roundtrip(g, err)`` — quantize+dequantize with EF state; this
  is what the trainer applies per step (the wire format XLA's all-reduce
  then carries is int8-equivalent; on a real multi-pod deployment the
  shard_map path below puts actual int8 on the pod links).
* ``compressed_psum(x, axis, mesh)`` — explicit shard_map int8 psum over the
  'pod' axis (dry-runnable on the 2x16x16 mesh: the HLO shows the int8
  all-reduce payload at 1/4 the bytes).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map, tree_map


def _quant(x: jnp.ndarray):
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequant(q: jnp.ndarray, scale: jnp.ndarray):
    return q.astype(jnp.float32) * scale


def compress_roundtrip(g, err):
    """Per-leaf int8 quantize->dequantize with error feedback. Returns
    (g_hat, new_err). err pytree matches g (float32)."""
    def leaf(gl, el):
        gl32 = gl.astype(jnp.float32) + el
        q, s = _quant(gl32)
        gh = _dequant(q, s)
        return gh.astype(gl.dtype), gl32 - gh

    flat = tree_map(leaf, g, err)
    g_hat = tree_map(lambda t: t[0], flat,
                     is_leaf=lambda t: isinstance(t, tuple))
    new_err = tree_map(lambda t: t[1], flat,
                       is_leaf=lambda t: isinstance(t, tuple))
    return g_hat, new_err


def init_error_feedback(params):
    return tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_psum(x: jnp.ndarray, axis: str, mesh: Mesh) -> jnp.ndarray:
    """Explicit int8-payload psum over ``axis`` (e.g. 'pod'): agree on a
    global scale (one scalar pmax), quantize, all-reduce the int8 payload
    (int32 accumulator), dequantize — 4x fewer bytes on the slow inter-pod
    links. ``x`` carries the per-pod values stacked on axis 0 (sharded over
    ``axis``); every output row holds the dequantized sum."""
    def body(xl):
        xl32 = xl.astype(jnp.float32)
        gmax = jax.lax.pmax(jnp.max(jnp.abs(xl32)), axis)   # shared scale
        scale = jnp.maximum(gmax, 1e-12) / 127.0
        q = jnp.clip(jnp.round(xl32 / scale), -127, 127).astype(jnp.int32)
        qsum = jax.lax.psum(q, axis)                        # int8-wide wire
        return (qsum.astype(jnp.float32) * scale).astype(xl.dtype)

    spec = P(axis, *([None] * (x.ndim - 1)))
    return shard_map(body, mesh=mesh, in_specs=spec, out_specs=spec)(x)
