"""Pure-jnp oracle: naive softmax attention."""
import jax.numpy as jnp
import jax


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        causal: bool = True) -> jnp.ndarray:
    """q (BH, Sq, D), k/v (BH, Skv, D|Dv) -> (BH, Sq, Dv)."""
    D = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * D ** -0.5
    if causal:
        Sq, Sk = q.shape[1], k.shape[1]
        mask = jnp.arange(Sq)[:, None] >= jnp.arange(Sk)[None, :]
        s = jnp.where(mask[None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)
