"""Layout handling + jit'd entry for the flash-attention kernel."""
from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_attn.kernel import flash_attention_pallas
from repro.kernels.flash_attn.ref import flash_attention_ref


@partial(jax.jit, static_argnames=("causal", "use_kernel", "interpret",
                                   "bq", "bk"))
def flash_attention_k(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                      causal: bool = True, use_kernel: bool = True,
                      interpret: bool = True, bq: int = 128,
                      bk: int = 128) -> jnp.ndarray:
    """(B, S, H, D) layout with GQA (k/v heads Hk | H % Hk == 0)."""
    B, Sq, H, D = q.shape
    Hk = k.shape[2]
    rep = H // Hk
    kr = jnp.repeat(k, rep, axis=2)
    vr = jnp.repeat(v, rep, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, D)
    kf = kr.transpose(0, 2, 1, 3).reshape(B * H, -1, D)
    vf = vr.transpose(0, 2, 1, 3).reshape(B * H, -1, vr.shape[-1])
    if use_kernel:
        out = flash_attention_pallas(qf, kf, vf, causal=causal,
                                     bq=bq, bk=bk, interpret=interpret)
    else:
        out = flash_attention_ref(qf, kf, vf, causal=causal)
    return out.reshape(B, H, Sq, -1).transpose(0, 2, 1, 3)
