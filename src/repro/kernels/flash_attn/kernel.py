"""Pallas TPU kernel: causal flash attention (online softmax, VMEM-tiled).

Grid = (batch*heads, q_blocks); the kv loop runs *inside* the kernel with a
``fori_loop`` so the (Bq, D) accumulator, running max and denominator stay
in VMEM/VREGs across the whole row of kv blocks — one HBM write per q tile.
Block shapes default to MXU-aligned (128, head_dim); causal blocks beyond
the diagonal are skipped by masking (structural zero work is visible to the
roofline via the cost model, see benchmarks).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, bq: int, bk: int,
                  seq_kv: int, causal: bool, scale: float):
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * scale           # (bq, D)
    D = q.shape[-1]
    nk = seq_kv // bk

    def body(ki, carry):
        m, l, acc = carry
        k = jax.lax.dynamic_slice(k_ref[0], (ki * bk, 0),
                                  (bk, k_ref.shape[-1])).astype(jnp.float32)
        v = jax.lax.dynamic_slice(v_ref[0], (ki * bk, 0),
                                  (bk, v_ref.shape[-1])).astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (bq, bk)
        if causal:
            qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return m_new, l_new, acc_new

    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    a0 = jnp.zeros((bq, v_ref.shape[-1]), jnp.float32)
    if causal:
        # only kv blocks up to (and including) the diagonal do work
        n_iter = jnp.minimum((qi + 1) * bq, seq_kv) // bk \
            + jnp.where(((qi + 1) * bq) % bk != 0, 1, 0)
    else:
        n_iter = nk
    m, l, acc = jax.lax.fori_loop(0, n_iter, body, (m0, l0, a0))
    o_ref[0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                           causal: bool = True, bq: int = 128, bk: int = 128,
                           interpret: bool = True) -> jnp.ndarray:
    """q (BH, Sq, D); k/v (BH, Skv, D) — heads pre-flattened & GQA
    pre-broadcast (ops.py handles layout). Returns (BH, Sq, Dv)."""
    BH, Sq, D = q.shape
    Skv = k.shape[1]
    Dv = v.shape[-1]
    bq = min(bq, Sq)
    bk = min(bk, Skv)
    assert Sq % bq == 0 and Skv % bk == 0, "pad seq to block multiples"
    scale = D ** -0.5
    grid = (BH, Sq // bq)
    return pl.pallas_call(
        partial(_flash_kernel, bq=bq, bk=bk, seq_kv=Skv, causal=causal,
                scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, Skv, D), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, Skv, Dv), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, Dv), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, Dv), q.dtype),
        interpret=interpret,
    )(q, k, v)
