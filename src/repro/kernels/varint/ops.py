from functools import partial

import jax

from repro.kernels.varint.kernel import delta_vlen_pallas
from repro.kernels.varint.ref import delta_vlen_ref


@partial(jax.jit, static_argnames=("sentinel", "use_kernel", "interpret"))
def delta_vlen(ids, sentinel: int, use_kernel: bool = False,
               interpret: bool = True):
    """Delta against the previous valid id + LEB128 size, kernel-gated."""
    if use_kernel:
        return delta_vlen_pallas(ids, sentinel, interpret=interpret)
    return delta_vlen_ref(ids, sentinel)
