"""jnp reference for the delta+varint sizing pass (the CPU test path).

``delta_vlen_ref(ids, sentinel)``: ids (B, M) sorted ascending among the
valid (< sentinel) entries, sentinel holes allowed.  Returns

* ``delta`` (B, M) int32 — each valid id minus the previous valid id in its
  row (the first valid id absolute); 0 at holes,
* ``vlen``  (B, M) int32 — LEB128 byte length of that delta (1..5); 0 at
  holes.

This is the sizing/transform half of the fetchV id wire codec
(:mod:`repro.core.wire`); the byte scatter stays jnp in both paths.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def varint_size(v: jnp.ndarray) -> jnp.ndarray:
    """LEB128 byte length of non-negative int32 values (1..5) — the one
    sizing ladder every codec path shares (`repro.core.wire` imports it;
    the Pallas kernel body inlines the same compares)."""
    v = v.astype(jnp.int32)
    return (1 + (v >= 1 << 7).astype(jnp.int32)
            + (v >= 1 << 14).astype(jnp.int32)
            + (v >= 1 << 21).astype(jnp.int32)
            + (v >= 1 << 28).astype(jnp.int32))


def delta_vlen_ref(ids: jnp.ndarray, sentinel: int):
    valid = ids < sentinel
    x = jnp.where(valid, ids, -1)
    run = jax.lax.cummax(x, axis=x.ndim - 1)
    prev = jnp.concatenate(
        [jnp.full(run[..., :1].shape, -1, run.dtype), run[..., :-1]],
        axis=-1)
    delta = jnp.where(prev >= 0, ids - prev, ids)
    delta = jnp.where(valid, jnp.maximum(delta, 0), 0).astype(jnp.int32)
    vlen = jnp.where(valid, varint_size(delta), 0).astype(jnp.int32)
    return delta, vlen
