"""Pallas TPU kernel: fused delta + LEB128-size pass of the id wire codec.

The fetchV request encoder (:mod:`repro.core.wire`) needs, per lane, the
delta of every valid id against the previous valid id (sentinel holes
skipped) and the varint byte length of that delta.  On TPU this is a
running-max scan fused with elementwise threshold compares — one VMEM pass
over the (block_b, M) tile instead of the three materialized intermediates
of the jnp reference.  The running prefix max is computed per m-chunk with
a log-step shift/max ladder (VPU-friendly, no dynamic gather), carrying
the last column across chunks exactly like the membership kernel streams
its row chunks.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _chunk_cummax(x: jnp.ndarray) -> jnp.ndarray:
    """Within-chunk inclusive prefix max via log-step shifts."""
    tb, c = x.shape
    s = 1
    while s < c:
        shifted = jnp.concatenate(
            [jnp.full((tb, s), -1, x.dtype), x[:, :-s]], axis=1)
        x = jnp.maximum(x, shifted)
        s *= 2
    return x


def _delta_vlen_kernel(ids_ref, delta_ref, vlen_ref, *, m_chunk: int,
                       sentinel: int):
    ids = ids_ref[...]
    tb, m = ids.shape
    n_chunks = m // m_chunk
    x = jnp.where(ids < sentinel, ids, -1)

    def body(c, carry):
        prev_last, delta_acc, vlen_acc = carry
        xc = jax.lax.dynamic_slice(x, (0, c * m_chunk), (tb, m_chunk))
        idc = jax.lax.dynamic_slice(ids, (0, c * m_chunk), (tb, m_chunk))
        cm = jnp.maximum(_chunk_cummax(xc), prev_last[:, None])
        prev = jnp.concatenate(
            [prev_last[:, None], cm[:, :-1]], axis=1)
        valid = idc < sentinel
        d = jnp.where(prev >= 0, idc - prev, idc)
        d = jnp.where(valid, jnp.maximum(d, 0), 0)
        vl = (1 + (d >= 1 << 7) + (d >= 1 << 14) + (d >= 1 << 21)
              + (d >= 1 << 28)).astype(jnp.int32)
        vl = jnp.where(valid, vl, 0)
        delta_acc = jax.lax.dynamic_update_slice(delta_acc, d,
                                                 (0, c * m_chunk))
        vlen_acc = jax.lax.dynamic_update_slice(vlen_acc, vl,
                                                (0, c * m_chunk))
        return cm[:, -1], delta_acc, vlen_acc

    init = (jnp.full((tb,), -1, jnp.int32),
            jnp.zeros((tb, m), jnp.int32), jnp.zeros((tb, m), jnp.int32))
    _, delta, vlen = jax.lax.fori_loop(0, n_chunks, body, init)
    delta_ref[...] = delta
    vlen_ref[...] = vlen


def delta_vlen_pallas(ids: jnp.ndarray, sentinel: int, block_b: int = 256,
                      m_chunk: int = 128, interpret: bool = True):
    """ids (B, M) int32 -> (delta (B, M) int32, vlen (B, M) int32)."""
    B, M = ids.shape
    m_chunk = min(m_chunk, max(M, 1))
    Mp = -(-M // m_chunk) * m_chunk
    Bp = -(-B // block_b) * block_b
    pad = jnp.pad(ids, ((0, Bp - B), (0, Mp - M)),
                  constant_values=sentinel)
    grid = (Bp // block_b,)
    delta, vlen = pl.pallas_call(
        partial(_delta_vlen_kernel, m_chunk=m_chunk, sentinel=sentinel),
        grid=grid,
        in_specs=[pl.BlockSpec((block_b, Mp), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((block_b, Mp), lambda i: (i, 0)),
                   pl.BlockSpec((block_b, Mp), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((Bp, Mp), jnp.int32),
                   jax.ShapeDtypeStruct((Bp, Mp), jnp.int32)],
        interpret=interpret,
    )(pad)
    return delta[:B, :M], vlen[:B, :M]
