"""Pallas TPU kernels for the perf-critical hot spots, each with a pure-jnp
oracle (ref.py) and a jit'd wrapper (ops.py). Validated in interpret mode on
CPU; BlockSpecs target TPU v5e VMEM/MXU (see DESIGN.md §2 hardware notes).

  membership    — batched sorted-set membership (verifyE answering)
  intersect     — sorted adjacency intersection (candidate refinement)
  segment_spmm  — GNN scatter-aggregate as one-hot MXU matmul
  flash_attn    — causal flash attention (online softmax)
  moe_gemm      — grouped per-expert SwiGLU GEMM
"""
from repro.kernels.membership.ops import membership
from repro.kernels.intersect.ops import intersect
from repro.kernels.segment_spmm.ops import segment_spmm, segment_spmm_tiled
from repro.kernels.flash_attn.ops import flash_attention_k
from repro.kernels.moe_gemm.ops import moe_gemm

__all__ = ["membership", "intersect", "segment_spmm", "segment_spmm_tiled",
           "flash_attention_k", "moe_gemm"]
