"""Pallas TPU kernel: segment-sum message aggregation as one-hot MXU matmul.

GNN message passing ``out[n] = Σ_{e: dst_e = n} msg_e`` is a scatter — the
worst case for a systolic machine. TPU adaptation (DESIGN.md §2): edges are
host-sorted by destination and tiled so each grid step owns one destination
tile; within a step the scatter becomes ``one_hot(dst_local)ᵀ @ msgs`` — a
(TN, TE) x (TE, D) matmul that runs on the MXU at full tilt. This is the
classic TPU scatter-to-matmul rewrite (cf. MegaBlocks-style dispatch).

Inputs (pre-tiled by ``ops.tile_edges``):
  msgs      (n_tiles, TE, D)  — gathered source messages, padded
  dst_local (n_tiles, TE)     — destination index *within* the tile, TN = pad
Output:
  out       (n_tiles, TN, D)  — per-tile aggregates (caller reshapes to (N, D))
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _spmm_kernel(msgs_ref, dst_ref, out_ref, *, tn: int):
    msgs = msgs_ref[0]                  # (TE, D)
    dst = dst_ref[0]                    # (TE,)
    onehot = (dst[:, None] == jax.lax.broadcasted_iota(jnp.int32, (1, tn), 1)
              ).astype(msgs.dtype)      # (TE, TN)
    out_ref[0] = jax.lax.dot_general(
        onehot, msgs, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(out_ref.dtype)


def segment_spmm_pallas(msgs: jnp.ndarray, dst_local: jnp.ndarray, tn: int,
                        interpret: bool = True) -> jnp.ndarray:
    n_tiles, te, d = msgs.shape
    out = pl.pallas_call(
        partial(_spmm_kernel, tn=tn),
        grid=(n_tiles,),
        in_specs=[pl.BlockSpec((1, te, d), lambda i: (i, 0, 0)),
                  pl.BlockSpec((1, te), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, tn, d), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_tiles, tn, d), jnp.float32),
        interpret=interpret,
    )(msgs, dst_local)
    return out
