"""Host-side edge tiling + jit'd entry point for segment-SpMM."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.segment_spmm.kernel import segment_spmm_pallas
from repro.kernels.segment_spmm.ref import segment_spmm_ref, segment_sum_dense


def tile_edges(dst: np.ndarray, n: int, tn: int, te: int):
    """Sort edges by destination and pack into (n_tiles, te) slots so tile t
    holds edges targeting nodes [t*tn, (t+1)*tn). Edges overflowing a tile's
    ``te`` slots spill into duplicate tiles for the same node range.

    Returns (perm, tile_ids, dst_local, slot_mask): feed ``msgs[perm]``
    scattered into (n_tiles, te, D) at ``slot``."""
    dst = np.asarray(dst)
    order = np.argsort(dst, kind="stable")
    sdst = dst[order]
    tile_of_edge = sdst // tn
    n_node_tiles = -(-n // tn)
    tiles, slots, owner_tile = [], [], []
    counts = np.zeros(0, dtype=np.int64)
    tile_base: dict[int, int] = {}
    next_tile = 0
    fill: list[int] = []
    for e_idx in range(len(sdst)):
        t = int(tile_of_edge[e_idx])
        if t not in tile_base:
            tile_base[t] = next_tile
            fill.append(0)
            next_tile += 1
            owner_tile.append(t)
        cur = tile_base[t]
        while fill[cur] >= te:          # spill tile
            if cur + 1 < next_tile and owner_tile[cur + 1] == t:
                cur += 1
            else:
                owner_tile.append(t)
                fill.append(0)
                next_tile += 1
                cur = next_tile - 1
            tile_base[t] = cur
        tiles.append(cur)
        slots.append(fill[cur])
        fill[cur] += 1
    n_tiles = max(next_tile, 1)
    return (order.astype(np.int32), np.asarray(tiles, np.int32),
            np.asarray(slots, np.int32),
            np.asarray(owner_tile + [0] * (n_tiles - len(owner_tile)), np.int32),
            n_tiles)


def pack_messages(msgs: jnp.ndarray, dst: jnp.ndarray, tiling, tn: int,
                  te: int):
    """Scatter gathered messages into the tiled layout."""
    perm, tiles, slots, owner, n_tiles = tiling
    d = msgs.shape[-1]
    sm = msgs[perm]
    sd = dst[perm]
    buf = jnp.zeros((n_tiles, te, d), msgs.dtype)
    buf = buf.at[tiles, slots].set(sm)
    dl = jnp.full((n_tiles, te), tn, jnp.int32)   # tn == drop slot
    dl = dl.at[tiles, slots].set(sd - owner[tiles] * tn)
    return buf, dl, owner, n_tiles


@partial(jax.jit, static_argnames=("n", "use_kernel", "interpret", "tn", "te"))
def segment_spmm(msgs: jnp.ndarray, dst: jnp.ndarray, n: int,
                 use_kernel: bool = False, interpret: bool = True,
                 tn: int = 128, te: int = 512) -> jnp.ndarray:
    """out (n, D) = segment_sum(msgs, dst). The kernel path requires static
    host tiling, so it is exposed via ``segment_spmm_tiled`` below; this
    entry runs the XLA-native path."""
    del use_kernel, interpret, tn, te
    return segment_sum_dense(msgs, dst, n)


def segment_spmm_tiled(msgs: jnp.ndarray, dst: np.ndarray, n: int,
                       tn: int = 128, te: int = 512,
                       use_kernel: bool = True,
                       interpret: bool = True) -> jnp.ndarray:
    """Full pipeline: host tiling -> one-hot-matmul Pallas kernel ->
    un-tile + combine spill tiles. Oracle-equivalent to segment_sum."""
    tiling = tile_edges(np.asarray(dst), n, tn, te)
    buf, dl, owner, n_tiles = pack_messages(msgs, jnp.asarray(dst), tiling,
                                            tn, te)
    if use_kernel:
        # kernel drop slot: dst_local == tn rows contribute to none
        tiles_out = segment_spmm_pallas(
            buf, dl, tn, interpret=interpret)          # (n_tiles, tn, D)
    else:
        tiles_out = segment_spmm_ref(buf, dl, tn)
    # combine spill tiles: scatter-add tile outputs to their node range
    n_node_tiles = -(-n // tn)
    out = jnp.zeros((n_node_tiles, tn, msgs.shape[-1]), jnp.float32)
    out = out.at[owner].add(tiles_out)
    return out.reshape(n_node_tiles * tn, -1)[:n]
