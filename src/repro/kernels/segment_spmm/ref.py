"""Pure-jnp oracle: plain segment_sum over the same tiled layout."""
import jax
import jax.numpy as jnp


def segment_spmm_ref(msgs: jnp.ndarray, dst_local: jnp.ndarray,
                     tn: int) -> jnp.ndarray:
    """msgs (n_tiles, TE, D); dst_local (n_tiles, TE) in [0, TN] (TN = drop).
    -> (n_tiles, TN, D) float32."""
    def per_tile(m, d):
        return jax.ops.segment_sum(m.astype(jnp.float32), d,
                                   num_segments=tn + 1)[:tn]
    return jax.vmap(per_tile)(msgs, dst_local)


def segment_sum_dense(msgs: jnp.ndarray, dst: jnp.ndarray,
                      n: int) -> jnp.ndarray:
    """Untiled end-to-end oracle."""
    return jax.ops.segment_sum(msgs.astype(jnp.float32), dst, num_segments=n)
