from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.moe_gemm.kernel import moe_gemm_pallas
from repro.kernels.moe_gemm.ref import moe_gemm_ref


@partial(jax.jit, static_argnames=("use_kernel", "interpret", "bc", "bf"))
def moe_gemm(x: jnp.ndarray, wg: jnp.ndarray, wu: jnp.ndarray,
             wd: jnp.ndarray, use_kernel: bool = True,
             interpret: bool = True, bc: int = 128,
             bf: int = 128) -> jnp.ndarray:
    """Grouped expert SwiGLU FFN over the dispatched buffer (E, C, d)."""
    if use_kernel:
        return moe_gemm_pallas(x, wg, wu, wd, bc=bc, bf=bf,
                               interpret=interpret)
    return moe_gemm_ref(x, wg, wu, wd)
