"""Pure-jnp oracle: dense per-expert SwiGLU."""
import jax
import jax.numpy as jnp


def moe_gemm_ref(x: jnp.ndarray, wg: jnp.ndarray, wu: jnp.ndarray,
                 wd: jnp.ndarray) -> jnp.ndarray:
    g = jnp.einsum("ecd,edf->ecf", x, wg,
                   preferred_element_type=jnp.float32)
    u = jnp.einsum("ecd,edf->ecf", x, wu,
                   preferred_element_type=jnp.float32)
    h = (jax.nn.silu(g) * u).astype(x.dtype)
    return jnp.einsum("ecf,efd->ecd", h, wd,
                      preferred_element_type=jnp.float32).astype(x.dtype)
