"""Pallas TPU kernel: grouped per-expert GEMM (the MoE FLOP hot-spot).

Computes ``out[e] = (silu(x[e] @ wg[e]) * (x[e] @ wu[e])) @ wd[e]`` for the
capacity-dispatched token buffer ``x (E, C, d)`` — the full SwiGLU expert
FFN — with a grid over (expert, C tiles, f tiles) and an f-tile accumulation
held in a VMEM scratch accumulator. Tiles are MXU-aligned; weights stream
through VMEM one (d, bf) panel at a time so the working set is
``bc*d + 3*d*bf + bc*bf`` regardless of d_ff.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _moe_kernel(x_ref, wg_ref, wu_ref, wd_ref, o_ref, acc_ref, *, nf: int):
    fi = pl.program_id(2)
    x = x_ref[0]                     # (bc, d)
    g = jax.lax.dot_general(x, wg_ref[0], (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    u = jax.lax.dot_general(x, wu_ref[0], (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    h = (jax.nn.silu(g) * u).astype(x.dtype)            # (bc, bf)
    part = jax.lax.dot_general(h, wd_ref[0], (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)

    @pl.when(fi == 0)
    def _init():
        acc_ref[...] = part

    @pl.when(fi != 0)
    def _acc():
        acc_ref[...] += part

    @pl.when(fi == nf - 1)
    def _flush():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def moe_gemm_pallas(x: jnp.ndarray, wg: jnp.ndarray, wu: jnp.ndarray,
                    wd: jnp.ndarray, bc: int = 128, bf: int = 128,
                    interpret: bool = True) -> jnp.ndarray:
    """x (E, C, d); wg/wu (E, d, f); wd (E, f, d) -> (E, C, d)."""
    E, C, d = x.shape
    f = wg.shape[-1]
    bc = min(bc, C)
    bf = min(bf, f)
    assert C % bc == 0 and f % bf == 0, "pad C/f to block multiples"
    grid = (E, C // bc, f // bf)
    return pl.pallas_call(
        partial(_moe_kernel, nf=f // bf),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bc, d), lambda e, c, fi: (e, c, 0)),
            pl.BlockSpec((1, d, bf), lambda e, c, fi: (e, 0, fi)),
            pl.BlockSpec((1, d, bf), lambda e, c, fi: (e, 0, fi)),
            pl.BlockSpec((1, bf, d), lambda e, c, fi: (e, fi, 0)),
        ],
        out_specs=pl.BlockSpec((1, bc, d), lambda e, c, fi: (e, c, 0)),
        out_shape=jax.ShapeDtypeStruct((E, C, d), x.dtype),
        scratch_shapes=[pltpu.VMEM((bc, d), jnp.float32)],
        interpret=interpret,
    )(x, wg, wu, wd)
