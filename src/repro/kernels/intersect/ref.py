"""Pure-jnp oracle for the intersect kernel."""
import jax
import jax.numpy as jnp


def intersect_ref(a: jnp.ndarray, b: jnp.ndarray, sentinel: int):
    idx = jax.vmap(jnp.searchsorted)(b, a)
    idx = jnp.clip(idx, 0, b.shape[-1] - 1)
    mask = (jnp.take_along_axis(b, idx, axis=-1) == a) & (a != sentinel)
    return mask, mask.sum(axis=-1, dtype=jnp.int32)
