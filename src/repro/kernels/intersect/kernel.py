"""Pallas TPU kernel: batched sorted-list intersection (Alg 1 line 6 —
candidate refinement C(u) <- adj(piv) ∩ adj(f(u'))).

``a (B, M)`` and ``b (B, M)`` are sorted, sentinel-padded adjacency windows.
Output: ``mask (B, M) bool`` marking a-entries present in b, and
``count (B,) int32``. Same VPU chunk-compare scheme as the membership
kernel (no dynamic gather), tiled over B via BlockSpec; the count is an
in-kernel reduction so callers can size compaction without a second pass.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _intersect_kernel(a_ref, b_ref, mask_ref, cnt_ref, *, m_chunk: int,
                      sentinel: int):
    a = a_ref[...]
    b = b_ref[...]
    TB, M = a.shape
    acc = jnp.zeros((TB, M), dtype=jnp.bool_)
    n_chunks = M // m_chunk

    def body(c, acc):
        chunk = jax.lax.dynamic_slice(b, (0, c * m_chunk), (TB, m_chunk))
        hit = (a[:, :, None] == chunk[:, None, :]).any(axis=-1)
        return acc | hit

    acc = jax.lax.fori_loop(0, n_chunks, body, acc)
    acc = acc & (a != sentinel)
    mask_ref[...] = acc
    cnt_ref[...] = acc.sum(axis=-1, dtype=jnp.int32)


def intersect_pallas(a: jnp.ndarray, b: jnp.ndarray, sentinel: int,
                     block_b: int = 256, m_chunk: int = 128,
                     interpret: bool = True):
    B, M = a.shape
    m_chunk = min(m_chunk, max(M, 1))
    Mp = -(-M // m_chunk) * m_chunk
    Bp = -(-B // block_b) * block_b
    pad_a = jnp.pad(a, ((0, Bp - B), (0, Mp - M)), constant_values=sentinel)
    pad_b = jnp.pad(b, ((0, Bp - B), (0, Mp - M)),
                    constant_values=jnp.iinfo(jnp.int32).min)
    grid = (Bp // block_b,)
    mask, cnt = pl.pallas_call(
        partial(_intersect_kernel, m_chunk=m_chunk, sentinel=sentinel),
        grid=grid,
        in_specs=[pl.BlockSpec((block_b, Mp), lambda i: (i, 0)),
                  pl.BlockSpec((block_b, Mp), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((block_b, Mp), lambda i: (i, 0)),
                   pl.BlockSpec((block_b,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((Bp, Mp), jnp.bool_),
                   jax.ShapeDtypeStruct((Bp,), jnp.int32)],
        interpret=interpret,
    )(pad_a, pad_b)
    return mask[:B, :M], cnt[:B]
