from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.intersect.kernel import intersect_pallas
from repro.kernels.intersect.ref import intersect_ref


def _pow2ceil(x: int) -> int:
    return 1 << max(int(x) - 1, 0).bit_length()


def tile_defaults(m: int) -> tuple[int, int]:
    """Tuned ``(block_b, m_chunk)`` for window width ``m``.

    Degree-bucketed adjacency keeps most windows far narrower than
    ``max_degree``, so the chunk is the window width rounded up to a
    power of two (capped at the 128-lane VPU width) — small buckets get
    narrow tiles instead of streaming full-width chunks of sentinel
    padding; with a narrow chunk the batch tile is widened so the
    (block_b, m_chunk) working set keeps feeding the VPU.
    """
    m_chunk = min(128, _pow2ceil(max(m, 1)))
    block_b = 256 if m_chunk >= 64 else 512
    return block_b, m_chunk


@partial(jax.jit, static_argnames=("sentinel", "use_kernel", "interpret",
                                   "block_b", "m_chunk"))
def intersect(a: jnp.ndarray, b: jnp.ndarray, sentinel: int,
              use_kernel: bool = False, interpret: bool = True,
              block_b: int | None = None, m_chunk: int | None = None):
    """Sorted-list intersection: (mask over a, per-row count).

    ``block_b``/``m_chunk`` tune the Pallas tiling; ``None`` picks
    :func:`tile_defaults` from the ``b`` window width (narrow degree
    buckets get narrow chunks).  The jnp reference ignores the tiling, so
    any (block_b, m_chunk) is bit-identical to ``use_kernel=False``.
    """
    if use_kernel:
        db, dm = tile_defaults(b.shape[-1])
        return intersect_pallas(a, b, sentinel,
                                block_b=block_b or db,
                                m_chunk=m_chunk or dm,
                                interpret=interpret)
    return intersect_ref(a, b, sentinel)
