from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.intersect.kernel import intersect_pallas
from repro.kernels.intersect.ref import intersect_ref


@partial(jax.jit, static_argnames=("sentinel", "use_kernel", "interpret"))
def intersect(a: jnp.ndarray, b: jnp.ndarray, sentinel: int,
              use_kernel: bool = False, interpret: bool = True):
    """Sorted-list intersection: (mask over a, per-row count)."""
    if use_kernel:
        return intersect_pallas(a, b, sentinel, interpret=interpret)
    return intersect_ref(a, b, sentinel)
