"""Pure-jnp oracle for the membership kernel."""
import jax
import jax.numpy as jnp


def membership_ref(rows: jnp.ndarray, vals: jnp.ndarray) -> jnp.ndarray:
    """rows (B, M) sorted int32 (sentinel-padded); vals (B, K) -> (B, K)."""
    idx = jax.vmap(jnp.searchsorted)(rows, vals)
    idx = jnp.clip(idx, 0, rows.shape[-1] - 1)
    return jnp.take_along_axis(rows, idx, axis=-1) == vals
