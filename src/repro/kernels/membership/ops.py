"""Jit'd entry point: Pallas on TPU, jnp reference elsewhere."""
from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.membership.kernel import membership_pallas
from repro.kernels.membership.ref import membership_ref


@partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def membership(rows: jnp.ndarray, vals: jnp.ndarray,
               use_kernel: bool = False, interpret: bool = True) -> jnp.ndarray:
    """Edge-existence / candidate-refinement membership test.

    ``use_kernel=True`` runs the Pallas kernel (interpret=True on CPU);
    the default jnp path is what the engine uses on this CPU container."""
    if use_kernel:
        return membership_pallas(rows, vals, interpret=interpret)
    return membership_ref(rows, vals)
