"""Pallas TPU kernel: batched sorted-set membership (verifyE / Alg 2 checks).

Given sentinel-padded *sorted* adjacency windows ``rows (B, M)`` and query
values ``vals (B, K)``, produce ``out (B, K) bool`` with
``out[b, k] = vals[b, k] in rows[b]``.

TPU adaptation (instead of the GPU binary-search-per-thread): the row is
streamed through the VPU in 128-lane chunks and compared against the query
vector with an OR-reduction — no dynamic gather, fully vectorized, and the
(B_tile, M) working set is explicitly tiled into VMEM via BlockSpec. For
adjacency windows (M <= few hundred) this is compare-bound, far below the
VPU roofline of the surrounding scatter code it replaces.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _membership_kernel(rows_ref, vals_ref, out_ref, *, m_chunk: int):
    rows = rows_ref[...]          # (TB, M) int32, sorted, sentinel-padded
    vals = vals_ref[...]          # (TB, K) int32
    TB, M = rows.shape
    K = vals.shape[1]
    acc = jnp.zeros((TB, K), dtype=jnp.bool_)
    n_chunks = M // m_chunk

    def body(c, acc):
        chunk = jax.lax.dynamic_slice(rows, (0, c * m_chunk), (TB, m_chunk))
        hit = (vals[:, :, None] == chunk[:, None, :]).any(axis=-1)
        return acc | hit

    acc = jax.lax.fori_loop(0, n_chunks, body, acc)
    out_ref[...] = acc


def membership_pallas(rows: jnp.ndarray, vals: jnp.ndarray,
                      block_b: int = 256, m_chunk: int = 128,
                      interpret: bool = True) -> jnp.ndarray:
    """rows (B, M) sorted int32; vals (B, K) int32 -> (B, K) bool."""
    B, M = rows.shape
    K = vals.shape[1]
    # pad M to a chunk multiple and B to a block multiple
    m_chunk = min(m_chunk, max(M, 1))
    Mp = -(-M // m_chunk) * m_chunk
    Bp = -(-B // block_b) * block_b
    rows_p = jnp.pad(rows, ((0, Bp - B), (0, Mp - M)),
                     constant_values=jnp.iinfo(jnp.int32).max)
    vals_p = jnp.pad(vals, ((0, Bp - B), (0, 0)),
                     constant_values=jnp.iinfo(jnp.int32).min)
    grid = (Bp // block_b,)
    out = pl.pallas_call(
        partial(_membership_kernel, m_chunk=m_chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, Mp), lambda i: (i, 0)),
            pl.BlockSpec((block_b, K), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, K), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Bp, K), jnp.bool_),
        interpret=interpret,
    )(rows_p, vals_p)
    return out[:B]
