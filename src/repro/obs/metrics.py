"""Typed instrument registry behind the driver ``stats`` dict.

An :class:`Instrument` is a declared counter / gauge / info / histogram
with a unit and description; a :class:`MetricsRegistry` holds a set of
them and *is* a ``MutableMapping``, so every existing call site that
does ``stats["n_waves"] += 1`` or ``stats.get("auto_depth")`` keeps
working unchanged while the values gain a schema, exporters, and a
machine-checked declared-name set (radslint RL004's metric extension
lints the schema module against the exporter/benchmark consumers).

Semantics that matter to callers:

* declared-but-unset instruments are **absent** from the mapping view —
  ``"auto_depth" in stats`` stays False until the scheduler actually
  sets it, exactly like the plain dict it replaces;
* writing an undeclared key auto-registers it as an untyped gauge
  (benchmarks run phases named ``warm``/``bench`` which create e.g.
  ``warm_pipeline_s`` keys on the fly) — the registry never throws on a
  stats write, it only *types* the keys it knows;
* ``to_stats()`` snapshots set values into a plain dict, which is what
  crosses process boundaries (``merge_process_stats`` merges those
  plain dicts byte-wise unchanged — the registry is per-process).
"""
from __future__ import annotations

from collections.abc import MutableMapping
from dataclasses import dataclass, field
import json

__all__ = ["Instrument", "MetricsRegistry", "UNSET",
           "COUNTER", "GAUGE", "INFO", "HISTOGRAM"]

COUNTER = "counter"
GAUGE = "gauge"
INFO = "info"
HISTOGRAM = "histogram"
_KINDS = (COUNTER, GAUGE, INFO, HISTOGRAM)


class _Unset:
    __slots__ = ()

    def __repr__(self):
        return "UNSET"


UNSET = _Unset()


@dataclass
class Instrument:
    """One declared metric: name + kind + unit + description + value."""

    name: str
    kind: str = GAUGE
    unit: str = ""
    desc: str = ""
    declared: bool = True
    value: object = UNSET

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(f"unknown instrument kind {self.kind!r}")


class MetricsRegistry(MutableMapping):
    """Mapping-compatible typed registry (see module docstring)."""

    def __init__(self, instruments=()):
        self._ins: dict[str, Instrument] = {}
        for ins in instruments:
            self.register(ins)

    # -- declaration -------------------------------------------------------- #
    def register(self, ins: Instrument) -> Instrument:
        prev = self._ins.get(ins.name)
        if prev is not None:
            if prev.declared and ins.declared and prev.kind != ins.kind:
                raise ValueError(
                    f"instrument {ins.name!r} redeclared as {ins.kind}, "
                    f"was {prev.kind}")
            return prev
        self._ins[ins.name] = ins
        return ins

    def declared_names(self) -> set[str]:
        return {n for n, i in self._ins.items() if i.declared}

    def instruments(self) -> list[Instrument]:
        return list(self._ins.values())

    # -- mapping protocol (only SET instruments are visible) ----------------- #
    def __getitem__(self, key):
        ins = self._ins.get(key)
        if ins is None or ins.value is UNSET:
            raise KeyError(key)
        return ins.value

    def __setitem__(self, key, value):
        ins = self._ins.get(key)
        if ins is None:
            ins = self._ins[key] = Instrument(key, GAUGE, declared=False)
        ins.value = value

    def __delitem__(self, key):
        ins = self._ins.get(key)
        if ins is None or ins.value is UNSET:
            raise KeyError(key)
        ins.value = UNSET

    def __iter__(self):
        return (n for n, i in self._ins.items() if i.value is not UNSET)

    def __len__(self):
        return sum(1 for i in self._ins.values() if i.value is not UNSET)

    def __repr__(self):
        return f"MetricsRegistry({dict(self)!r})"

    # -- convenience --------------------------------------------------------- #
    def inc(self, name: str, v=1):
        ins = self._ins.get(name)
        if ins is None:
            ins = self._ins[name] = Instrument(name, COUNTER, declared=False)
        ins.value = v if ins.value is UNSET else ins.value + v
        return ins.value

    def to_stats(self) -> dict:
        """Plain-dict snapshot of set values — the thing that crosses
        process boundaries and feeds ``merge_process_stats`` unchanged."""
        return {n: i.value for n, i in self._ins.items()
                if i.value is not UNSET}

    # -- exporters ------------------------------------------------------------ #
    def export_json(self, path: str) -> str:
        doc = {n: dict(kind=i.kind, unit=i.unit, desc=i.desc,
                       declared=i.declared,
                       value=None if i.value is UNSET else i.value)
               for n, i in sorted(self._ins.items())}
        with open(path, "w") as f:
            json.dump(doc, f, indent=1, default=_jsonable)
        return path

    def export_prometheus(self, path: str) -> str:
        """Prometheus textfile-collector format: numeric counters/gauges
        as ``rads_<name>``, numeric lists as per-index labeled series,
        info/str instruments as a ``rads_info`` label set."""
        lines: list[str] = []
        info_labels: list[str] = []
        for n, ins in sorted(self._ins.items()):
            if ins.value is UNSET:
                continue
            v = ins.value
            ptype = "counter" if ins.kind == COUNTER else "gauge"
            if isinstance(v, bool):
                v = int(v)
            if isinstance(v, (int, float)):
                if ins.desc:
                    lines.append(f"# HELP rads_{n} {ins.desc}")
                lines.append(f"# TYPE rads_{n} {ptype}")
                lines.append(f"rads_{n} {float(v):g}")
            elif isinstance(v, (list, tuple)) and all(
                    isinstance(x, (int, float)) for x in v):
                lines.append(f"# TYPE rads_{n} {ptype}")
                lines.extend(f'rads_{n}{{index="{i}"}} {float(x):g}'
                             for i, x in enumerate(v))
            elif isinstance(v, dict) and all(
                    isinstance(x, (int, float)) for x in v.values()):
                lines.append(f"# TYPE rads_{n} {ptype}")
                lines.extend(f'rads_{n}{{key="{k}"}} {float(x):g}'
                             for k, x in sorted(v.items()))
            else:
                info_labels.append(f'{n}="{v}"')
        if info_labels:
            lines.append("# TYPE rads_info gauge")
            lines.append(f"rads_info{{{','.join(info_labels)}}} 1")
        with open(path, "w") as f:
            f.write("\n".join(lines) + "\n")
        return path

    def summary(self, names) -> str:
        """Unit-aware one-liner for the launcher (replaces hand-formatted
        prints): seconds as ``1.23s``, bytes as MB, bools as on/off,
        dict instruments as ``k=v`` pairs.  Unset names are skipped."""
        parts: list[str] = []
        for n in names:
            ins = self._ins.get(n)
            if ins is None or ins.value is UNSET:
                continue
            v = ins.value
            if isinstance(v, bool):
                txt = "on" if v else "off"
            elif ins.unit == "s" and isinstance(v, (int, float)):
                txt = f"{v:.2f}s"
            elif ins.unit == "us" and isinstance(v, (int, float)):
                txt = f"{v / 1e6:.2f}s"
            elif ins.unit == "bytes" and isinstance(v, (int, float)):
                txt = f"{v / 1e6:.1f}MB"
            elif isinstance(v, float):
                txt = f"{v:.3g}"
            elif isinstance(v, dict):
                txt = " ".join(f"{k}={v[k]}" for k in sorted(v))
            else:
                txt = str(v)
            parts.append(f"{n} {txt}")
        return " | ".join(parts)


def _jsonable(x):
    try:
        import numpy as np

        if isinstance(x, np.ndarray):
            return x.tolist()
        if isinstance(x, np.generic):
            return x.item()
    except Exception:
        pass
    return float(x)
