"""Observability for the RADS engine: wave tracing + a typed metrics registry.

This package is the design note for the subsystem (ISSUE 9).  It has two
halves — **tracing** (:mod:`repro.obs.trace`) and **metrics**
(:mod:`repro.obs.metrics` + the declared schema in
:mod:`repro.obs.schema`) — joined by one rule: *observability must cost
nothing when off and must never perturb the engine when on*.

Ring-buffer layout
------------------
:class:`TraceRecorder` preallocates a fixed-size Python list of record
slots at construction; recording a span is one small-tuple build and one
``ring[n % cap]`` store — O(1), no growth, no allocation of container
state in the hot loop.  When the ring wraps, the *oldest* records are
silently dropped and the drop count is reported in the exported JSON
(``otherData.dropped_records``), so a truncated trace is detectable, not
misleading.  Records are ``(ph, name, tid, ts_us, dur_us, flow_id,
args)`` tuples; export unrolls the ring in record order and emits Chrome
trace-event dicts (every event carries ``ph/ts/pid/tid``) that load
directly in Perfetto / ``chrome://tracing``.

Clock domain
------------
Every timestamp comes from :func:`now_us` — monotonic
``time.perf_counter_ns`` anchored at module import.  The scheduler's
per-phase ``*_wall_us`` stats are measured with the *same* function, so
the timeline, the stats dict, and the ``wall_skew`` benchmark column are
in one clock domain by construction; there is no wall-vs-span
reconciliation step.  Under ``dist`` each process has its own anchor —
lanes are internally consistent per process, and the merged view keeps
one process group per ``pid`` rather than pretending cross-process
clocks align.

Off-path guarantees
-------------------
The scheduler and runner hold :data:`NULL_TRACER` (a no-op singleton
with ``enabled = False``) unless a real recorder is injected, and every
hot-loop record site is guarded by ``if tracer.enabled`` — with tracing
off, the wave loop executes *zero* instrumentation code beyond one
attribute test, which is what makes tracing-off byte-identical to
tracing-on in counts and ``bytes_wire_*`` (gated in
``tests/test_obs.py``).  Recording takes **pre-fetched host scalars
only**: no method on the recorder ever touches a device value, so
instrumentation cannot introduce an RL001 host sync — the recorder's
methods are listed in ``[tool.radslint] hot_loops`` to keep that
machine-checked.

Metrics: one source of truth behind ``stats``
---------------------------------------------
:class:`MetricsRegistry` is a ``MutableMapping`` of typed
:class:`Instrument` declarations (counter / gauge / info / histogram
with unit + description).  The driver builds its per-run ``stats``
object from :func:`repro.obs.schema.build_driver_registry` — every
existing ``stats["k"] += v`` call site keeps working unchanged, but the
keys now have a declared schema that radslint's RL004 metric extension
lints against the exporters and benchmark columns.  Subsystems
(exchange backends, :class:`~repro.core.cache.AdjCache`, wire codecs,
the executable store) *register* their instruments through
``register_metrics`` hooks instead of poking dict keys blind.
Exporters: :meth:`MetricsRegistry.export_json` (typed document) and
:meth:`MetricsRegistry.export_prometheus` (textfile-collector format).

Dist merge contract
-------------------
Traces: each process records into its own file with its process index
as the Chrome ``pid``; merging is pure concatenation
(:func:`merge_traces`, CLI ``python -m tools.merge_traces``) — lanes
stay grouped per process.  Metrics: the registry is per-process;
``to_stats()`` snapshots a plain dict which crosses the process
boundary and feeds ``merge_process_stats`` byte-wise unchanged (logical
stats must be identical across processes — that assertion is the
determinism gate), while per-process ``wall_us`` is **max-merged** and
reported as ``per_process_wall_us`` + ``wall_skew`` so multi-host wall
clock is honest instead of descriptive.

Import-order note: this package imports nothing from ``repro.core``
(``jax`` is imported lazily only inside ``device_span``), so every core
module may import it without cycles.
"""
from __future__ import annotations

from repro.obs.metrics import (COUNTER, GAUGE, HISTOGRAM, INFO, UNSET,
                               Instrument, MetricsRegistry)
from repro.obs.schema import build_driver_registry
from repro.obs.trace import (NULL_TRACER, TRACK_PREWARM, TRACK_RETIRE,
                             TRACK_SCHED, TRACK_WAVE0, NullTracer,
                             TraceRecorder, merge_traces, now_us)

__all__ = [
    "COUNTER", "GAUGE", "HISTOGRAM", "INFO", "UNSET",
    "Instrument", "MetricsRegistry", "build_driver_registry",
    "NULL_TRACER", "NullTracer", "TraceRecorder", "merge_traces", "now_us",
    "TRACK_SCHED", "TRACK_RETIRE", "TRACK_PREWARM", "TRACK_WAVE0",
]
