"""Host-side span recorder -> Chrome trace-event JSON (Perfetto).

See the package docstring (:mod:`repro.obs`) for the design note.  The
short version of the contract this module keeps:

* **one clock domain**: every timestamp comes from :func:`now_us` — a
  process-wide monotonic ``time.perf_counter_ns`` anchored at import —
  so spans recorded on the scheduler thread, the prewarm thread, and the
  phase wall-clock the driver reports (``stats["*_wall_us"]``) are all
  directly comparable, and merged multi-process traces only differ by a
  per-process anchor offset (lanes stay internally consistent);
* **preallocated ring**: :class:`TraceRecorder` writes fixed-shape
  record tuples into a preallocated slot list — recording is an O(1)
  index-modulo store, the buffer never grows, and overflow silently
  drops the *oldest* records (the count is reported in the export);
* **host scalars only**: the recorder never touches device values — all
  arguments are pre-fetched host scalars, so instrumentation can never
  introduce an RL001 host sync (the radslint hot-loop config includes
  the record methods to keep that machine-checked);
* **zero instruments when off**: the scheduler holds :data:`NULL_TRACER`
  unless a recorder was passed in, and its hot-loop record sites are
  guarded by ``tracer.enabled`` — the off path executes no span code at
  all, which is what makes tracing-on vs tracing-off byte-identical in
  counts and ``bytes_wire_*`` (gated in ``tests/test_obs.py``).

Track (``tid``) layout — the ≥4 distinct track types the acceptance
criteria name:

====================  =====================================================
``TRACK_SCHED`` (1)   scheduler events: phase spans, group formation,
                      steal / overflow-split / cap-escalation instants
``TRACK_RETIRE`` (2)  finalize/retire: the single blocking ``device_get``
                      per wave, carrying the flow-arrow *end* per wave
``TRACK_PREWARM`` (3) background prewarm ladder walks + the stage
                      resolves (store load vs XLA compile) they trigger
``TRACK_WAVE0+k``     one lane per *in-flight* wave slot: init /
                      fetch:uN / expand:uN / verify:uN / finalize
                      dispatch spans plus a whole-life ``wave`` span,
                      carrying the flow-arrow *start*
====================  =====================================================

Flow arrows: admission emits ``ph="s"`` (id = wave sequence number)
inside the wave lane's ``init`` span; retirement emits ``ph="f"`` with
``bp="e"`` inside the retire span — Perfetto draws the dispatch→retire
arrow per wave.  ``device_span`` optionally bridges to
``jax.profiler.TraceAnnotation`` so device profiles line up with these
host spans when a jax profiler session is active.
"""
from __future__ import annotations

import json
import time

__all__ = ["NULL_TRACER", "NullTracer", "TraceRecorder", "now_us",
           "merge_traces", "TRACK_SCHED", "TRACK_RETIRE", "TRACK_PREWARM",
           "TRACK_WAVE0"]

TRACK_SCHED = 1      # scheduler events (phases, group formation, instants)
TRACK_RETIRE = 2     # retire/finalize: the blocking device_get per wave
TRACK_PREWARM = 3    # background prewarm + stage resolution
TRACK_WAVE0 = 16     # first wave lane; lane k lives at TRACK_WAVE0 + k

_T0_NS = time.perf_counter_ns()


def now_us() -> float:
    """Monotonic microseconds since process trace epoch (import time).

    The single clock domain for every span *and* for the scheduler's
    per-phase ``wall_us`` stats, so wall-clock honesty and the timeline
    agree by construction."""
    return (time.perf_counter_ns() - _T0_NS) / 1e3


class _NullCM:
    __slots__ = ()

    def __enter__(self):
        return None

    def __exit__(self, *exc):
        return False


_NULL_CM = _NullCM()


class NullTracer:
    """The off path: every method is a no-op, ``enabled`` is False so hot
    loops can skip even the call.  A singleton (:data:`NULL_TRACER`) is
    the default everywhere — holding it adds zero instruments."""

    __slots__ = ()
    enabled = False

    def now_us(self) -> float:
        return 0.0

    def complete(self, name, tid, ts_us, dur_us=None, **args):
        pass

    def instant(self, name, tid, **args):
        pass

    def flow_start(self, fid, tid, name="wave"):
        pass

    def flow_end(self, fid, tid, name="wave"):
        pass

    def name_track(self, tid, name):
        pass

    def span(self, name, tid, **args):
        return _NULL_CM

    def device_span(self, name):
        return _NULL_CM


NULL_TRACER = NullTracer()


class _Span:
    """Context manager recording one complete ('X') event on exit."""

    __slots__ = ("_rec", "_name", "_tid", "_args", "_t0")

    def __init__(self, rec, name, tid, args):
        self._rec, self._name, self._tid, self._args = rec, name, tid, args

    def __enter__(self):
        self._t0 = now_us()
        return self

    def __exit__(self, *exc):
        self._rec._push(("X", self._name, self._tid, self._t0,
                         now_us() - self._t0, None, self._args))
        return False


class TraceRecorder:
    """Monotonic-clock ring-buffer span recorder (see module docstring).

    ``capacity`` bounds the ring (records, not bytes); ``pid`` becomes
    the Chrome-trace process lane (the dist worker passes its process
    index so merged traces keep one lane group per process);
    ``jax_bridge=True`` makes :meth:`device_span` emit a matching
    ``jax.profiler.TraceAnnotation`` around each stage dispatch."""

    enabled = True

    def __init__(self, capacity: int = 1 << 16, pid: int = 0,
                 jax_bridge: bool = False):
        if capacity < 8:
            raise ValueError(f"trace ring capacity too small: {capacity}")
        self._ring: list = [None] * int(capacity)
        self._cap = int(capacity)
        self._n = 0                      # total records ever pushed
        self.pid = int(pid)
        self.jax_bridge = bool(jax_bridge)
        self._track_names: dict[int, str] = {}

    # -- recording (hot path: one tuple + one slot store) -------------------- #
    def now_us(self) -> float:
        return now_us()

    def _push(self, rec: tuple) -> None:
        self._ring[self._n % self._cap] = rec
        self._n += 1

    def complete(self, name: str, tid: int, ts_us: float,
                 dur_us: float | None = None, **args) -> None:
        """Record a complete ('X') span given its pre-fetched host-scalar
        start (and optionally duration); no device value ever enters."""
        if dur_us is None:
            dur_us = now_us() - ts_us
        self._push(("X", name, tid, ts_us, dur_us, None, args or None))

    def instant(self, name: str, tid: int, **args) -> None:
        self._push(("i", name, tid, now_us(), 0.0, None, args or None))

    def flow_start(self, fid: int, tid: int, name: str = "wave") -> None:
        self._push(("s", name, tid, now_us(), 0.0, int(fid), None))

    def flow_end(self, fid: int, tid: int, name: str = "wave") -> None:
        self._push(("f", name, tid, now_us(), 0.0, int(fid), None))

    def name_track(self, tid: int, name: str) -> None:
        self._track_names.setdefault(int(tid), str(name))

    def span(self, name: str, tid: int, **args) -> _Span:
        """``with tracer.span("prewarm", TRACK_PREWARM, scap=64): ...``"""
        return _Span(self, name, tid, args or None)

    def device_span(self, name: str):
        """Optional jax.profiler bridge: a TraceAnnotation matching the
        host span, so device profiles line up with these lanes.  A
        no-op context manager unless ``jax_bridge`` was requested."""
        if not self.jax_bridge:
            return _NULL_CM
        import jax

        return jax.profiler.TraceAnnotation(name)

    # -- export --------------------------------------------------------------- #
    @property
    def n_recorded(self) -> int:
        return self._n

    @property
    def n_dropped(self) -> int:
        return max(0, self._n - self._cap)

    def records(self) -> list[tuple]:
        """Ring contents in record order (oldest surviving first)."""
        if self._n <= self._cap:
            return [r for r in self._ring[:self._n]]
        head = self._n % self._cap
        return self._ring[head:] + self._ring[:head]

    def events(self) -> list[dict]:
        """Chrome trace-event dicts: track metadata first, then the ring
        in record order.  Every event carries ``ph/ts/pid/tid``."""
        pid = self.pid
        out: list[dict] = [dict(name="process_name", ph="M", ts=0, pid=pid,
                                tid=0, args=dict(name=f"rads p{pid}"))]
        for tid, name in sorted(self._track_names.items()):
            out.append(dict(name="thread_name", ph="M", ts=0, pid=pid,
                            tid=tid, args=dict(name=name)))
        for ph, name, tid, ts, dur, fid, args in self.records():
            ev = dict(name=name, ph=ph, ts=ts, pid=pid, tid=tid, cat="rads")
            if ph == "X":
                ev["dur"] = dur
            elif ph == "i":
                ev["s"] = "t"
            elif ph in ("s", "f"):
                ev["cat"] = "wave-flow"
                ev["id"] = fid
                if ph == "f":
                    ev["bp"] = "e"   # bind to the enclosing retire span
            if args:
                ev["args"] = args
            out.append(ev)
        return out

    def to_chrome(self) -> dict:
        return {"traceEvents": self.events(),
                "displayTimeUnit": "ms",
                "otherData": {"dropped_records": self.n_dropped,
                              "pid": self.pid}}

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f)
        return path


def merge_traces(docs: list[dict]) -> dict:
    """Merge per-process Chrome trace docs into one (the dist contract:
    each process's recorder carried its own ``pid``, so concatenation IS
    the merge — lanes stay grouped per process in Perfetto)."""
    events: list[dict] = []
    dropped = 0
    for doc in docs:
        events.extend(doc.get("traceEvents", []))
        dropped += int(doc.get("otherData", {}).get("dropped_records", 0))
    return {"traceEvents": events, "displayTimeUnit": "ms",
            "otherData": {"dropped_records": dropped,
                          "merged_processes": len(docs)}}
