"""Declared metric schema — the single source of truth for stats keys.

Every key :func:`repro.core.driver.rads_enumerate` can emit is declared
here as a typed :class:`~repro.obs.metrics.Instrument`; the driver
builds its ``stats`` object from :func:`build_driver_registry` instead
of an ad-hoc dict literal.  Declarations are *literal* constructor
calls with string-constant names on purpose: radslint's RL004 metric
extension parses this module's AST and verifies each declared
instrument actually reaches an exporter / benchmark column (see
``[tool.radslint] metric_schema`` / ``metric_consumers`` in
pyproject.toml), the same threading guarantee ``WaveState`` byte
counters already have.

Group tuples mirror which subsystem *owns* the instrument — scheduler,
exchange/wire, AdjCache, compile pipeline — matching who registers or
writes it at runtime.  The ``StageExecCache`` hit/miss/store counters
are deliberately NOT here: they are registry-internal to
``runtime/compile_cache.py`` (a nested registry surfaced through the
single ``exec_cache`` instrument below), not top-level stats keys.
"""
from __future__ import annotations

from repro.obs.metrics import (COUNTER, GAUGE, HISTOGRAM, INFO, Instrument,
                               MetricsRegistry)

__all__ = ["counter", "gauge", "info", "histogram", "build_driver_registry",
           "DRIVER_SCHEMA", "SCHEDULER_SCHEMA", "EXCHANGE_SCHEMA",
           "CACHE_SCHEMA", "COMPILE_SCHEMA", "WIRE_SCHEMA"]


def counter(name: str, unit: str = "", desc: str = "") -> Instrument:
    return Instrument(name, COUNTER, unit, desc)


def gauge(name: str, unit: str = "", desc: str = "") -> Instrument:
    return Instrument(name, GAUGE, unit, desc)


def info(name: str, desc: str = "") -> Instrument:
    return Instrument(name, INFO, "", desc)


def histogram(name: str, unit: str = "", desc: str = "") -> Instrument:
    return Instrument(name, HISTOGRAM, unit, desc)


# -- driver: seed classification, plan, result assembly ---------------------- #
DRIVER_SCHEMA = (
    gauge("n_sme_seeds", "", "seeds eligible for the machine-local SM-E phase"),
    gauge("n_dist_seeds", "", "seeds requiring the distributed R-Meef phase"),
    counter("n_groups", "", "Algorithm-3 region groups formed (max per dev)"),
    gauge("plan_rounds", "", "rounds in the chosen matching plan"),
    counter("sme_count", "", "embeddings found in the SM-E phase"),
    counter("dist_count", "", "embeddings found in the distributed phase"),
    gauge("storage_format", "", "on-device adjacency layout"),
    gauge("peak_adj_bytes", "bytes", "resident adjacency footprint"),
    gauge("priors_preloaded", "", "persisted capacity/cost priors were used"),
    gauge("prior_cost_p90", "", "p90 per-seed cost from the persisted hist"),
    histogram("node_hist", "", "per-seed node-count histogram (priors v2)"),
    gauge("final_caps", "", "frontier/fetch/verify caps after escalation"),
)

# -- scheduler: waves, robustness loop, wall attribution ---------------------- #
SCHEDULER_SCHEMA = (
    counter("n_waves", "", "waves retired across both phases"),
    gauge("max_inflight_waves", "", "peak waves concurrently in flight"),
    counter("steal_events", "", "checkR/shareR queue steals"),
    counter("overflow_retries", "", "overflow-driven group splits (§6)"),
    counter("cap_escalations", "", "elastic capacity escalations (§6)"),
    counter("wave_s_total", "s", "summed wave dispatch->retire wall"),
    gauge("pipeline_depth", "", "configured pipeline depth ('auto' adapts)"),
    gauge("auto_depth", "", "depth the adaptive scheduler settled on"),
    counter("sme_pipeline_s", "s", "SM-E phase pipeline wall (perf_counter)"),
    counter("dist_pipeline_s", "s", "dist phase pipeline wall (perf_counter)"),
    counter("sme_wall_us", "us", "SM-E phase wall on the span clock"),
    counter("dist_wall_us", "us", "dist phase wall on the span clock"),
    counter("wall_us", "us", "total phase wall on the span clock "
                             "(max-merged across processes)"),
    gauge("wall_skew", "", "max/mean per-process wall_us after merge"),
    gauge("per_process_wall_us", "us", "per-process wall_us list after merge"),
)

# -- exchange backends: wire traffic + process topology ----------------------- #
EXCHANGE_SCHEMA = (
    gauge("process_index", "", "this process's index in the dist job"),
    gauge("process_count", "", "processes participating in the dist job"),
    gauge("comm_pipeline", "", "pipelined group communication enabled"),
    gauge("comm_chunks", "", "communication chunks per group exchange"),
    counter("bytes_fetch", "bytes", "raw fetchV byte accounting"),
    counter("bytes_verify", "bytes", "raw verifyE byte accounting"),
    counter("bytes_wire_fetch", "bytes", "actual coded fetchV wire bytes"),
    counter("bytes_wire_verify", "bytes", "actual coded verifyE wire bytes"),
    histogram("bytes_wire_fetch_dev", "bytes", "per-device fetch wire bytes"),
    histogram("bytes_wire_verify_dev", "bytes", "per-device verify wire bytes"),
    gauge("bytes_wire_max_dev", "bytes", "max per-device total wire bytes"),
    gauge("comm_skew", "", "max/mean per-device wire bytes"),
)

# -- AdjCache: device-resident foreign-adjacency cache ------------------------- #
CACHE_SCHEMA = (
    gauge("cache_enabled", "", "AdjCache constructed for this run"),
    gauge("cache_bytes", "bytes", "AdjCache slab footprint"),
    counter("cache_hits", "", "AdjCache probe hits"),
    counter("cache_probes", "", "AdjCache probes"),
    gauge("cache_hit_rate", "", "hits/probes for this run"),
    counter("bytes_saved_cache", "bytes", "wire bytes avoided by cache hits"),
)

# -- compile pipeline: stage jits + persistent executable store ---------------- #
COMPILE_SCHEMA = (
    counter("compiles", "", "stage traces compiled this call"),
    counter("compile_s", "s", "wall spent in .lower().compile()"),
    counter("compile_cache_hits", "", "StageRunner slot/store hits"),
    gauge("exec_cache_enabled", "", "persistent executable store active"),
    gauge("exec_cache", "", "StageExecCache counter deltas for this call"),
)

# -- wire codecs ---------------------------------------------------------------- #
WIRE_SCHEMA = (
    info("wire_format", "codec actually used on the wire"),
    info("wire_format_requested", "codec requested by EngineConfig"),
    info("wire_auto_reason", "why measured auto-selection chose the codec"),
    counter("bytes_fetch_compressed", "bytes",
            "modeled compressed fetch baseline"),
)

_ALL_GROUPS = (DRIVER_SCHEMA, SCHEDULER_SCHEMA, EXCHANGE_SCHEMA,
               CACHE_SCHEMA, COMPILE_SCHEMA, WIRE_SCHEMA)


def build_driver_registry() -> MetricsRegistry:
    """Fresh per-run registry declaring every instrument the driver,
    scheduler, exchange, caches, and wire codecs may write."""
    reg = MetricsRegistry()
    for group in _ALL_GROUPS:
        for ins in group:
            reg.register(Instrument(ins.name, ins.kind, ins.unit, ins.desc))
    return reg
