"""Query patterns: automorphism-based symmetry breaking, span, distances (§2)."""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field

import numpy as np


@dataclass(frozen=True)
class Pattern:
    """Unlabeled, undirected, connected query pattern (3-10 vertices)."""

    n: int
    edges: frozenset[tuple[int, int]]  # canonical (min, max) pairs

    @staticmethod
    def from_edges(edges) -> "Pattern":
        es = frozenset((min(a, b), max(a, b)) for a, b in edges if a != b)
        n = max(max(e) for e in es) + 1
        p = Pattern(n=n, edges=es)
        if not p.is_connected():
            raise ValueError("pattern must be connected")
        return p

    def has_edge(self, a: int, b: int) -> bool:
        return (min(a, b), max(a, b)) in self.edges

    def adj(self, u: int) -> list[int]:
        out = []
        for (a, b) in self.edges:
            if a == u:
                out.append(b)
            elif b == u:
                out.append(a)
        return sorted(out)

    def degree(self, u: int) -> int:
        return len(self.adj(u))

    def degrees(self) -> np.ndarray:
        return np.array([self.degree(u) for u in range(self.n)], dtype=np.int32)

    def is_connected(self) -> bool:
        seen = {0}
        stack = [0]
        while stack:
            u = stack.pop()
            for w in self.adj(u):
                if w not in seen:
                    seen.add(w)
                    stack.append(w)
        return len(seen) == self.n

    def distances(self) -> np.ndarray:
        """All-pairs shortest path (BFS per vertex)."""
        n = self.n
        d = np.full((n, n), n + 1, dtype=np.int32)
        for s in range(n):
            d[s, s] = 0
            frontier = [s]
            dd = 0
            while frontier:
                dd += 1
                nxt = []
                for u in frontier:
                    for w in self.adj(u):
                        if d[s, w] > dd:
                            d[s, w] = dd
                            nxt.append(w)
                frontier = nxt
        return d

    def span(self, u: int) -> int:
        """Definition 2: max shortest distance from u to any other vertex."""
        return int(self.distances()[u].max())

    def automorphisms(self) -> list[tuple[int, ...]]:
        autos = []
        deg = tuple(self.degree(u) for u in range(self.n))
        for perm in itertools.permutations(range(self.n)):
            if tuple(deg[perm[u]] for u in range(self.n)) != deg:
                continue
            if all(self.has_edge(perm[a], perm[b]) for (a, b) in self.edges):
                autos.append(perm)
        return autos

    def symmetry_constraints(self) -> list[tuple[int, int]]:
        """Grochow-Kellis symmetry breaking [8]: returns pairs (a, b) meaning
        every reported embedding must satisfy f(a) < f(b). Guarantees each
        isomorphic image is enumerated exactly once."""
        A = self.automorphisms()
        constraints: list[tuple[int, int]] = []
        while len(A) > 1:
            u = None
            for cand in range(self.n):
                orbit = {a[cand] for a in A}
                if len(orbit) > 1:
                    u = cand
                    break
            if u is None:
                break
            orbit = {a[u] for a in A}
            for v in sorted(orbit - {u}):
                constraints.append((u, v))
            A = [a for a in A if a[u] == u]
        return constraints
