"""R-Meef: region-grouped multi-round expand, verify & filter (§3, App. B).

One vectorized, static-shape engine serves four roles:

* **SM-E** (``local_only=True``): the paper's single-machine pass over seeds
  whose border distance >= span(u_start) (Prop. 1) — no collectives at all.
* **Distributed R-Meef** (``local_only=False``): per unit (= round),
  ``fetchV`` (batched foreign-adjacency fetch with dedup) then per-leaf
  expansion with local verification, then one batched ``verifyE`` exchange
  over the EVI (deduped undetermined edges; Def. 5, Prop. 2).
* the **reference** modes (``Exchange('sim')`` / ``Exchange('gather')``) on
  one device, and
* the **production** mode (``Exchange('spmd', mesh)``) where the leading
  ``ndev`` axis is sharded over the mesh and exchanges are ``all_to_all``.

All shapes are static: capacities come from ``EngineConfig``; every overflow
is *detected and flagged*, and the scheduler reacts by splitting region groups
(§6 memory control — robustness mechanism, not an error path).

The per-unit round is decomposed into three separately-jittable stages over
an immutable :class:`WaveState` pytree —

* :func:`fetch_stage`  — the batched ``fetchV`` request/response exchange,
* :func:`expand_stage` — every ``_leaf_step`` of the unit (candidate
  generation, local filters, EVI recording),
* :func:`verify_stage` — the batched ``verifyE`` exchange + alive-masking —

so that :mod:`repro.core.scheduler` can pipeline stages of *different*
region-group waves (double-buffered exchanges).  :func:`run_rounds` remains
as the synchronous composition of the stages; stage boundaries carry no
semantics, so ``run_rounds == staged pipeline`` byte-for-byte.

``fetch_stage`` additionally threads the optional device-resident
foreign-adjacency cache (:class:`~repro.core.cache.AdjCache`): unique
foreign pivots are probed *before* the a2a request is built (hits are
masked off the wire), cached rows are merged over the responses after the
exchange, and miss responses enter under the benefit-based admission rule
— all inside the jitted stage, so cache state crosses stage and wave
boundaries as a pytree with no host round-trips.  Cache state only changes
which transport delivers a row, never its bytes, so enumeration results
are cache-invariant.

Both exchanges additionally speak the pluggable **wire format**
(``ExchangeBackend.wire_format``, selected by ``EngineConfig.wire_format``):
with ``"varint"`` the request/response payloads are encoded as compact
``uint8`` streams *inside the jitted stages* (:mod:`repro.core.wire` —
delta+varint ids and rows for ``fetchV``, Elias-Fano + run-delta pairs and
bit-packed answers for ``verifyE``) and decoded on the receiving device;
``bytes_wire_fetch``/``bytes_wire_verify`` account the actual stream
lengths, while ``bytes_fetch``/``bytes_verify`` keep the raw-equivalent
accounting so the two formats stay comparable.  The codecs are exact, so
results are wire-format-invariant.

The engine reads adjacency exclusively through the pluggable
:class:`~repro.graph.storage.DeviceGraph` interface (``rows_at``/``deg_at``
over the stacked layout): the ``dense`` format is the seed's padded array,
``bucketed`` stores degree-bucketed CSR slabs — both produce byte-identical
results because ``rows_at`` reassembles the same sentinel-padded windows.

Accelerator kernels (gated by ``EngineConfig.use_pallas_kernels``, jnp refs
as the CPU test path):

* membership tests (back-edge checks in ``_leaf_step`` on the dense layout
  and the ``verifyE`` answer path) route through
  :mod:`repro.kernels.membership.ops`;
* candidate generation on the **bucketed** layout routes the back-edge
  refinement ``C(u) ∩ adj(f(u'))`` (Alg. 1 line 6) through
  :mod:`repro.kernels.intersect.ops` instead.
"""
from __future__ import annotations

from dataclasses import dataclass, replace

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.rads import EngineConfig
from repro.core.cache import AdjCache, probe_dev
from repro.core.exchange import (ExchangeBackend, compact,
                                 unique_ids, unique_pairs)
from repro.core import wire as wire_codec
from repro.core.plan import Plan
from repro.graph.storage import DeviceGraph
from repro.kernels.intersect.ops import (intersect as _intersect_op,
                                         tile_defaults as _intersect_tiles)
from repro.kernels.membership.ops import membership as _membership_op


def _membership(rows: jnp.ndarray, vals: jnp.ndarray,
                use_pallas: bool = False) -> jnp.ndarray:
    """Back-edge / verifyE membership test, kernel-gated.

    ``use_pallas=False`` is the jnp reference lowering (CPU test path);
    ``True`` runs the Pallas kernel (interpreted off-TPU)."""
    return _membership_op(rows, vals, use_kernel=use_pallas,
                          interpret=jax.default_backend() != "tpu")


def _backedge_mask(g: DeviceGraph, w_row: jnp.ndarray, cand: jnp.ndarray,
                   cfg: EngineConfig) -> jnp.ndarray:
    """Candidate-generation back-edge filter: is cand[r, j] in w_row[r]?

    Formats with ``intersect_backedge`` (the bucketed layout) route the
    sorted-window intersection ``C(u) ∩ adj(f(u'))`` through the Pallas
    ``intersect`` kernel (jnp ref off-kernel); the rest keep the
    ``membership`` lowering (the bit-exact seed path).  The two differ only
    where ``cand == sentinel`` — positions the caller has already
    invalidated — so the final masks are identical.
    """
    if g.intersect_backedge:
        # tile the kernel against the *bucket* caps, not the padded window:
        # on the bucketed layout every row's content fits the top cap, so
        # small-bucket graphs get narrower m-chunks (less sentinel traffic)
        caps = getattr(g, "bucket_caps", None)
        bb, mc = _intersect_tiles(caps[-1]) if caps else (None, None)
        mask, _ = _intersect_op(cand, w_row, sentinel=g.n,
                                use_kernel=cfg.use_pallas_kernels,
                                interpret=jax.default_backend() != "tpu",
                                block_b=bb, m_chunk=mc)
        return mask
    return _membership(w_row, cand, cfg.use_pallas_kernels)


# --------------------------------------------------------------------------- #
# Static plan data
# --------------------------------------------------------------------------- #
@dataclass(frozen=True)
class StepSpec:
    col: int                      # column this leaf writes (matching order)
    piv_col: int
    unit_idx: int
    leaf: int                     # query vertex id
    leaf_deg: int                 # degree filter
    back_cols: tuple[int, ...]    # earlier cols with an edge to leaf (no pivot)
    sym_lt_cols: tuple[int, ...]  # require rows[:, c] <  cand
    sym_gt_cols: tuple[int, ...]  # require cand < rows[:, c]


@dataclass(frozen=True)
class PlanData:
    order: tuple[int, ...]
    col_of: tuple[int, ...]                  # query vertex -> column
    steps: tuple[StepSpec, ...]
    unit_piv_cols: tuple[int, ...]
    unit_steps: tuple[tuple[int, ...], ...]  # step indices per unit
    start_deg: int
    u_start: int
    span_start: int


def build_plan_data(plan: Plan) -> PlanData:
    p = plan.pattern
    order = plan.matching_order
    assert order, "plan must carry a matching order (use best_plan)"
    col_of = [0] * p.n
    for i, u in enumerate(order):
        col_of[u] = i
    cons = p.symmetry_constraints()
    steps: list[StepSpec] = []
    unit_piv_cols: list[int] = []
    unit_steps: list[tuple[int, ...]] = []
    placed = {order[0]}
    for ui, unit in enumerate(plan.units):
        piv_col = col_of[unit.piv]
        unit_piv_cols.append(piv_col)
        sids: list[int] = []
        for lf in sorted(unit.leaves, key=lambda v: col_of[v]):
            back = tuple(col_of[w] for w in p.adj(lf)
                         if w in placed and w != unit.piv)
            lt = tuple(col_of[a] for (a, b) in cons if b == lf and a in placed)
            gt = tuple(col_of[b] for (a, b) in cons if a == lf and b in placed)
            steps.append(StepSpec(col=col_of[lf], piv_col=piv_col,
                                  unit_idx=ui, leaf=lf,
                                  leaf_deg=p.degree(lf), back_cols=back,
                                  sym_lt_cols=lt, sym_gt_cols=gt))
            sids.append(len(steps) - 1)
            placed.add(lf)
        unit_steps.append(tuple(sids))
    return PlanData(order=order, col_of=tuple(col_of), steps=tuple(steps),
                    unit_piv_cols=tuple(unit_piv_cols),
                    unit_steps=tuple(unit_steps),
                    start_deg=p.degree(order[0]), u_start=order[0],
                    span_start=p.span(order[0]))


# --------------------------------------------------------------------------- #
# fetchV / verifyE exchanges
# --------------------------------------------------------------------------- #
def _per_peer_compact(ids, mask, owners, ndev: int, cap_out: int, fill: int,
                      extras: tuple = ()):
    """Split a sorted id list into per-peer request buffers (ndev, cap_out).

    ``extras``: ``(array, fill)`` pairs co-compacted with ``ids`` through
    the same argsort (the cached fetch path routes hit flags / ways /
    cached rows alongside the ids).  Returns ``(reqs, *extras_compacted,
    counts, overflow)``; order within a peer stays sorted."""
    def one_peer(p):
        m = mask & (owners == p)
        _, ov, *outs = compact(
            m, cap_out, ids, *(a for a, _ in extras),
            fills=(fill, *(f for _, f in extras)))
        return (*outs, m.sum(), ov)

    *outs, counts, ovs = jax.vmap(one_peer)(jnp.arange(ndev))
    return (*outs, counts, jnp.any(ovs))


def _varint_id_bytes(wire: jnp.ndarray, n: int) -> jnp.ndarray:
    """Modeled delta+varint size of the fetchV id payloads.

    ``wire``: (ndev, peer, fcap) request buffers — ids ascending among the
    valid (< n) entries, sentinel holes allowed (cache hits are masked to
    ``n``).  Each peer stream is delta-coded against the previous valid id
    (the first id absolute) and each delta LEB128-varint sized; returns the
    per-(src, peer) byte matrix for
    :meth:`~repro.core.exchange.ExchangeBackend.off_device_payload_bytes`.
    """
    valid = wire < n
    run = jax.lax.cummax(jnp.where(valid, wire, -1), axis=wire.ndim - 1)
    prev = jnp.concatenate(
        [jnp.full(run[..., :1].shape, -1, run.dtype), run[..., :-1]], axis=-1)
    delta = jnp.maximum(jnp.where(prev >= 0, wire - prev, wire), 0)
    # deltas >= 2^28 would take 5 LEB128 bytes; the modeled format falls
    # back to the raw 4-byte int32 for those (the escape tag is amortized
    # into the varint sizes), so compressed <= raw 4B/id always holds
    vlen = (1 + (delta >= 1 << 7).astype(jnp.int32)
            + (delta >= 1 << 14).astype(jnp.int32)
            + (delta >= 1 << 21).astype(jnp.int32))
    return jnp.where(valid, vlen, 0).sum(-1)           # (ndev, peer)


def fetch_exchange(g: DeviceGraph, exch: ExchangeBackend,
                   pivots, need, fcap: int, cache: AdjCache | None = None,
                   use_pallas: bool = False):
    """Batched fetchV (§3.2 Expand): dedup foreign pivot ids, probe the
    adjacency cache, exchange the misses, answer with local adjacency rows,
    exchange back, merge cached rows in, and admit the miss responses.

    pivots/need: (ndev, cap).  Returns ``(req_ids (ndev, ndev, fcap) sorted
    per peer — hits included, so the expand-side searchsorted lookup is
    cache-agnostic, fetched_adj (ndev, ndev, fcap, maxdeg) with cached rows
    merged in, overflow, fstats, cache')`` where ``fstats`` carries the
    per-call byte/hit accounting (``bytes_fetch`` counts only what actually
    crossed the wire; ``bytes_saved_cache`` is the hit-masked remainder;
    ``bytes_fetch_compressed`` models delta+varint id coding of the wire
    payload).  With ``cache=None`` the request path is byte-identical to
    the uncached engine and ``cache'`` is ``None``.

    With ``exch.wire_format == "varint"`` the hole-masked request lanes are
    delta+varint coded (:mod:`repro.core.wire`) and the a2a transports the
    ``uint8`` streams + per-lane lengths; the answering device decodes,
    responds with degree+delta coded rows, and the requester scatters the
    compacted responses back onto its hole positions — decoded payloads
    are bit-identical to the raw slabs, so only ``bytes_wire_fetch``
    (actual stream lengths, always <= ``bytes_fetch``) changes.
    """
    ndev, stride, n, D = g.ndev, g.stride, g.n, g.max_degree
    t_ids = jnp.arange(ndev)
    use_cache = cache is not None

    def build(t, pv, nd, ck=None, cr=None):
        foreign = nd & (pv // stride != t) & (pv < n)
        uids, umask = unique_ids(pv, foreign, n)
        if use_cache:
            hit, hway, crow = probe_dev(ck, cr, uids, n)
            hit = hit & umask
        else:
            hit = jnp.zeros(uids.shape, bool)
            hway = jnp.zeros(uids.shape, jnp.int32)
            crow = jnp.full(uids.shape + (1,), n, jnp.int32)  # placeholder
        owners = jnp.clip(uids // stride, 0, ndev - 1)
        return _per_peer_compact(uids, umask, owners, ndev, fcap, n,
                                 extras=((hit, False), (hway, 0), (crow, n)))

    if use_cache:
        (reqs, hit_c, way_c, crow_c, counts, ovs) = jax.vmap(
            build)(t_ids, pivots, need, cache.keys, cache.rows)
        # hits never cross the wire: mask them out of the a2a request
        wire = jnp.where(hit_c, n, reqs)
    else:
        (reqs, hit_c, way_c, crow_c, counts, ovs) = jax.vmap(
            build)(t_ids, pivots, need)
        wire = reqs
    # per-peer hit counts from the compacted flags: identical to the
    # pre-compaction count for every *consumed* wave (an overflowing wave's
    # stats are discarded at retire, so truncation never reaches them)
    counts_hit = hit_c.sum(-1).astype(counts.dtype)

    def answer(t, rc):
        li = jnp.clip(rc - t * stride, 0, stride - 1)
        ok = (rc // stride == t) & (rc < n)
        return jnp.where(ok[..., None], g.rows_at(t, li), n)

    if exch.wire_format == "varint":
        # coded path: compacted varint id streams out, degree+delta coded
        # row streams back, sender scatters onto its hole positions
        req_cap, degs_cap, rows_cap = wire_codec.fetch_stream_caps(fcap, D)
        interp = jax.default_backend() != "tpu"
        req_s, req_len, req_raw, e_ov, model_ids = \
            wire_codec.encode_ids_lanes(wire, n, req_cap,
                                        use_pallas=use_pallas,
                                        interpret=interp)
        recv_s, recv_len, recv_raw = exch.a2a_tree((req_s, req_len, req_raw))
        dec_ids, dec_mask = wire_codec.decode_ids_lanes(
            recv_s, recv_len, recv_raw, fcap, n)
        resp = jax.vmap(answer)(t_ids, dec_ids)        # (ndev, src, fcap, D)
        dg_s, dg_len, ri_s, ri_len, resp_raw, r_ov = \
            wire_codec.encode_rows_lanes(resp, dec_mask, n, degs_cap,
                                         rows_cap)
        bk_dg, bk_dgl, bk_ri, bk_ril, bk_raw = exch.a2a_tree(
            (dg_s, dg_len, ri_s, ri_len, resp_raw))
        rows_c = wire_codec.decode_rows_lanes(bk_dg, bk_dgl, bk_ri, bk_ril,
                                              bk_raw, fcap, D, n)
        fetched = wire_codec.scatter_compacted_lanes(rows_c, wire < n, n)
        wire_stream_bytes = (
            exch.off_device_payload_bytes(req_len)
            + exch.off_device_payload_bytes(dg_len + ri_len))
        # sent-bytes attribution: requesters send the id streams, responders
        # send the row streams — row sums recover wire_stream_bytes exactly
        wire_dev = (exch.per_dev_sent_bytes(req_len)
                    + exch.per_dev_sent_bytes(dg_len + ri_len))
        wire_ov = e_ov | r_ov
    else:
        recv = exch.a2a(wire)                          # (ndev, src, fcap)
        resp = jax.vmap(answer)(t_ids, recv)           # (ndev, src, fcap, D)
        fetched = exch.a2a(resp)                       # (ndev, peer, fcap, D)
        wire_stream_bytes = None
        wire_ov = jnp.zeros((), bool)
        model_ids = None
    if use_cache:
        # merge cached rows over the (sentinel) responses of masked slots,
        # then run the admission pass over this batch's probe outcomes
        fetched = jnp.where(hit_c[..., None], crow_c, fetched)
        cache = cache.updated(reqs.reshape(ndev, -1),
                              hit_c.reshape(ndev, -1),
                              way_c.reshape(ndev, -1),
                              fetched.reshape(ndev, -1, D))

    # 4B request id + 4B * max_degree response row per off-device entry
    elem = 4 * (1 + D)
    full_bytes = exch.off_device_bytes(counts, elem)
    wire_bytes = exch.off_device_bytes(counts - counts_hit, elem) \
        if use_cache else full_bytes
    if wire_stream_bytes is None:
        # raw path per-device attribution: requester t sends 4B ids per
        # entry (eff[t, p]), responder p sends 4*D-byte rows back (eff.T);
        # the two row sums add up to wire_bytes exactly
        eff = (counts - counts_hit if use_cache else counts)
        wire_dev = (exch.per_dev_sent_bytes(eff * 4.0)
                    + exch.per_dev_sent_bytes(eff.T * (4.0 * D)))
    # the modeled column reuses the codec's sizing pass when it already ran
    comp_ids = (_varint_id_bytes(wire, n) if model_ids is None
                else model_ids)
    comp_bytes = (exch.off_device_payload_bytes(comp_ids)
                  + exch.off_device_bytes(counts - counts_hit, 4.0 * D))
    zero = jnp.zeros((), jnp.float32)
    fstats = dict(
        bytes_fetch=wire_bytes,
        bytes_fetch_compressed=comp_bytes,
        # actual on-the-wire bytes: stream lengths under 'varint', the raw
        # accounting under 'raw' (per-lane raw escape keeps this <= raw)
        bytes_wire_fetch=(wire_stream_bytes if wire_stream_bytes is not None
                          else wire_bytes),
        bytes_wire_fetch_dev=wire_dev,
        bytes_saved_cache=full_bytes - wire_bytes,
        # probe/hit counters exist only when there is a cache to probe —
        # a --no-cache run must audit as having zero cache activity
        cache_hits=counts_hit.sum().astype(jnp.float32) if use_cache
        else zero,
        cache_probes=counts.sum().astype(jnp.float32) if use_cache
        else zero)
    return reqs, fetched, jnp.any(ovs) | wire_ov, fstats, cache


def verify_exchange(g: DeviceGraph, exch: ExchangeBackend,
                    pa, pb, pmask, vcap: int, use_pallas: bool = False):
    """Batched verifyE over the EVI (§3.2). pa/pb/pmask: (ndev, R, K).
    Pairs routed to owner(pa). Returns (ok (ndev, R, K) — True where the
    edge exists or the slot is inactive, overflow, off_bytes, wire_bytes,
    wire_dev) where ``wire_dev`` is the per-device *sent*-byte attribution
    of ``wire_bytes`` (requesters send the pair streams, owners send the
    answers; its sum recovers ``wire_bytes`` exactly).

    ``off_bytes`` is the raw-equivalent accounting (8 B/pair + 1 B/answer,
    comparable across wire formats); ``wire_bytes`` is what actually
    crossed: with ``exch.wire_format == "varint"`` the sorted ``a`` column
    goes Elias-Fano, ``b`` goes run-delta varint, and the answers come
    back bit-packed (:mod:`repro.core.wire`) — with ``"raw"`` the two are
    equal."""
    ndev, stride, n = g.ndev, g.stride, g.n
    R, K = pa.shape[1], pa.shape[2]
    fa, fb, fm = (x.reshape(ndev, R * K) for x in (pa, pb, pmask))

    ua, ub, umask, rank = jax.vmap(
        lambda a, b, m: unique_pairs(a, b, m, n))(fa, fb, fm)
    owners = jnp.clip(ua // stride, 0, ndev - 1)

    def build(uaa, ubb, mm, ow):
        ra, ca, ov_a = _per_peer_compact(uaa, mm, ow, ndev, vcap, n)
        rb, _, ov_b = _per_peer_compact(ubb, mm, ow, ndev, vcap, n)
        # uniques sorted by `a` => owners non-decreasing => peers contiguous;
        # slot inside peer block = index - first index of that owner
        start = jax.vmap(lambda o: jnp.searchsorted(ow, o))(ow)
        slot = jnp.arange(uaa.shape[0]) - start
        return ra, rb, ca, slot, ov_a | ov_b

    reqs_a, reqs_b, counts, slots, ov = jax.vmap(build)(ua, ub, umask, owners)

    def answer(t, ra, rb):
        li = jnp.clip(ra - t * stride, 0, stride - 1)
        local_ok = (ra // stride == t) & (ra < n)
        rows = g.rows_at(t, li)                        # (src, vcap, D)
        D = rows.shape[-1]
        memb = _membership(rows.reshape(-1, D), rb.reshape(-1, 1),
                           use_pallas).reshape(rb.shape)
        return memb & local_ok

    if exch.wire_format == "varint":
        # coded path: EF(a) + run-delta varint(b) out, bit-packed bools back
        a_cap, b_cap, ans_cap = wire_codec.verify_stream_caps(vcap)
        a_s, a_len, b_s, b_len, p_raw, p_ov = wire_codec.encode_pairs_lanes(
            reqs_a, reqs_b, n, a_cap, b_cap)
        ra_s, ra_len, rb_s, rb_len, r_raw, r_counts = exch.a2a_tree(
            (a_s, a_len, b_s, b_len, p_raw, counts))
        dec_a, dec_b, _ = wire_codec.decode_pairs_lanes(
            ra_s, ra_len, rb_s, rb_len, r_raw, r_counts, vcap, n, n)
        ans = jax.vmap(answer)(jnp.arange(ndev), dec_a, dec_b)
        ans_s, ans_len = wire_codec.pack_bools_lanes(ans, r_counts, ans_cap)
        back_s, _ = exch.a2a_tree((ans_s, ans_len))
        back = wire_codec.unpack_bools_lanes(back_s, counts, vcap)
        wire_bytes = (exch.off_device_payload_bytes(a_len + b_len)
                      + exch.off_device_payload_bytes(ans_len))
        wire_dev = (exch.per_dev_sent_bytes(a_len + b_len)
                    + exch.per_dev_sent_bytes(ans_len))
        ov = ov | p_ov
    else:
        # the (a, b) request buffers travel as one sub-state through the
        # backend
        recv_a, recv_b = exch.a2a_tree((reqs_a, reqs_b))
        ans = jax.vmap(answer)(jnp.arange(ndev), recv_a, recv_b)
        back = exch.a2a(ans)                           # (ndev, peer, vcap)
        wire_bytes = None

    def collect(bk, ow, sl, mm, rk):
        sl_c = jnp.clip(sl, 0, vcap - 1)
        ok_unique = bk[ow, sl_c] & mm & (sl < vcap)
        return ok_unique[jnp.clip(rk, 0, ok_unique.shape[0] - 1)]

    ok_flat = jax.vmap(collect)(back, owners, slots, umask, rank)
    ok = ok_flat.reshape(ndev, R, K) | ~pmask
    # 8B pair request + 1B bool response per off-device entry
    off_bytes = exch.off_device_bytes(counts, 8 + 1)
    if wire_bytes is None:
        wire_bytes = off_bytes
        # requester t sends 8B pairs (counts[t, p]); owner p sends 1B
        # answers back (counts.T) — row sums add up to off_bytes exactly
        wire_dev = (exch.per_dev_sent_bytes(counts * 8.0)
                    + exch.per_dev_sent_bytes(counts.T * 1.0))
    return ok, jnp.any(ov), off_bytes, wire_bytes, wire_dev


# --------------------------------------------------------------------------- #
# Leaf expansion
# --------------------------------------------------------------------------- #
def _leaf_step(g: DeviceGraph, cfg: EngineConfig, spec: StepSpec,
               k_off: int, rows, alive, seed_slot,
               pend_a, pend_b, pend_m, req_ids, fetched, local_only: bool):
    """Expand one leaf: candidates = adj(pivot); filter (injectivity,
    symmetry, degree, local back-edge intersection — Alg. 1+2); compact to
    frontier_cap; record undetermined edges into the pending (EVI) buffers.
    Adjacency is read through the format-agnostic ``DeviceGraph``."""
    ndev, stride, n, D = g.ndev, g.stride, g.n, g.max_degree
    cap = cfg.frontier_cap
    t_ids = jnp.arange(ndev)

    def dev(t, rws, alv, sslot, pa, pb, pm, rq, ft):
        R, w = rws.shape
        pv = rws[:, spec.piv_col]
        is_local = (pv // stride == t) & (pv < n)
        li = jnp.clip(pv - t * stride, 0, stride - 1)
        lrow = g.rows_at(t, li)                            # (R, D)
        if local_only:
            prow = jnp.where(is_local[:, None], lrow, n)
            lost = jnp.zeros((), bool)
        else:
            peer = jnp.clip(pv // stride, 0, ndev - 1)
            peer_ids = rq[peer]                            # (R, fcap)
            slot = jax.vmap(jnp.searchsorted)(peer_ids, pv[:, None])[:, 0]
            slot = jnp.clip(slot, 0, rq.shape[1] - 1)
            frow = ft[peer, slot]                          # (R, D)
            hit = jnp.take_along_axis(peer_ids, slot[:, None], 1)[:, 0] == pv
            prow = jnp.where(is_local[:, None], lrow,
                             jnp.where(hit[:, None], frow, n))
            lost = jnp.any(alv & (pv < n) & ~is_local & ~hit)

        cand = prow                                        # (R, D)
        valid = (cand < n) & alv[:, None]
        for c in range(w):                                 # injectivity
            valid &= cand != rws[:, c][:, None]
        for c in spec.sym_lt_cols:                         # symmetry breaking
            valid &= rws[:, c][:, None] < cand
        for c in spec.sym_gt_cols:
            valid &= cand < rws[:, c][:, None]
        c_local = (cand // stride == t) & (cand < n)
        c_li = jnp.clip(cand - t * stride, 0, stride - 1)
        valid &= jnp.where(c_local, g.deg_at(t, c_li) >= spec.leaf_deg, True)
        if local_only:
            valid &= c_local                               # Prop. 1 pruning
        for c in spec.back_cols:       # local checks (Alg 2 lines 3-5, 8-11)
            wv = rws[:, c]
            w_loc = (wv // stride == t) & (wv < n)
            w_row = g.rows_at(t, jnp.clip(wv - t * stride, 0, stride - 1))
            valid &= jnp.where(
                w_loc[:, None], _backedge_mask(g, w_row, cand, cfg), True)

        # compact (R*D) -> cap
        parent = jnp.repeat(jnp.arange(R, dtype=jnp.int32), D)
        new_mask, ov, parent_c, cand_c = compact(
            valid.reshape(-1), cap, parent, cand.reshape(-1), fill=0)
        new_rows = jnp.concatenate(
            [rws[parent_c], cand_c[:, None].astype(jnp.int32)], axis=1)
        new_rows = jnp.where(new_mask[:, None], new_rows, n)
        new_slot = jnp.where(new_mask, sslot[parent_c], 0)
        pa_n, pb_n, pm_n = pa[parent_c], pb[parent_c], pm[parent_c]
        pm_n &= new_mask[:, None]

        # new pending pairs: back edges whose f(u') is foreign. Route to the
        # local endpoint if the candidate is local (paper: verify locally),
        # else to owner(f(u')).
        for k, c in enumerate(spec.back_cols):
            wv_n = new_rows[:, c]
            cd = new_rows[:, -1]
            w_loc_n = (wv_n // stride == t) & (wv_n < n)
            c_loc_n = (cd // stride == t) & (cd < n)
            need = new_mask & ~w_loc_n
            a_val = jnp.where(c_loc_n, cd, wv_n)
            b_val = jnp.where(c_loc_n, wv_n, cd)
            pa_n = pa_n.at[:, k_off + k].set(jnp.where(need, a_val, n))
            pb_n = pb_n.at[:, k_off + k].set(jnp.where(need, b_val, n))
            pm_n = pm_n.at[:, k_off + k].set(need)
        return new_rows, new_mask, new_slot, pa_n, pb_n, pm_n, ov, lost

    if local_only:
        def dev_local(t, rws, alv, sslot, pa, pb, pm):
            return dev(t, rws, alv, sslot, pa, pb, pm, None, None)
        outs = jax.vmap(dev_local)(t_ids, rows, alive, seed_slot,
                                   pend_a, pend_b, pend_m)
    else:
        outs = jax.vmap(dev)(t_ids, rows, alive, seed_slot,
                             pend_a, pend_b, pend_m, req_ids, fetched)
    rows, alive, seed_slot, pend_a, pend_b, pend_m, ovs, losts = outs
    return (rows, alive, seed_slot, pend_a, pend_b, pend_m,
            jnp.any(ovs), jnp.any(losts))


# --------------------------------------------------------------------------- #
# WaveState: the immutable per-wave pytree threaded through the stages
# --------------------------------------------------------------------------- #
@jax.tree_util.register_pytree_node_class
@dataclass(frozen=True)
class WaveState:
    """Everything one region-group wave carries between pipeline stages.

    ``rows`` widens by one column per leaf step, so stage functions are
    jitted *per unit index* (each (unit, stage) pair has a distinct static
    shape).  ``pend_*`` (the EVI buffers, Def. 5) exist only on the
    expand→verify edge and are ``None`` elsewhere; ``rounds_alive`` grows by
    one per-device count per completed unit.

    Byte counters are f32 scalars (x64 is disabled), exact up to 2^24
    bytes *per wave* — per-wave traffic beyond ~16MB would round, and the
    cache conservation law (``bytes_fetch + bytes_saved_cache`` == the
    uncached ``bytes_fetch``) would then only hold approximately.  The
    driver accumulates across waves in Python floats, so only the
    single-wave total is bounded."""

    rows: jnp.ndarray            # (ndev, cap, width) partial embeddings
    alive: jnp.ndarray           # (ndev, cap) bool
    seed_slot: jnp.ndarray       # (ndev, cap) originating seed slot
    overflow: jnp.ndarray        # () bool — any capacity overflow so far
    lost: jnp.ndarray            # () bool — any dropped fetchV response
    bytes_fetch: jnp.ndarray     # () f32 — off-device fetchV wire traffic
    bytes_verify: jnp.ndarray    # () f32 — off-device verifyE traffic
    bytes_wire_fetch: jnp.ndarray   # () f32 — actual coded fetchV stream bytes
    bytes_wire_verify: jnp.ndarray  # () f32 — actual coded verifyE stream bytes
    bytes_wire_fetch_dev: jnp.ndarray   # (ndev,) f32 — fetchV wire bytes by
    # sending device (sums to bytes_wire_fetch; skew-curve source)
    bytes_wire_verify_dev: jnp.ndarray  # (ndev,) f32 — verifyE wire bytes by
    # sending device (sums to bytes_wire_verify)
    bytes_fetch_compressed: jnp.ndarray  # () f32 — modeled delta+varint wire
    bytes_saved_cache: jnp.ndarray       # () f32 — fetchV bytes hit-masked
    cache_hits: jnp.ndarray      # () f32 — unique foreign ids served by cache
    cache_probes: jnp.ndarray    # () f32 — unique foreign ids requested
    compile_cache_hits: jnp.ndarray  # () f32 — stage executables loaded, not
    # traced, for this wave's dispatches (StageRunner credits via the
    # finalize exec_hits argument; the field itself stays zero inside the
    # stages — host-side compile accounting never enters a trace)
    node_counts: jnp.ndarray     # (ndev, scap) trie nodes per seed (§6 calib)
    rounds_alive: tuple = ()     # per-unit (ndev,) alive counts
    pend_a: jnp.ndarray | None = None   # (ndev, cap, K) EVI endpoint a
    pend_b: jnp.ndarray | None = None   # (ndev, cap, K) EVI endpoint b
    pend_m: jnp.ndarray | None = None   # (ndev, cap, K) EVI slot active

    def tree_flatten(self):
        return ((self.rows, self.alive, self.seed_slot, self.overflow,
                 self.lost, self.bytes_fetch, self.bytes_verify,
                 self.bytes_wire_fetch, self.bytes_wire_verify,
                 self.bytes_wire_fetch_dev, self.bytes_wire_verify_dev,
                 self.bytes_fetch_compressed, self.bytes_saved_cache,
                 self.cache_hits, self.cache_probes,
                 self.compile_cache_hits,
                 self.node_counts, self.rounds_alive,
                 self.pend_a, self.pend_b, self.pend_m), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)


def init_wave(g: DeviceGraph, seeds, seed_mask) -> WaveState:
    """Stage 0: lift a padded (ndev, scap) seed block into a WaveState."""
    ndev = g.ndev
    scap = seeds.shape[1]
    return WaveState(
        rows=seeds[..., None].astype(jnp.int32),
        alive=seed_mask,
        seed_slot=jnp.broadcast_to(
            jnp.arange(scap, dtype=jnp.int32), seeds.shape),
        overflow=jnp.zeros((), bool),
        lost=jnp.zeros((), bool),
        bytes_fetch=jnp.zeros((), jnp.float32),
        bytes_verify=jnp.zeros((), jnp.float32),
        bytes_wire_fetch=jnp.zeros((), jnp.float32),
        bytes_wire_verify=jnp.zeros((), jnp.float32),
        bytes_wire_fetch_dev=jnp.zeros((ndev,), jnp.float32),
        bytes_wire_verify_dev=jnp.zeros((ndev,), jnp.float32),
        bytes_fetch_compressed=jnp.zeros((), jnp.float32),
        bytes_saved_cache=jnp.zeros((), jnp.float32),
        cache_hits=jnp.zeros((), jnp.float32),
        cache_probes=jnp.zeros((), jnp.float32),
        compile_cache_hits=jnp.zeros((), jnp.float32),
        node_counts=jnp.zeros((ndev, scap), jnp.int32))


def unit_evi_width(pd: PlanData, ui: int) -> int:
    """Number of EVI slots unit ``ui`` can emit (0 => verifyE is a no-op)."""
    return sum(len(pd.steps[s].back_cols) for s in pd.unit_steps[ui])


def fetch_stage(g: DeviceGraph, pd: PlanData, cfg: EngineConfig,
                exch: ExchangeBackend, ui: int, state: WaveState,
                local_only: bool, cache: AdjCache | None = None):
    """Pipeline stage 1 of unit ``ui``: batched fetchV on the unit pivot,
    with the foreign-adjacency cache probed before and fed after the a2a.

    Returns ``(state', bufs, cache')`` where ``bufs = (req_ids, fetched)``
    feeds ``expand_stage`` (``None`` in SM-E mode — no collectives at all)
    and ``cache'`` is the post-admission cache state the caller threads
    into the next fetch (``None`` stays ``None``)."""
    if local_only:
        return state, None, cache
    piv_col = pd.unit_piv_cols[ui]
    req_ids, fetched, f_ov, fs, cache = fetch_exchange(
        g, exch, state.rows[:, :, piv_col], state.alive,
        cfg.fetch_cap, cache, use_pallas=cfg.use_pallas_kernels)
    state = replace(
        state, overflow=state.overflow | f_ov,
        bytes_fetch=state.bytes_fetch + fs["bytes_fetch"],
        bytes_wire_fetch=state.bytes_wire_fetch + fs["bytes_wire_fetch"],
        bytes_wire_fetch_dev=(state.bytes_wire_fetch_dev
                              + fs["bytes_wire_fetch_dev"]),
        bytes_fetch_compressed=(state.bytes_fetch_compressed
                                + fs["bytes_fetch_compressed"]),
        bytes_saved_cache=state.bytes_saved_cache + fs["bytes_saved_cache"],
        cache_hits=state.cache_hits + fs["cache_hits"],
        cache_probes=state.cache_probes + fs["cache_probes"])
    return state, (req_ids, fetched), cache


def expand_stage(g: DeviceGraph, pd: PlanData, cfg: EngineConfig,
                 ui: int, state: WaveState, bufs, local_only: bool
                 ) -> WaveState:
    """Pipeline stage 2 of unit ``ui``: every leaf step of the unit —
    candidate generation from (local ∪ fetched) adjacency, injectivity /
    symmetry / degree / local-membership filters, frontier compaction, and
    EVI recording into fresh ``pend_*`` buffers."""
    step_ids = pd.unit_steps[ui]
    scap = state.node_counts.shape[1]
    K = max(unit_evi_width(pd, ui), 1)
    rows, alive, seed_slot = state.rows, state.alive, state.seed_slot
    overflow, lost, node_counts = state.overflow, state.lost, state.node_counts
    pend_a = jnp.full((g.ndev, rows.shape[1], K), g.n, jnp.int32)
    pend_b = jnp.full((g.ndev, rows.shape[1], K), g.n, jnp.int32)
    pend_m = jnp.zeros((g.ndev, rows.shape[1], K), bool)
    req_ids, fetched = bufs if bufs is not None else (None, None)
    k_off = 0
    for sid in step_ids:
        spec = pd.steps[sid]
        (rows, alive, seed_slot, pend_a, pend_b, pend_m, ov_s, lost_s
         ) = _leaf_step(g, cfg, spec, k_off,
                        rows, alive, seed_slot, pend_a, pend_b, pend_m,
                        req_ids, fetched, local_only)
        overflow |= ov_s
        lost |= lost_s
        k_off += len(spec.back_cols)
        inc = jax.vmap(
            # duplicate indices are intended here (many frontier rows per
            # seed) and integer .add is order-independent:
            # radslint: allow[RL003] deterministic seed-slot segment-sum
            lambda ss, al: jnp.zeros((scap,), jnp.int32)
            .at[jnp.clip(ss, 0, scap - 1)].add(al.astype(jnp.int32))
        )(seed_slot, alive)
        node_counts += inc
    return replace(state, rows=rows, alive=alive, seed_slot=seed_slot,
                   overflow=overflow, lost=lost, node_counts=node_counts,
                   pend_a=pend_a, pend_b=pend_b, pend_m=pend_m)


def verify_stage(g: DeviceGraph, pd: PlanData, cfg: EngineConfig,
                 exch: ExchangeBackend, ui: int, state: WaveState,
                 local_only: bool) -> WaveState:
    """Pipeline stage 3 of unit ``ui``: batched verifyE over the EVI, then
    alive-masking.  Consumes and clears the ``pend_*`` buffers and appends
    the unit's per-device alive count to ``rounds_alive``."""
    alive = state.alive
    overflow, bytes_verify = state.overflow, state.bytes_verify
    bytes_wire_verify = state.bytes_wire_verify
    bytes_wire_verify_dev = state.bytes_wire_verify_dev
    if (not local_only) and unit_evi_width(pd, ui) > 0:
        ok, v_ov, v_b, v_wb, v_wd = verify_exchange(
            g, exch, state.pend_a, state.pend_b, state.pend_m,
            cfg.verify_cap, use_pallas=cfg.use_pallas_kernels)
        alive = alive & jnp.all(ok, axis=-1)
        overflow = overflow | v_ov
        bytes_verify = bytes_verify + v_b
        bytes_wire_verify = bytes_wire_verify + v_wb
        bytes_wire_verify_dev = bytes_wire_verify_dev + v_wd
    return replace(state, alive=alive, overflow=overflow,
                   bytes_verify=bytes_verify,
                   bytes_wire_verify=bytes_wire_verify,
                   bytes_wire_verify_dev=bytes_wire_verify_dev,
                   rounds_alive=state.rounds_alive + (alive.sum(axis=-1),),
                   pend_a=None, pend_b=None, pend_m=None)


def finalize_wave(state: WaveState, exec_hits=0.0):
    """Drain point: WaveState -> the classic (rows, alive, counts, complete,
    stats) tuple the driver consumes.

    ``exec_hits`` is the StageRunner's count of stage dispatches this wave
    served from the persistent executable cache instead of tracing
    (:mod:`repro.runtime.compile_cache`).  It rides through the traced
    finalize as a scalar argument so the hit accounting reaches the driver
    in the same single ``device_get`` as every other wave stat."""
    counts = state.alive.sum(axis=-1)
    stats = dict(bytes_fetch=state.bytes_fetch,
                 bytes_verify=state.bytes_verify,
                 bytes_wire_fetch=state.bytes_wire_fetch,
                 bytes_wire_verify=state.bytes_wire_verify,
                 bytes_wire_fetch_dev=state.bytes_wire_fetch_dev,
                 bytes_wire_verify_dev=state.bytes_wire_verify_dev,
                 bytes_fetch_compressed=state.bytes_fetch_compressed,
                 bytes_saved_cache=state.bytes_saved_cache,
                 cache_hits=state.cache_hits,
                 cache_probes=state.cache_probes,
                 compile_cache_hits=state.compile_cache_hits + exec_hits,
                 rows_per_round=jnp.stack(state.rounds_alive),
                 node_counts=state.node_counts)
    return (state.rows, state.alive, counts,
            ~(state.overflow | state.lost), stats)


# --------------------------------------------------------------------------- #
# Full multi-round run (synchronous composition of the stages)
# --------------------------------------------------------------------------- #
def run_rounds(g: DeviceGraph, pd: PlanData, cfg: EngineConfig,
               exch: ExchangeBackend, seeds, seed_mask, local_only: bool,
               cache: AdjCache | None = None):
    """Traceable core: all units, all leaves, exchanges per round.

    seeds: (ndev, scap) global vertex ids.  Returns (rows, alive, counts,
    complete, stats).  This is exactly ``fetch→expand→verify`` per unit —
    the async scheduler runs the same stages, interleaved across waves,
    with the (optional) adjacency cache threaded through the fetches.
    The cache is per-call here: the post-run state is discarded (the
    classic return tuple is kept), so cross-wave cache warmth is the
    :class:`~repro.core.scheduler.StageRunner`'s job — ``run_rounds ==
    staged pipeline`` holds for results, not for cache temperature."""
    state = init_wave(g, seeds, seed_mask)
    for ui in range(len(pd.unit_steps)):
        state, bufs, cache = fetch_stage(g, pd, cfg, exch, ui, state,
                                         local_only, cache)
        state = expand_stage(g, pd, cfg, ui, state, bufs, local_only)
        state = verify_stage(g, pd, cfg, exch, ui, state, local_only)
    return finalize_wave(state)
