"""Device-resident foreign-adjacency cache (the paper's §7 caching heuristic).

R-Meef rounds repeatedly ``fetchV`` the adjacency lists of the same foreign
pivots — across leaf steps, waves, and region groups — because popular
(hub) vertices appear as ``f(pivot)`` in many partial embeddings.  This
module keeps a per-device, *device-resident* cache of previously fetched
foreign rows so repeat requests are answered locally and masked out of the
all-to-all exchange entirely.

Slab layout
-----------
The cache is a set-associative slab in the engine's stacked ``(ndev, ...)``
layout (one independent cache per device):

* ``keys``    — ``(ndev, slots, ways)`` int32 vertex ids; the sentinel ``n``
  marks an invalid line.  A vertex ``v`` can only live in set
  ``v % slots`` (``slots`` is a power of two, enforced by
  ``EngineConfig.__post_init__``, so the modulo is a mask); ``ways`` is the
  associativity axis — ``ways=1`` degenerates to a plain direct-mapped
  cache.
* ``rows``    — ``(ndev, slots, ways, line_width)`` int32 payloads: the
  sentinel-padded sorted adjacency windows exactly as ``DeviceGraph.rows_at``
  produces them (``line_width`` is the graph's top bucketed cap /
  ``max_degree``), so a hit is byte-identical to a fresh fetch.
* ``benefit`` — ``(ndev, slots, ways)`` int32 benefit counters implementing
  the paper's admission rule (below).  Invalid lines sit at a large
  negative benefit so empty ways fill first.

Benefit-based admission / eviction
----------------------------------
The paper's caching heuristic scores a vertex by *fetch frequency × row
size* — caching a hub's long row saves more wire bytes per hit than a
leaf's short row.  The counters realize that score online:

* on a **hit**, the line's benefit grows by its payload size
  (``deg + 1`` — row words plus the request id it saved);
* on a **miss**, the fetched row becomes an insert candidate with initial
  benefit ``deg + 1``; the victim is the minimum-benefit way of its set and
  the candidate is admitted only if its benefit is >= the victim's;
* a **rejected** candidate decays the victim by its own benefit (aging), so
  a stale once-hot line loses a contest against a line that keeps being
  fetched — frequency × size decides, not recency alone;
* optionally, a **shared-benefit decay schedule** (``EngineConfig.
  cache_decay > 0``): every ``decay`` update batches the benefit counters
  of all *live* lines are halved (``>> 1``).  A hub line that was hot in an
  early phase but stops being fetched then loses its accumulated benefit
  geometrically instead of pinning its set for the rest of the run —
  without decay a long-lived line's counter only falls via rejected-
  candidate aging, which needs repeated conflicting misses in that exact
  set.  Empty ways keep their sentinel benefit (they must always lose the
  victim contest), and the batch tick is part of the pytree, so the
  schedule is deterministic across backends and survives re-jits.

Within one update batch at most one insert lands per set (all candidates of
a set see the same pre-update benefit, hence pick the same victim way); the
winner is chosen deterministically (max benefit, then smallest id), so
cache contents — and therefore the byte accounting — are identical across
the ``sim`` / ``gather`` / ``spmd`` exchange backends and both storage
formats.

jit invariants
--------------
:class:`AdjCache` is a registered pytree (array leaves + static geometry
aux), exactly like :class:`~repro.graph.storage.DeviceGraph`: it travels
*through* the jitted engine stages as an argument and a result, so probe,
merge, and admission all run on device with no host round-trips.
:class:`~repro.core.scheduler.StageRunner` owns the state across waves and
re-threads the same arrays through re-jitted stages when a capacity
escalation changes the stage shapes (the cache geometry never depends on
the engine capacities).  ``shard`` places the leading ``ndev`` axis on a
mesh for the spmd backend; every cache operation is per-device
(vmapped/elementwise over that axis), so sharding propagates with no extra
collectives.

Correctness note: cache state only ever changes *which transport* delivers
a row (wire vs. local slab), never the row's bytes — enumeration results
are invariant to cache configuration, hit pattern, and eviction order.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

# invalid lines sit far below any reachable benefit so empty ways always
# lose the victim contest; live counters are clamped to the same magnitude
_EMPTY_BENEFIT = -(1 << 20)
_BENEFIT_CLAMP = 1 << 20


@jax.tree_util.register_pytree_node_class
@dataclass(frozen=True)
class AdjCache:
    """Set-associative foreign-adjacency cache state (see module docstring).

    Array leaves are pytree children; the geometry ints are static aux data
    (a geometry change re-traces the engine stages, like ``DeviceGraph``).
    """

    ndev: int
    slots: int        # sets per device (power of two)
    ways: int         # associativity (1 = direct-mapped)
    n: int            # sentinel / invalid key (== graph.n)
    line_width: int   # payload row width (== graph.max_degree)
    decay: int        # halve live benefits every `decay` batches (0 = off)

    keys: jnp.ndarray     # (ndev, slots, ways) int32, n = invalid
    rows: jnp.ndarray     # (ndev, slots, ways, line_width) int32
    benefit: jnp.ndarray  # (ndev, slots, ways) int32
    tick: jnp.ndarray     # (ndev,) int32 — update batches seen (decay clock)

    @classmethod
    def build(cls, ndev: int, slots: int, ways: int, n: int,
              line_width: int, decay: int = 0) -> "AdjCache":
        """An all-invalid cache of the given geometry."""
        return cls(
            ndev=ndev, slots=slots, ways=ways, n=n, line_width=line_width,
            decay=decay,
            keys=jnp.full((ndev, slots, ways), n, jnp.int32),
            rows=jnp.full((ndev, slots, ways, line_width), n, jnp.int32),
            benefit=jnp.full((ndev, slots, ways), _EMPTY_BENEFIT, jnp.int32),
            tick=jnp.zeros((ndev,), jnp.int32))

    @property
    def cache_bytes(self) -> int:
        """Resident device footprint of the cache arrays."""
        leaves = jax.tree_util.tree_leaves(self)
        return int(sum(x.size * x.dtype.itemsize for x in leaves))

    def register_metrics(self, reg) -> None:
        """Set the cache-owned instruments on a stats registry (declared in
        :mod:`repro.obs.schema`) — presence and footprint; the per-wave
        ``cache_hits``/``cache_probes``/``bytes_saved_cache`` counters flow
        through ``WaveState`` -> ``finalize_wave`` as before."""
        reg["cache_enabled"] = True
        reg["cache_bytes"] = int(self.cache_bytes)

    def shard(self, mesh, axis: str = "data") -> "AdjCache":
        """Every leaf sharded on its leading ``ndev`` axis — through
        :func:`repro.compat.global_shard` so a process-spanning mesh (the
        ``dist`` backend) works identically to a local one."""
        from repro import compat

        return compat.global_shard(self, mesh, axis)

    # -- device-side ops (stacked layout; vmapped per device) --------------- #
    def updated(self, ids: jnp.ndarray, hit: jnp.ndarray, way: jnp.ndarray,
                rows: jnp.ndarray) -> "AdjCache":
        """Apply one batch of probe outcomes: bump hit lines, admit misses.

        ``ids``/``hit``/``way``: (ndev, M); ``rows``: (ndev, M, line_width)
        — the merged fetch responses (cached row where hit, wire row where
        miss).  Ids must be unique per device among valid (< n) entries
        (the fetchV request buffers are deduped upstream).

        With ``decay > 0`` the live benefit counters are halved once every
        ``decay`` batches after the bump/admission pass (the shared-benefit
        decay schedule; see module docstring).
        """
        n = self.n
        k, r, b = jax.vmap(
            lambda ck, cr, cb, i, h, w, rw: _update_dev(
                ck, cr, cb, n, i, h, w, rw)
        )(self.keys, self.rows, self.benefit, ids, hit, way, rows)
        tick = self.tick + 1
        if self.decay > 0:
            fire = (tick % self.decay == 0)[:, None, None]
            b = jnp.where(fire & (k < n), b >> 1, b)
        return AdjCache(ndev=self.ndev, slots=self.slots, ways=self.ways,
                        n=self.n, line_width=self.line_width,
                        decay=self.decay, keys=k, rows=r, benefit=b,
                        tick=tick)

    def tree_flatten(self):
        return ((self.keys, self.rows, self.benefit, self.tick),
                (self.ndev, self.slots, self.ways, self.n, self.line_width,
                 self.decay))

    @classmethod
    def tree_unflatten(cls, aux, children):
        keys, rows, benefit, tick = children
        return cls(*aux, keys=keys, rows=rows, benefit=benefit, tick=tick)


def build_cache(cfg, g) -> AdjCache | None:
    """Construct the cache ``EngineConfig`` asks for (``None`` = disabled).

    ``g`` is any :class:`~repro.graph.storage.DeviceGraph`: the cache only
    needs its geometry (``ndev``, sentinel ``n``, ``max_degree`` — the row
    width every format's ``rows_at`` pads to).
    """
    if not cfg.enable_cache:
        return None
    return AdjCache.build(ndev=g.ndev, slots=cfg.cache_slots,
                          ways=cfg.cache_ways, n=g.n,
                          line_width=g.max_degree,
                          decay=cfg.cache_decay)


# --------------------------------------------------------------------------- #
# Per-device primitives (no leading ndev axis — callers vmap)
# --------------------------------------------------------------------------- #
def probe_dev(keys: jnp.ndarray, rows: jnp.ndarray, ids: jnp.ndarray,
              n: int):
    """Look ``ids`` (M,) up in one device's cache.

    Returns ``(hit (M,) bool, way (M,) int32, out_rows (M, line_width))``;
    missed / sentinel ids get ``hit=False`` and an all-sentinel row.
    """
    slots = keys.shape[0]
    slot = jnp.bitwise_and(ids, slots - 1)           # slots is a power of two
    k = keys[slot]                                   # (M, ways)
    eq = (k == ids[:, None]) & (ids[:, None] < n)
    hit = jnp.any(eq, axis=-1)
    way = jnp.argmax(eq, axis=-1).astype(jnp.int32)
    out = rows[slot, way]                            # (M, line_width)
    out = jnp.where(hit[:, None], out, n)
    return hit, way, out


def _update_dev(keys, rows, ben, n, ids, hit, way, frows):
    """One device's benefit bump + admission pass (see module docstring)."""
    slots, ways = keys.shape
    valid = ids < n
    deg = (frows < n).sum(-1).astype(jnp.int32)
    weight = deg + 1                                 # row words + request id
    slot = jnp.bitwise_and(ids, slots - 1)

    # 1. hits: grow the line's benefit by the bytes it just saved.  Distinct
    #    ids can never share a line (one key per line), so the scatter-add
    #    has no meaningful duplicates (non-hits add at a dropped index).
    ben = ben.at[jnp.where(hit & valid, slot, slots), way].add(
        jnp.where(hit & valid, weight, 0), mode="drop")

    # 2. misses: victim = min-benefit way of the set, admitted only if the
    #    candidate's benefit wins; rejected candidates age the victim.
    cand = valid & ~hit
    bset = ben[slot]                                 # (M, ways)
    victim = jnp.argmin(bset, axis=-1).astype(jnp.int32)
    vben = jnp.min(bset, axis=-1)
    admit = cand & (weight >= vben)
    ben = ben.at[jnp.where(cand & ~admit, slot, slots), victim].add(
        jnp.where(cand & ~admit, -weight, 0), mode="drop")

    # 3. dedup winners per (set, victim way): every candidate of a set saw
    #    the same pre-update benefit, so they all picked the same victim —
    #    keep the max-benefit candidate (smallest id on ties) so insertion
    #    is deterministic across backends and schedules.
    lkey = jnp.where(admit, slot * ways + victim, slots * ways)
    order = jnp.lexsort((ids, -weight, lkey))
    lk_s = lkey[order]
    first = jnp.concatenate([jnp.array([True]), lk_s[1:] != lk_s[:-1]])
    win = first & admit[order]
    wslot = jnp.where(win, slot[order], slots)       # out-of-range => drop
    wway = victim[order]
    keys = keys.at[wslot, wway].set(ids[order], mode="drop")
    rows = rows.at[wslot, wway].set(frows[order], mode="drop")
    ben = ben.at[wslot, wway].set(weight[order], mode="drop")
    return keys, rows, jnp.clip(ben, -_BENEFIT_CLAMP, _BENEFIT_CLAMP)
