"""Cross-run capacity / cost priors (§6 calibration, persisted).

The engine calibrates two things while it runs: the per-seed trie-node
cost (the region-group budget denominator, running mean over every
completed wave) and the static engine capacities (doubled on overflow —
each escalation re-jits every stage mid-enumeration).  Both are pure
functions of the (pattern, data graph) workload, so persisting them lets
the *next* run on the same workload start with the right capacities —
skipping the escalate/re-jit ladder entirely — and with a realistic
per-seed cost for region-group sizing instead of the cold-start guess.

The cache is a flat JSON file (``EngineConfig.priors_path``) mapping a
workload key — canonical pattern edge list + graph fingerprint
(vertices, edges, ndev) — to ``{"per_seed_cost": float, "caps": {...}}``.
Writes are merge + atomic-rename under an advisory file lock so
concurrent runs on different workloads can share one cache file.
"""
from __future__ import annotations

import json
import os

from repro.core.query import Pattern
from repro.graph.storage import PartitionedGraph


def priors_key(pattern: Pattern, pg: PartitionedGraph) -> str:
    """Workload fingerprint: canonical query edges + data-graph identity."""
    edges = ";".join(f"{a}-{b}" for a, b in sorted(pattern.edges))
    m = int(pg.deg.sum()) // 2
    return f"q[{edges}]|g[n={pg.n_real},m={m},ndev={pg.ndev}]"


def load_priors(path: str) -> dict:
    """Read the cache; missing or corrupt files are an empty prior."""
    try:
        with open(path) as f:
            out = json.load(f)
        return out if isinstance(out, dict) else {}
    except (OSError, json.JSONDecodeError):
        return {}


def save_priors(path: str, key: str, entry: dict) -> None:
    """Merge ``entry`` under ``key`` and atomically rewrite the cache.

    The read-merge-replace runs under an advisory ``flock`` on a sibling
    lock file (where the platform has one), so concurrent runs finishing
    at the same time don't drop each other's entries."""
    lock = open(f"{path}.lock", "w")
    try:
        try:
            import fcntl
            fcntl.flock(lock, fcntl.LOCK_EX)
        except (ImportError, OSError):
            pass                     # no flock: fall back to atomic rename
        cur = load_priors(path)
        cur[key] = entry
        tmp = f"{path}.tmp.{os.getpid()}"
        with open(tmp, "w") as f:
            json.dump(cur, f, indent=1, sort_keys=True)
        os.replace(tmp, path)
    finally:
        lock.close()
