"""Cross-run capacity / cost priors (§6 calibration, persisted).

The engine calibrates two things while it runs: the per-seed trie-node
cost (the region-group budget denominator, running mean over every
completed wave) and the static engine capacities (doubled on overflow —
each escalation re-jits every stage mid-enumeration).  Both are pure
functions of the (pattern, data graph) workload, so persisting them lets
the *next* run on the same workload start with the right capacities —
skipping the escalate/re-jit ladder entirely — and with a realistic
per-seed cost for region-group sizing instead of the cold-start guess.

Priors v2 additionally persists, per workload:

* the **per-seed node_counts histogram** (log2-binned trie-node counts
  over every completed seed) — the next run sizes its region-group waves
  from a high percentile of the *distribution* instead of the mean, so
  skewed seed-degree workloads stop overflowing on the hub-heavy groups;
* the **learned pipeline depth** — the depth ``pipeline_depth="auto"``
  converged to, used as the next run's starting depth.

The cache is a flat JSON file (``EngineConfig.priors_path``) mapping a
workload key — canonical pattern edge list + graph fingerprint
(vertices, edges, ndev) — to ``{"per_seed_cost": float, "caps": {...},
"node_hist": [...], "pipeline_depth": int}``.  Writes are merge +
atomic-rename under an advisory file lock so concurrent runs on
different workloads can share one cache file.
"""
from __future__ import annotations

import json
import os

import numpy as np

from repro.core.query import Pattern
from repro.graph.storage import PartitionedGraph

# log2 bins for the per-seed trie-node-count histogram: bin i counts seeds
# with ceil(log2(nodes + 1)) == i, i.e. nodes in [2^(i-1), 2^i).
HIST_BINS = 24


def priors_key(pattern: Pattern, pg: PartitionedGraph) -> str:
    """Workload fingerprint: canonical query edges + data-graph identity."""
    edges = ";".join(f"{a}-{b}" for a, b in sorted(pattern.edges))
    m = int(pg.deg.sum()) // 2
    return f"q[{edges}]|g[n={pg.n_real},m={m},ndev={pg.ndev}]"


def hist_update(hist: np.ndarray, node_counts: np.ndarray) -> None:
    """Accumulate per-seed trie-node counts into a log2-binned histogram
    (in place).  ``hist``: (HIST_BINS,) int64; ``node_counts``: (k,)."""
    nc = np.asarray(node_counts)
    if nc.size == 0:
        return
    bins = np.zeros(nc.shape, dtype=np.int64)
    pos = nc > 0
    bins[pos] = np.minimum(
        np.ceil(np.log2(nc[pos] + 1.0)).astype(np.int64), HIST_BINS - 1)
    np.add.at(hist, bins, 1)


def hist_percentile(hist, q: float) -> float:
    """Upper-edge cost estimate of the ``q``-quantile histogram bin
    (``2^i`` for bin ``i``) — the wave-sizing denominator for priors v2."""
    h = np.asarray(hist, dtype=np.float64)
    total = h.sum()
    if total <= 0:
        return 1.0
    idx = int(np.searchsorted(np.cumsum(h), q * total))
    return float(2 ** min(idx, HIST_BINS - 1))


def load_priors(path: str) -> dict:
    """Read the cache; missing or corrupt files are an empty prior."""
    try:
        with open(path) as f:
            out = json.load(f)
        return out if isinstance(out, dict) else {}
    except (OSError, json.JSONDecodeError):
        return {}


def save_priors(path: str, key: str, entry: dict) -> None:
    """Merge ``entry`` under ``key`` and atomically rewrite the cache.

    The read-merge-replace runs under an advisory ``flock`` on a sibling
    lock file (where the platform has one), so concurrent runs finishing
    at the same time don't drop each other's entries."""
    lock = open(f"{path}.lock", "w")
    try:
        try:
            import fcntl
            fcntl.flock(lock, fcntl.LOCK_EX)
        except (ImportError, OSError):
            pass                     # no flock: fall back to atomic rename
        cur = load_priors(path)
        cur[key] = entry
        tmp = f"{path}.tmp.{os.getpid()}"
        with open(tmp, "w") as f:
            json.dump(cur, f, indent=1, sort_keys=True)
        os.replace(tmp, path)
    finally:
        lock.close()
