"""Baselines the paper compares against (§7, §8) — vectorized numpy
implementations with explicit communication/memory accounting.

* ``psgl_enumerate``     — PSgL [21]: Pregel-style one-vertex-per-round
  expansion; partial matches are *shuffled* to the owner of the candidate
  vertex each round (the paper's critique: intermediate results on the
  wire, no compression, no memory control).
* ``join_enumerate``     — TwinTwig [13] / SEED [15]: star decomposition
  units + multi-round hash joins; *both* join sides are shuffled by join
  key every round.
* ``crystal_lite``       — Crystal [18]: clique-index based; we build the
  triangle index (the dominant index in their design) and seed matching
  from it, reporting index bytes (Table 2 analogue).

These are algorithmic reproductions for the paper's comparison tables
(Figures 8-11): the quantities compared — shuffled bytes, peak intermediate
rows, result counts — are implementation-independent; wall times are
comparable across baselines (all share the same vectorization style) but
not against the JAX RADS engine (different runtime), see EXPERIMENTS.md.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from repro.core.query import Pattern
from repro.graph.storage import Graph, PartitionedGraph


@dataclass
class BaselineResult:
    count: int
    embeddings: set[tuple[int, ...]] | None
    bytes_shuffled: float
    peak_rows: int
    seconds: float
    extra: dict = field(default_factory=dict)


# --------------------------------------------------------------------------- #
# shared vectorized helpers (numpy, padded-adjacency style)
# --------------------------------------------------------------------------- #
def _adj_rows(pg: PartitionedGraph, v: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Padded adjacency rows + degrees for global (renumbered) ids v."""
    own = v // pg.stride
    loc = v - own * pg.stride
    return pg.adj[own, loc], pg.deg[own, loc]


def _member(pg: PartitionedGraph, u: np.ndarray, v: np.ndarray) -> np.ndarray:
    """Edge-existence test (u, v) elementwise (global renumbered ids)."""
    rows, _ = _adj_rows(pg, u)
    return (rows == v[:, None]).any(axis=1)


def _expand(pg: PartitionedGraph, rows: np.ndarray, anchor_col: int,
            leaf_deg: int, back_cols: list[int], lt_cols: list[int],
            gt_cols: list[int]) -> np.ndarray:
    """All extensions of ``rows`` by one vertex from adj(rows[:, anchor]),
    with injectivity / degree / symmetry / back-edge checks."""
    k, w = rows.shape
    arow, adeg = _adj_rows(pg, rows[:, anchor_col])
    D = arow.shape[1]
    cand = arow.reshape(-1)
    parent = np.repeat(np.arange(k), D)
    valid = cand < pg.n
    for c in range(w):
        valid &= cand != rows[parent, c]
    for c in lt_cols:
        valid &= rows[parent, c] < cand
    for c in gt_cols:
        valid &= cand < rows[parent, c]
    cand_c = np.where(valid, cand, 0)
    _, cdeg = _adj_rows(pg, cand_c)
    valid &= cdeg >= leaf_deg
    for c in back_cols:
        chk = _member(pg, cand_c, rows[parent, c])
        valid &= chk
    parent, cand = parent[valid], cand[valid]
    return np.column_stack([rows[parent], cand]).astype(np.int64)


def _order_and_filters(pattern: Pattern):
    """BFS matching order + per-step anchor/back/symmetry column lists."""
    order = [0]
    seen = {0}
    i = 0
    while len(order) < pattern.n:
        u = order[i]
        i += 1
        for wv in pattern.adj(u):
            if wv not in seen:
                seen.add(wv)
                order.append(wv)
    pos = {u: j for j, u in enumerate(order)}
    cons = pattern.symmetry_constraints()
    steps = []
    for j in range(1, pattern.n):
        u = order[j]
        back = [pos[wv] for wv in pattern.adj(u) if pos[wv] < j]
        anchor = back[0]
        back = back[1:]
        lt = [pos[a] for (a, b) in cons if b == u and pos[a] < j]
        gt = [pos[b] for (a, b) in cons if a == u and pos[b] < j]
        steps.append((pos[u], anchor, back, lt, gt, pattern.degree(u)))
    return order, steps


def _to_query_order(rows: np.ndarray, order: list[int],
                    pg: PartitionedGraph) -> set[tuple[int, ...]]:
    inv = np.argsort(np.array(order))
    out = set()
    for r in pg.new2old[rows][:, inv]:
        out.add(tuple(int(x) for x in r))
    return out


# --------------------------------------------------------------------------- #
# PSgL
# --------------------------------------------------------------------------- #
def psgl_enumerate(pg: PartitionedGraph, pattern: Pattern,
                   return_embeddings: bool = True) -> BaselineResult:
    t0 = time.perf_counter()
    order, steps = _order_and_filters(pattern)
    # round 0: all local candidates of order[0]
    deg0 = pattern.degree(order[0])
    all_v = np.flatnonzero(pg.new2old >= 0)
    degs = pg.deg.reshape(-1)[all_v]
    rows = all_v[degs >= deg0][:, None].astype(np.int64)
    loc = rows[:, 0] // pg.stride                 # current machine of partials
    bytes_shuffled = 0.0
    peak = rows.shape[0]
    for (col, anchor, back, lt, gt, ldeg) in steps:
        # shuffle partials to owner(f(anchor)) — PSgL routes the partial
        # match to the worker holding the expansion vertex
        tgt = rows[:, anchor] // pg.stride
        moved = tgt != loc
        bytes_shuffled += float(moved.sum()) * rows.shape[1] * 4
        loc = tgt
        rows = _expand(pg, rows, anchor, ldeg, back, lt, gt)
        # new partial lives at owner(candidate) for the *next* verify step
        loc = rows[:, -1] // pg.stride if rows.size else np.zeros(0, np.int64)
        peak = max(peak, rows.shape[0])
    secs = time.perf_counter() - t0
    embs = _to_query_order(rows, order, pg) if return_embeddings else None
    return BaselineResult(count=rows.shape[0], embeddings=embs,
                          bytes_shuffled=bytes_shuffled, peak_rows=peak,
                          seconds=secs)


# --------------------------------------------------------------------------- #
# TwinTwig / SEED (join-based)
# --------------------------------------------------------------------------- #
def star_decomposition(pattern: Pattern, max_edges: int) -> list[tuple[int, tuple[int, ...]]]:
    """Partition E_P into stars (center, leaves); TwinTwig caps stars at 2
    edges, SEED does not."""
    remaining = set(pattern.edges)
    units: list[tuple[int, tuple[int, ...]]] = []
    while remaining:
        # pick the vertex with most remaining incident edges
        cnt: dict[int, int] = {}
        for (a, b) in remaining:
            cnt[a] = cnt.get(a, 0) + 1
            cnt[b] = cnt.get(b, 0) + 1
        c = max(cnt, key=lambda x: (cnt[x], -x))
        leaves = [b if a == c else a for (a, b) in remaining if c in (a, b)]
        leaves = tuple(sorted(leaves)[:max_edges])
        units.append((c, leaves))
        for lf in leaves:
            remaining.discard((min(c, lf), max(c, lf)))
    # order units so each shares a vertex with the prefix (join-ability)
    ordered = [units[0]]
    rest = units[1:]
    covered = {units[0][0], *units[0][1]}
    while rest:
        for i, (c, lf) in enumerate(rest):
            if c in covered or any(x in covered for x in lf):
                ordered.append(rest.pop(i))
                covered.update({c, *lf})
                break
        else:  # disconnected remainder (cannot happen for connected P)
            ordered.append(rest.pop(0))
            covered.update({ordered[-1][0], *ordered[-1][1]})
    return ordered


def _star_embeddings(pg: PartitionedGraph, pattern: Pattern,
                     unit: tuple[int, tuple[int, ...]]) -> np.ndarray:
    """All embeddings of one star unit (computed locally on each machine —
    a star centered at v needs only adj(v))."""
    c, leaves = unit
    all_v = np.flatnonzero(pg.new2old >= 0)
    degs = pg.deg.reshape(-1)[all_v]
    rows = all_v[degs >= pattern.degree(c)][:, None].astype(np.int64)
    for j, lf in enumerate(leaves):
        k = rows.shape[0]
        arow, _ = _adj_rows(pg, rows[:, 0])
        D = arow.shape[1]
        cand = arow.reshape(-1)
        parent = np.repeat(np.arange(k), D)
        valid = cand < pg.n
        for cc in range(rows.shape[1]):
            valid &= cand != rows[parent, cc]
        cand_c = np.where(valid, cand, 0)
        _, cdeg = _adj_rows(pg, cand_c)
        valid &= cdeg >= pattern.degree(lf)
        rows = np.column_stack([rows[parent[valid]], cand[valid]])
    return rows  # columns: [center, *leaves]


def join_enumerate(pg: PartitionedGraph, pattern: Pattern,
                   kind: str = "twintwig",
                   return_embeddings: bool = True) -> BaselineResult:
    t0 = time.perf_counter()
    max_edges = 2 if kind == "twintwig" else pattern.n
    units = star_decomposition(pattern, max_edges)
    cons = pattern.symmetry_constraints()
    bytes_shuffled = 0.0
    peak = 0

    part_cols: list[int] = []          # query vertices covered so far
    part: np.ndarray | None = None
    for (c, leaves) in units:
        unit_rows = _star_embeddings(pg, pattern, (c, leaves))
        unit_cols = [c, *leaves]
        peak = max(peak, unit_rows.shape[0])
        if part is None:
            part, part_cols = unit_rows, unit_cols
        else:
            shared = [u for u in unit_cols if u in part_cols]
            newv = [u for u in unit_cols if u not in part_cols]
            # MapReduce-style shuffle of BOTH sides by join key
            bytes_shuffled += (part.size + unit_rows.size) * 4 * \
                (1 - 1 / pg.ndev)
            key_p = _key(part, [part_cols.index(u) for u in shared], pg.n)
            key_u = _key(unit_rows, [unit_cols.index(u) for u in shared], pg.n)
            op, ou = np.argsort(key_p, kind="stable"), np.argsort(key_u, kind="stable")
            part, key_p = part[op], key_p[op]
            unit_rows, key_u = unit_rows[ou], key_u[ou]
            lo = np.searchsorted(key_u, key_p, side="left")
            hi = np.searchsorted(key_u, key_p, side="right")
            cnt = hi - lo
            pi = np.repeat(np.arange(part.shape[0]), cnt)
            ui = _range_concat(lo, cnt)
            new_cols_idx = [unit_cols.index(u) for u in newv]
            joined = np.column_stack([part[pi], unit_rows[ui][:, new_cols_idx]])
            # injectivity across the new columns
            valid = np.ones(joined.shape[0], dtype=bool)
            base_w = part.shape[1]
            for j in range(len(newv)):
                for cc in range(base_w + j):
                    valid &= joined[:, base_w + j] != joined[:, cc]
            part = joined[valid]
            part_cols = part_cols + newv
        # early symmetry filtering where both endpoints are covered
        part = _apply_sym(part, part_cols, cons)
        peak = max(peak, part.shape[0])
    # verify edges not inside any star: both endpoints covered at the end
    covered_pairs = set()
    for (c, leaves) in units:
        for lf in leaves:
            covered_pairs.add((min(c, lf), max(c, lf)))
    missing = [e for e in pattern.edges if e not in covered_pairs]
    for (a, b) in missing:
        ia, ib = part_cols.index(a), part_cols.index(b)
        part = part[_member(pg, part[:, ia], part[:, ib])]
    secs = time.perf_counter() - t0
    embs = _to_query_order(part, part_cols, pg) if return_embeddings else None
    return BaselineResult(count=part.shape[0], embeddings=embs,
                          bytes_shuffled=bytes_shuffled, peak_rows=peak,
                          seconds=secs, extra=dict(n_units=len(units)))


def _key(rows: np.ndarray, cols: list[int], n: int) -> np.ndarray:
    k = np.zeros(rows.shape[0], dtype=np.int64)
    for c in cols:
        k = k * n + rows[:, c]
    return k


def _range_concat(lo: np.ndarray, cnt: np.ndarray) -> np.ndarray:
    total = int(cnt.sum())
    out = np.ones(total, dtype=np.int64)
    if total == 0:
        return out[:0]
    offs = np.cumsum(cnt)[:-1]
    out[0] = lo[0] if len(lo) else 0
    starts = np.repeat(lo, cnt)
    idx = np.arange(total) - np.repeat(np.concatenate([[0], offs]), cnt)
    return starts + idx


def _apply_sym(rows: np.ndarray, cols: list[int],
               cons: list[tuple[int, int]]) -> np.ndarray:
    for (a, b) in cons:
        if a in cols and b in cols:
            rows = rows[rows[:, cols.index(a)] < rows[:, cols.index(b)]]
    return rows


# --------------------------------------------------------------------------- #
# Crystal-lite
# --------------------------------------------------------------------------- #
def build_triangle_index(g: Graph) -> np.ndarray:
    """All triangles (i < j < k) — the dominant part of Crystal's clique
    index. Returns (T, 3)."""
    tris = []
    for u in range(g.n):
        nu = g.neighbors(u)
        nu = nu[nu > u]
        for v in nu:
            nv = g.neighbors(int(v))
            common = np.intersect1d(nu, nv[nv > v], assume_unique=True)
            for wv in common:
                tris.append((u, int(v), int(wv)))
    return np.array(tris, dtype=np.int64).reshape(-1, 3)


def crystal_lite(pg: PartitionedGraph, pattern: Pattern, g: Graph,
                 tri_index: np.ndarray | None = None,
                 return_embeddings: bool = True) -> BaselineResult:
    """Seed from the triangle index when the pattern contains a triangle;
    expand the rest PSgL-style locally. Reports index bytes (Table 2)."""
    t0 = time.perf_counter()
    if tri_index is None:
        tri_index = build_triangle_index(g)
    index_bytes = tri_index.size * 4
    # find a pattern triangle
    tri = None
    for (a, b) in pattern.edges:
        for c in range(pattern.n):
            if c not in (a, b) and pattern.has_edge(a, c) and pattern.has_edge(b, c):
                tri = (a, b, c)
                break
        if tri:
            break
    order, steps = _order_and_filters(pattern)
    if tri is None:
        r = psgl_enumerate(pg, pattern, return_embeddings)
        r.extra["index_bytes"] = index_bytes
        r.extra["used_index"] = False
        return r
    # seed rows = triangles mapped to (a, b, c) in all 6 orientations,
    # then filter by symmetry constraints on those three columns
    perms = [(0, 1, 2), (0, 2, 1), (1, 0, 2), (1, 2, 0), (2, 0, 1), (2, 1, 0)]
    seeds = np.concatenate([tri_index[:, p] for p in perms], axis=0)
    # translate old ids -> renumbered ids
    seeds = pg.old2new[seeds].astype(np.int64)
    tri_cols = list(tri)
    cons = pattern.symmetry_constraints()
    seeds = _apply_sym(seeds, tri_cols, cons)
    # degree filter
    for j, u in enumerate(tri_cols):
        _, dd = _adj_rows(pg, seeds[:, j])
        seeds = seeds[dd >= pattern.degree(u)]
    rows, cols = seeds, tri_cols
    # expand remaining vertices in BFS order anchored on covered vertices
    remaining = [u for u in order if u not in cols]
    for u in remaining:
        back_all = [cols.index(wv) for wv in pattern.adj(u) if wv in cols]
        anchor, back = back_all[0], back_all[1:]
        lt = [cols.index(a) for (a, b) in cons if b == u and a in cols]
        gt = [cols.index(b) for (a, b) in cons if a == u and b in cols]
        rows = _expand(pg, rows, anchor, pattern.degree(u), back, lt, gt)
        cols = cols + [u]
    secs = time.perf_counter() - t0
    embs = _to_query_order(rows, cols, pg) if return_embeddings else None
    return BaselineResult(count=rows.shape[0], embeddings=embs,
                          bytes_shuffled=0.0, peak_rows=rows.shape[0],
                          seconds=secs,
                          extra=dict(index_bytes=index_bytes, used_index=True))
