"""Execution-plan computation (§4).

A plan is a sequence of decomposition units ``(piv, leaves)`` such that the
pivots form a connected dominating set; Theorem 1 says the minimum number of
units equals the connected-domination number ``c_P``. We enumerate all
minimum CDSs, all valid pivot orderings and leaf assignments (queries are
tiny — §4: "we can simply enumerate all the possible execution plans"), then
apply the paper's selection rules in order:

  1. minimum number of rounds (guaranteed by construction),
  2. minimum span of ``dp0.piv`` (maximizes the SM-E share, §4.2),
  3. maximum score  SC(PL) = Σ_i [ |E_sib_i|+|E_cro_i| ] / (i+1)^ρ
                           + deg(piv_i) / (i+1)          (§4.3, Eq. 4).
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field

from repro.core.query import Pattern


@dataclass(frozen=True)
class Unit:
    piv: int
    leaves: tuple[int, ...]


@dataclass(frozen=True)
class Plan:
    pattern: Pattern
    units: tuple[Unit, ...]
    # derived
    matching_order: tuple[int, ...] = ()

    @property
    def n_rounds(self) -> int:
        return len(self.units)

    def prefix_vertices(self, i: int) -> set[int]:
        """V_{P_{i-1}} — vertices matched before unit i starts."""
        vs: set[int] = set()
        for j in range(i):
            vs.add(self.units[j].piv)
            vs.update(self.units[j].leaves)
        return vs

    def edge_sets(self, i: int) -> tuple[list, list, list]:
        """(E_star, E_sib, E_cro) of unit i per §3.2."""
        u = self.units[i]
        p = self.pattern
        star = [(u.piv, lf) for lf in u.leaves if p.has_edge(u.piv, lf)]
        sib = [(a, b) for a, b in itertools.combinations(u.leaves, 2)
               if p.has_edge(a, b)]
        prev = self.prefix_vertices(i)
        cro = [(x, lf) for lf in u.leaves for x in prev
               if x != u.piv and p.has_edge(x, lf)]
        return star, sib, cro

    def score(self, rho: float = 1.0) -> float:
        s = 0.0
        for i in range(len(self.units)):
            _, sib, cro = self.edge_sets(i)
            s += (len(sib) + len(cro)) / (i + 1) ** rho
            s += self.pattern.degree(self.units[i].piv) / (i + 1)
        return s

    def validate(self) -> None:
        p = self.pattern
        seen: set[int] = set()
        for i, u in enumerate(self.units):
            if i == 0:
                seen.add(u.piv)
            else:
                assert u.piv in seen, f"unit {i} pivot {u.piv} not in prefix"
            assert u.leaves, f"unit {i} has no leaves"
            for lf in u.leaves:
                assert lf not in seen, f"leaf {lf} already matched"
                assert p.has_edge(u.piv, lf), f"leaf {lf} not adjacent to pivot"
                seen.add(lf)
        assert seen == set(range(p.n)), f"plan covers {seen}, want all {p.n}"


def compute_matching_order(plan: Plan) -> tuple[int, ...]:
    """Definition 10. Vertices in the order they are matched/stored."""
    p = plan.pattern
    pivot_unit = {u.piv: j for j, u in enumerate(plan.units)}
    order: list[int] = [plan.units[0].piv]
    for u in plan.units:
        def key(lf: int):
            if lf in pivot_unit:                      # (3)(iii) + (1)
                return (0, pivot_unit[lf], 0, lf)
            return (1, 0, -p.degree(lf), lf)          # (3)(ii)
        for lf in sorted(u.leaves, key=key):
            order.append(lf)
    assert len(order) == p.n and len(set(order)) == p.n
    return tuple(order)


# --------------------------------------------------------------------------- #
# CDS / plan enumeration
# --------------------------------------------------------------------------- #
def _is_dominating(p: Pattern, subset: tuple[int, ...]) -> bool:
    dom = set(subset)
    for u in subset:
        dom.update(p.adj(u))
    return len(dom) == p.n


def _is_connected_subset(p: Pattern, subset: tuple[int, ...]) -> bool:
    ss = set(subset)
    start = subset[0]
    seen = {start}
    stack = [start]
    while stack:
        u = stack.pop()
        for w in p.adj(u):
            if w in ss and w not in seen:
                seen.add(w)
                stack.append(w)
    return seen == ss


def minimum_cds(p: Pattern) -> list[tuple[int, ...]]:
    """All minimum connected dominating sets (c_P = their size)."""
    # single-vertex special case (stars): any vertex adjacent to all others
    for size in range(1, p.n + 1):
        found = [s for s in itertools.combinations(range(p.n), size)
                 if _is_dominating(p, s) and _is_connected_subset(p, s)]
        if found:
            return found
    raise RuntimeError("no CDS found (pattern disconnected?)")


def _leaf_assignments(p: Pattern, pivots: tuple[int, ...], cap: int = 4096):
    """Yield, for each non-pivot-0 vertex, the unit index it joins as a leaf.

    Constraints: leaf v of unit i requires edge (piv_i, v); if v is pivot of
    unit j, it must join a unit i < j (so dp_j.piv in V_{P_{j-1}}).
    """
    pivot_pos = {pv: j for j, pv in enumerate(pivots)}
    others = [v for v in range(p.n) if v != pivots[0]]
    choices: list[list[int]] = []
    for v in others:
        cand = []
        limit = pivot_pos.get(v, len(pivots))
        for i, pv in enumerate(pivots):
            if i >= limit:
                break
            if p.has_edge(pv, v):
                cand.append(i)
        if not cand:
            return  # this pivot ordering cannot host v
        choices.append(cand)
    total = 1
    for c in choices:
        total *= len(c)
        if total > cap:
            break
    if total > cap:
        # too many: greedy (earliest unit) single assignment
        yield {v: c[0] for v, c in zip(others, choices)}
        return
    for combo in itertools.product(*choices):
        yield dict(zip(others, combo))


def enumerate_plans(p: Pattern, max_plans: int = 20000) -> list[Plan]:
    plans: list[Plan] = []
    seen: set[tuple] = set()
    for cds in minimum_cds(p):
        for pivots in itertools.permutations(cds):
            for assign in _leaf_assignments(p, pivots):
                leaves: list[list[int]] = [[] for _ in pivots]
                ok = True
                for v, i in assign.items():
                    leaves[i].append(v)
                if any(not lf for lf in leaves):
                    ok = False      # every unit needs >= 1 leaf (Def. 6)
                if not ok:
                    continue
                units = tuple(Unit(pv, tuple(sorted(lf)))
                              for pv, lf in zip(pivots, leaves))
                if units in seen:
                    continue
                seen.add(units)
                plan = Plan(pattern=p, units=units)
                try:
                    plan.validate()
                except AssertionError:
                    continue
                plans.append(plan)
                if len(plans) >= max_plans:
                    return plans
    return plans


def best_plan(p: Pattern, rho: float = 1.0) -> Plan:
    """Apply the paper's rules; always returns a valid plan."""
    plans = enumerate_plans(p)
    if not plans:
        # degenerate: single unit with pivot = max-degree vertex (star pattern
        # where some vertex is adjacent to all others is guaranteed by CDS=1;
        # reaching here means leaf-assignment failed => fall back to BFS plan)
        return bfs_fallback_plan(p)
    min_span = min(pl.pattern.span(pl.units[0].piv) for pl in plans)
    plans = [pl for pl in plans
             if pl.pattern.span(pl.units[0].piv) == min_span]
    plans.sort(key=lambda pl: (-pl.score(rho), tuple((u.piv, u.leaves) for u in pl.units)))
    chosen = plans[0]
    return Plan(pattern=p, units=chosen.units,
                matching_order=compute_matching_order(chosen))


def bfs_fallback_plan(p: Pattern) -> Plan:
    """BFS-tree plan from the max-degree vertex (always valid, maybe not
    minimum rounds). Used as RanS/RanM-style baseline material too."""
    root = max(range(p.n), key=p.degree)
    seen = {root}
    units: list[Unit] = []
    frontier = [root]
    while len(seen) < p.n:
        nxt = []
        for u in frontier:
            lf = tuple(w for w in p.adj(u) if w not in seen)
            if lf:
                units.append(Unit(u, lf))
                seen.update(lf)
                nxt.extend(lf)
        frontier = nxt
    plan = Plan(pattern=p, units=tuple(units))
    plan.validate()
    return Plan(pattern=p, units=plan.units,
                matching_order=compute_matching_order(plan))


def random_star_plan(p: Pattern, seed: int = 0) -> Plan:
    """RanS baseline (App. C.2): random star decomposition, no optimization."""
    import random
    rng = random.Random(seed)
    verts = list(range(p.n))
    while True:
        root = rng.choice(verts)
        seen = {root}
        units: list[Unit] = []
        frontier = [root]
        ok = True
        while len(seen) < p.n:
            cands = [u for u in frontier if any(w not in seen for w in p.adj(u))]
            if not cands:
                ok = False
                break
            u = rng.choice(cands)
            avail = [w for w in p.adj(u) if w not in seen]
            k = rng.randint(1, len(avail))
            lf = tuple(rng.sample(avail, k))
            units.append(Unit(u, lf))
            seen.update(lf)
            frontier.extend(lf)
        if ok:
            plan = Plan(pattern=p, units=tuple(units))
            try:
                plan.validate()
            except AssertionError:
                continue
            return Plan(pattern=p, units=plan.units,
                        matching_order=compute_matching_order(plan))


def min_rounds_unscored_plan(p: Pattern) -> Plan:
    """RanM baseline (App. C.2): minimum rounds, no §4.2/§4.3 heuristics —
    take the *first* enumerated minimum-round plan."""
    plans = enumerate_plans(p, max_plans=1)
    plan = plans[0] if plans else bfs_fallback_plan(p)
    return Plan(pattern=p, units=plan.units,
                matching_order=compute_matching_order(plan))
