"""RADS host driver (§3.1 architecture) — setup and result assembly.

Per machine: SM-E first (border-distance split, Prop. 1), then the
distributed R-Meef phase over region groups.  Wave execution — including
the overflow-driven robustness loop (group splitting + elastic capacity
escalation, §6 memory control), checkR/shareR queue rebalancing, and the
double-buffered async pipeline — lives in :mod:`repro.core.scheduler`;
this module only

* classifies seeds (SM-E vs distributed, Prop. 1),
* builds the per-device region-group queues (§6, Algorithm 3),
* launches the two scheduler phases, and
* assembles the :class:`EnumerationResult` (counts, embeddings, stats).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import numpy as np

from repro.configs.rads import DEFAULT_ENGINE, EngineConfig
from repro.core.engine import (PlanData, build_plan_data,
                               graph_device_arrays)
from repro.core.exchange import Exchange
from repro.core.plan import Plan, best_plan
from repro.core.query import Pattern
from repro.core.region import iter_region_groups
from repro.core.scheduler import GroupQueue, PipelineScheduler, StageRunner
from repro.graph.storage import PartitionedGraph


@dataclass
class EnumerationResult:
    count: int
    embeddings: set[tuple[int, ...]] | None
    stats: dict = field(default_factory=dict)


def extract_embeddings(rows: np.ndarray, alive: np.ndarray, pd: PlanData,
                       pg: PartitionedGraph) -> set[tuple[int, ...]]:
    """rows (ndev, cap, n_q) in matching order -> query-order tuples in
    *original* vertex ids (one vectorized unique over the whole block)."""
    r = rows[alive]
    if r.size == 0:
        return set()
    inv = np.argsort(np.array(pd.order))
    remapped = pg.new2old[r][:, inv]
    return set(map(tuple, np.unique(remapped, axis=0).tolist()))


def rads_enumerate(pg: PartitionedGraph, pattern: Pattern,
                   cfg: EngineConfig = DEFAULT_ENGINE,
                   mode: str = "sim", mesh=None,
                   plan: Plan | None = None,
                   return_embeddings: bool = True) -> EnumerationResult:
    """``mode`` selects a registered exchange backend: 'sim' (reference),
    'gather' (device-local, meshless), 'spmd' (sharded production path —
    requires ``mesh``)."""
    plan = plan or best_plan(pattern, cfg.plan_rho)
    pd = build_plan_data(plan)
    adj, deg, meta = graph_device_arrays(pg)
    exch = Exchange(mode=mode, mesh=mesh)
    if mode == "spmd":
        from jax.sharding import NamedSharding, PartitionSpec as P
        adj = jax.device_put(adj, NamedSharding(mesh, P("data", None, None)))
        deg = jax.device_put(deg, NamedSharding(mesh, P("data", None)))
    runner = StageRunner(adj, deg, meta, pd, cfg, exch)

    # ---- candidate seeds per device: deg(v) >= deg(u_start) --------------- #
    ndev, stride = pg.ndev, pg.stride
    sme_seeds: list[np.ndarray] = []
    dist_seeds_all: list[int] = []
    for t in range(ndev):
        nl = int(pg.n_local[t])
        cand_local = np.flatnonzero(pg.deg[t, :nl] >= pd.start_deg)
        gids = cand_local + t * stride
        if cfg.enable_sme:
            is_sme = pg.border_dist[t, cand_local] >= pd.span_start
        else:
            is_sme = np.zeros(len(cand_local), dtype=bool)
        sme_seeds.append(gids[is_sme])
        dist_seeds_all.extend(map(int, gids[~is_sme]))

    stats = dict(n_sme_seeds=int(sum(len(s) for s in sme_seeds)),
                 n_dist_seeds=len(dist_seeds_all),
                 bytes_fetch=0.0, bytes_verify=0.0, n_groups=0,
                 overflow_retries=0, cap_escalations=0,
                 plan_rounds=plan.n_rounds,
                 sme_count=0, dist_count=0,
                 n_waves=0, max_inflight_waves=0, steal_events=0,
                 wave_s_total=0.0, pipeline_depth=cfg.pipeline_depth)
    total = 0
    embs: set[tuple[int, ...]] = set()

    def consume(rows, alive, counts, st, phase: str):
        nonlocal total
        c = int(np.asarray(counts).sum())
        total += c
        stats[f"{phase}_count"] += c
        stats["bytes_fetch"] += float(st["bytes_fetch"])
        stats["bytes_verify"] += float(st["bytes_verify"])
        if return_embeddings:
            embs.update(extract_embeddings(np.asarray(rows),
                                           np.asarray(alive), pd, pg))

    sched = PipelineScheduler(runner, stats, consume)

    # ---- SM-E phase ------------------------------------------------------- #
    per_seed_cost = 4.0 * pattern.n
    max_sme = max((len(s) for s in sme_seeds), default=0)
    if max_sme > 0:
        scap = 1 << (min(max_sme, 4096) - 1).bit_length()
        queues = [[np.asarray(s, dtype=np.int64)] if len(s) else []
                  for s in sme_seeds]
        c = sched.run(queues, scap, local_only=True, phase="sme")
        if c is not None:
            per_seed_cost = max(c, 1.0)

    # ---- distributed phase: work stealing + region groups ----------------- #
    if dist_seeds_all:
        if cfg.enable_work_stealing:
            allseeds = np.array(sorted(dist_seeds_all), dtype=np.int64)
            per = -(-len(allseeds) // ndev)
            dist_seeds = [allseeds[t * per:(t + 1) * per] for t in range(ndev)]
        else:
            dist_seeds = [np.array(sorted(
                [s for s in dist_seeds_all if s // stride == t]),
                dtype=np.int64) for t in range(ndev)]

        # group formation is *lazy*: the scheduler pulls groups on demand,
        # so Algorithm-3 grouping of wave k+1 overlaps wave k's compute
        queues = []
        for t in range(ndev):
            est = np.full(len(dist_seeds[t]), per_seed_cost)
            queues.append(GroupQueue(
                lazy=iter_region_groups(pg, dist_seeds[t], est,
                                        float(cfg.region_group_budget),
                                        seed=cfg.seed),
                n_lazy_seeds=len(dist_seeds[t])))
        # static wave width from the grouping invariant (phi <= budget, one
        # rollback slot) — groups cannot be sized without forming them all
        max_g = int(float(cfg.region_group_budget) // max(per_seed_cost, 1.0))
        max_g = max(1, min(max_g + 1, max(len(s) for s in dist_seeds)))
        scap = 1 << (max_g - 1).bit_length()
        sched.run(queues, scap, local_only=False, phase="dist")
        stats["n_groups"] = max(q.n_formed for q in queues)

    stats["final_caps"] = dict(frontier=runner.cfg.frontier_cap,
                               fetch=runner.cfg.fetch_cap,
                               verify=runner.cfg.verify_cap)
    return EnumerationResult(count=total,
                             embeddings=embs if return_embeddings else None,
                             stats=stats)
