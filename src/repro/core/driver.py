"""RADS host driver (§3.1 architecture).

Per machine: SM-E first (border-distance split, Prop. 1), then the
distributed R-Meef phase over region groups, with

* memory estimation calibrated from SM-E trie-node counters (§6),
* work stealing as balanced seed re-partitioning (checkR/shareR analogue),
* overflow-driven robustness loop: any capacity overflow is detected
  in-engine; the offending region group is recursively halved (§6 memory
  control), and if a *single seed* still overflows, capacities are doubled
  and the step recompiled (elastic capacity escalation) — enumeration never
  silently drops results.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.rads import DEFAULT_ENGINE, EngineConfig
from repro.core.engine import (PlanData, build_plan_data,
                               graph_device_arrays, run_rounds)
from repro.core.exchange import Exchange, ExchangeBackend
from repro.core.plan import Plan, best_plan
from repro.core.query import Pattern
from repro.core.region import make_region_groups
from repro.graph.storage import PartitionedGraph

_MAX_CAP = 1 << 22


@dataclass
class EnumerationResult:
    count: int
    embeddings: set[tuple[int, ...]] | None
    stats: dict = field(default_factory=dict)


def _pad_seeds(seeds_per_dev: list[np.ndarray], ndev: int, scap: int,
               sentinel: int) -> tuple[np.ndarray, np.ndarray]:
    out = np.full((ndev, scap), sentinel, dtype=np.int32)
    mask = np.zeros((ndev, scap), dtype=bool)
    for t, s in enumerate(seeds_per_dev):
        k = min(len(s), scap)
        out[t, :k] = s[:k]
        mask[t, :k] = True
    return out, mask


def _extract(rows: np.ndarray, alive: np.ndarray, pd: PlanData,
             pg: PartitionedGraph) -> set[tuple[int, ...]]:
    """rows (ndev, cap, n_q) in matching order -> query-order tuples in
    *original* vertex ids."""
    out: set[tuple[int, ...]] = set()
    r = rows[alive]
    if r.size == 0:
        return out
    inv = np.argsort(np.array(pd.order))
    for row in pg.new2old[r][:, inv]:
        out.add(tuple(int(x) for x in row))
    return out


class _Runner:
    """Holds the jitted step functions; re-jits on capacity escalation."""

    def __init__(self, adj, deg, meta, pd: PlanData, cfg: EngineConfig,
                 exch: ExchangeBackend):
        self.adj, self.deg, self.meta = adj, deg, meta
        self.pd, self.exch = pd, exch
        self.cfg = cfg
        self._build()

    def _build(self):
        meta, pd, cfg, exch = self.meta, self.pd, self.cfg, self.exch
        self.sme_fn = jax.jit(lambda a, d, s, m: run_rounds(
            a, d, meta, pd, cfg, exch, s, m, local_only=True))
        self.dist_fn = jax.jit(lambda a, d, s, m: run_rounds(
            a, d, meta, pd, cfg, exch, s, m, local_only=False))

    def escalate(self) -> bool:
        c = self.cfg
        if c.frontier_cap >= _MAX_CAP:
            return False
        self.cfg = dataclasses.replace(
            c, frontier_cap=min(c.frontier_cap * 2, _MAX_CAP),
            fetch_cap=min(c.fetch_cap * 2, _MAX_CAP),
            verify_cap=min(c.verify_cap * 2, _MAX_CAP))
        self._build()
        return True

    def run(self, fn_name: str, seeds, mask):
        fn = getattr(self, fn_name)
        return fn(self.adj, self.deg, jnp.asarray(seeds), jnp.asarray(mask))


def rads_enumerate(pg: PartitionedGraph, pattern: Pattern,
                   cfg: EngineConfig = DEFAULT_ENGINE,
                   mode: str = "sim", mesh=None,
                   plan: Plan | None = None,
                   return_embeddings: bool = True) -> EnumerationResult:
    """``mode`` selects a registered exchange backend: 'sim' (reference),
    'gather' (device-local, meshless), 'spmd' (sharded production path —
    requires ``mesh``)."""
    plan = plan or best_plan(pattern, cfg.plan_rho)
    pd = build_plan_data(plan)
    adj, deg, meta = graph_device_arrays(pg)
    exch = Exchange(mode=mode, mesh=mesh)
    if mode == "spmd":
        from jax.sharding import NamedSharding, PartitionSpec as P
        adj = jax.device_put(adj, NamedSharding(mesh, P("data", None, None)))
        deg = jax.device_put(deg, NamedSharding(mesh, P("data", None)))
    runner = _Runner(adj, deg, meta, pd, cfg, exch)

    # ---- candidate seeds per device: deg(v) >= deg(u_start) --------------- #
    ndev, stride = pg.ndev, pg.stride
    sme_seeds: list[np.ndarray] = []
    dist_seeds_all: list[int] = []
    for t in range(ndev):
        nl = int(pg.n_local[t])
        cand_local = np.flatnonzero(pg.deg[t, :nl] >= pd.start_deg)
        gids = cand_local + t * stride
        if cfg.enable_sme:
            is_sme = pg.border_dist[t, cand_local] >= pd.span_start
        else:
            is_sme = np.zeros(len(cand_local), dtype=bool)
        sme_seeds.append(gids[is_sme])
        dist_seeds_all.extend(map(int, gids[~is_sme]))

    stats = dict(n_sme_seeds=int(sum(len(s) for s in sme_seeds)),
                 n_dist_seeds=len(dist_seeds_all),
                 bytes_fetch=0.0, bytes_verify=0.0, n_groups=0,
                 overflow_retries=0, cap_escalations=0,
                 plan_rounds=plan.n_rounds,
                 sme_count=0, dist_count=0)
    total = 0
    embs: set[tuple[int, ...]] = set()

    def consume(rows, alive, counts, st, phase: str):
        nonlocal total
        c = int(np.asarray(counts).sum())
        total += c
        stats[f"{phase}_count"] += c
        stats["bytes_fetch"] += float(st["bytes_fetch"])
        stats["bytes_verify"] += float(st["bytes_verify"])
        if return_embeddings:
            embs.update(_extract(np.asarray(rows), np.asarray(alive), pd, pg))

    def run_batches(fn_name: str, batches: list[list[np.ndarray]],
                    scap: int, phase: str) -> float | None:
        """Process per-device seed batches with split-on-overflow and
        capacity escalation. Returns mean trie-node cost per seed."""
        cost = None
        stack = list(reversed(batches))
        while stack:
            cur = stack.pop()
            if max((len(b) for b in cur), default=0) == 0:
                continue
            if max(len(b) for b in cur) > scap:
                stack.append([b[scap:] for b in cur])
                cur = [b[:scap] for b in cur]
            seeds, mask = _pad_seeds(cur, ndev, scap, meta.n)
            rows, alive, counts, complete, st = runner.run(fn_name, seeds, mask)
            if not bool(complete):
                if max(len(b) for b in cur) <= 1:
                    if not runner.escalate():
                        raise RuntimeError("capacity ceiling reached")
                    stats["cap_escalations"] += 1
                    stack.append(cur)
                else:
                    stats["overflow_retries"] += 1
                    stack.append([b[len(b) // 2:] for b in cur])
                    stack.append([b[:len(b) // 2] for b in cur])
                continue
            consume(rows, alive, counts, st, phase)
            nc, mk = np.asarray(st["node_counts"]), np.asarray(mask)
            if mk.any():
                cost = float(nc[mk].mean())
        return cost

    # ---- SM-E phase ------------------------------------------------------- #
    per_seed_cost = 4.0 * pattern.n
    max_sme = max((len(s) for s in sme_seeds), default=0)
    if max_sme > 0:
        scap = 1 << (min(max_sme, 4096) - 1).bit_length()
        c = run_batches("sme_fn", _transpose_batches(sme_seeds), scap, "sme")
        if c is not None:
            per_seed_cost = max(c, 1.0)

    # ---- distributed phase: work stealing + region groups ----------------- #
    if dist_seeds_all:
        if cfg.enable_work_stealing:
            allseeds = np.array(sorted(dist_seeds_all), dtype=np.int64)
            per = -(-len(allseeds) // ndev)
            dist_seeds = [allseeds[t * per:(t + 1) * per] for t in range(ndev)]
        else:
            dist_seeds = [np.array(sorted(
                [s for s in dist_seeds_all if s // stride == t]),
                dtype=np.int64) for t in range(ndev)]

        groups_per_dev = []
        for t in range(ndev):
            est = np.full(len(dist_seeds[t]), per_seed_cost)
            groups_per_dev.append(make_region_groups(
                pg, dist_seeds[t], est, float(cfg.region_group_budget),
                seed=cfg.seed))
        stats["n_groups"] = max((len(g) for g in groups_per_dev), default=0)
        max_g = max((len(g) for gs in groups_per_dev for g in gs), default=1)
        scap = 1 << (max_g - 1).bit_length()

        queues = [list(gs) for gs in groups_per_dev]
        waves: list[list[np.ndarray]] = []
        while any(queues):
            waves.append([qs.pop(0) if qs else np.array([], dtype=np.int64)
                          for qs in queues])
        run_batches("dist_fn", waves, scap, "dist")

    stats["final_caps"] = dict(frontier=runner.cfg.frontier_cap,
                               fetch=runner.cfg.fetch_cap,
                               verify=runner.cfg.verify_cap)
    return EnumerationResult(count=total,
                             embeddings=embs if return_embeddings else None,
                             stats=stats)


def _transpose_batches(seeds_per_dev: list[np.ndarray]) -> list[list[np.ndarray]]:
    """One wave containing each device's full SM-E seed list (run_batches
    slices it into scap-sized chunks internally)."""
    return [[np.asarray(s, dtype=np.int64) for s in seeds_per_dev]]
