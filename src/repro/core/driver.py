"""RADS host driver (§3.1 architecture) — setup and result assembly.

Per machine: SM-E first (border-distance split, Prop. 1), then the
distributed R-Meef phase over region groups.  Wave execution — including
the overflow-driven robustness loop (group splitting + elastic capacity
escalation, §6 memory control), checkR/shareR queue rebalancing, and the
double-buffered async pipeline — lives in :mod:`repro.core.scheduler`;
this module only

* classifies seeds (SM-E vs distributed, Prop. 1),
* exports the partition in the configured on-device storage format
  (``EngineConfig.storage_format`` -> :func:`repro.graph.storage.device_graph`),
* constructs the device-resident foreign-adjacency cache from
  ``EngineConfig`` (:func:`repro.core.cache.build_cache`; sharded on the
  mesh for spmd) and hands it to the :class:`StageRunner` that owns it,
* preloads / persists the per-(pattern, graph) capacity & cost priors
  (:mod:`repro.core.priors`) — including the v2 per-seed ``node_counts``
  histogram (skew-aware wave sizing) and the learned auto pipeline depth —
  so repeat runs skip the escalate/re-jit ladder,
* builds the per-device region-group queues (§6, Algorithm 3),
* launches the two scheduler phases, and
* assembles the :class:`EnumerationResult` (counts, embeddings, stats).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field

import numpy as np

from repro import compat
from repro.configs.rads import DEFAULT_ENGINE, EngineConfig
from repro.core.cache import build_cache
from repro.core.engine import PlanData, build_plan_data
from repro.core.exchange import Exchange
from repro.core.plan import Plan, best_plan
from repro.core.priors import (HIST_BINS, hist_percentile, hist_update,
                               load_priors, priors_key, save_priors)
from repro.core.query import Pattern
from repro.core.region import iter_region_groups
from repro.core.scheduler import GroupQueue, PipelineScheduler, StageRunner
from repro.core.wire import register_wire_metrics, resolve_wire_format
from repro.graph.storage import PartitionedGraph, device_graph
from repro.obs import NULL_TRACER, build_driver_registry


@dataclass
class EnumerationResult:
    count: int
    embeddings: set[tuple[int, ...]] | None
    stats: dict = field(default_factory=dict)
    # the typed MetricsRegistry behind ``stats`` (same values; carries
    # kind/unit/description and the JSON / Prometheus exporters)
    registry: object = None


def extract_embeddings(rows: np.ndarray, alive: np.ndarray, pd: PlanData,
                       pg: PartitionedGraph) -> set[tuple[int, ...]]:
    """rows (ndev, cap, n_q) in matching order -> query-order tuples in
    *original* vertex ids (one vectorized unique over the whole block)."""
    r = rows[alive]
    if r.size == 0:
        return set()
    inv = np.argsort(np.array(pd.order))
    remapped = pg.new2old[r][:, inv]
    return set(map(tuple, np.unique(remapped, axis=0).tolist()))


def rads_enumerate(pg: PartitionedGraph, pattern: Pattern,
                   cfg: EngineConfig = DEFAULT_ENGINE,
                   mode: str = "sim", mesh=None,
                   plan: Plan | None = None,
                   return_embeddings: bool = True,
                   runner_cache: dict | None = None,
                   tracer=None) -> EnumerationResult:
    """``mode`` selects a registered exchange backend: 'sim' (reference),
    'gather' (device-local, meshless), 'spmd' (sharded production path —
    requires ``mesh``), 'dist' (spmd across ``jax.distributed`` processes —
    requires a process-spanning ``mesh``; see :mod:`repro.launch.dist_worker`
    for the bootstrap and :func:`merge_process_stats` for combining the
    per-process stats); ``cfg.storage_format`` selects the on-device
    adjacency layout ('dense' | 'bucketed').

    ``runner_cache``: optional dict the caller owns.  Repeat calls with the
    same (graph, pattern, mode, cfg) reuse the jitted :class:`StageRunner`
    from the cache, so only the first call pays stage compilation —
    benchmarks use this to split ``compile_us`` from steady-state
    ``wall_us``.

    ``tracer``: optional :class:`repro.obs.trace.TraceRecorder` — wave /
    stage / prewarm / scheduler spans land in it for Chrome-trace export;
    the default :data:`~repro.obs.trace.NULL_TRACER` records nothing and
    adds zero instruments to the wave loop.
    """
    tracer = tracer if tracer is not None else NULL_TRACER
    explicit_plan = plan
    plan = plan or best_plan(pattern, cfg.plan_rho)
    pd = build_plan_data(plan)

    if mode == "dist" and cfg.pipeline_depth == "auto":
        # cross-process determinism: every process must dispatch identical
        # collectives in identical order, and the adaptive depth steers
        # from *local* wall timing — pin it to the double-buffered default
        cfg = dataclasses.replace(cfg, pipeline_depth=2)

    # ---- capacity / cost priors (persisted §6 calibration) ---------------- #
    pkey = priors_key(pattern, pg) if cfg.priors_path else None
    prior = load_priors(cfg.priors_path).get(pkey) if pkey else None
    # measured wire auto-selection resolves BEFORE the runner key is built,
    # so warm runs land on the executables persisted for the chosen codec
    requested_wire = cfg.wire_format
    wire_fmt, wire_reason = resolve_wire_format(requested_wire, mode, prior)
    if wire_fmt != cfg.wire_format:
        cfg = dataclasses.replace(cfg, wire_format=wire_fmt)
    if prior:
        caps = prior.get("caps", {})
        cfg = dataclasses.replace(
            cfg,
            frontier_cap=max(cfg.frontier_cap, int(caps.get("frontier", 0))),
            fetch_cap=max(cfg.fetch_cap, int(caps.get("fetch", 0))),
            verify_cap=max(cfg.verify_cap, int(caps.get("verify", 0))))

    ck = None
    runner = None
    if runner_cache is not None:
        # the cached entry pins pg (and the plan), so the id()s can never be
        # recycled onto a different graph while the cache is alive; the mesh
        # participates directly (jax.sharding.Mesh hashes by content)
        ck = (mode, id(pg), pattern, cfg, mesh,
              id(explicit_plan) if explicit_plan is not None else None)
        hit = runner_cache.get(ck)
        runner = hit[-1] if hit is not None else None
    if runner is None:
        g = device_graph(pg, cfg.storage_format)
        adj_cache = build_cache(cfg, g)           # None when disabled
        if mode in ("spmd", "dist"):
            g = g.shard(mesh)
            if adj_cache is not None:
                adj_cache = adj_cache.shard(mesh)
        runner = StageRunner(g, pd, cfg,
                             Exchange(mode=mode, mesh=mesh,
                                      wire_format=cfg.wire_format,
                                      comm_chunks=(cfg.comm_chunks
                                                   if cfg.comm_pipeline
                                                   else 1)),
                             cache=adj_cache, tracer=tracer)
        if ck is not None:
            runner_cache[ck] = (pg, explicit_plan, runner)
    runner.tracer = tracer     # cached runners adopt this call's recorder
    # compile accounting is reported as THIS call's delta (runner_cache
    # reuses runners across calls, so the counters are cumulative)
    compiles0, compile_s0 = runner.compiles, runner.compile_s
    exec_stats0 = (dict(runner.exec_cache.stats)
                   if runner.exec_cache is not None else None)

    # ---- candidate seeds per device: deg(v) >= deg(u_start) --------------- #
    ndev, stride = pg.ndev, pg.stride
    sme_seeds: list[np.ndarray] = []
    dist_seeds_all: list[int] = []
    for t in range(ndev):
        nl = int(pg.n_local[t])
        cand_local = np.flatnonzero(pg.deg[t, :nl] >= pd.start_deg)
        gids = cand_local + t * stride
        if cfg.enable_sme:
            is_sme = pg.border_dist[t, cand_local] >= pd.span_start
        else:
            is_sme = np.zeros(len(cand_local), dtype=bool)
        sme_seeds.append(gids[is_sme])
        dist_seeds_all.extend(map(int, gids[~is_sme]))

    # the run's stats object is the typed registry declared in
    # repro.obs.schema — a MutableMapping, so every accumulation below and
    # in the scheduler works exactly as on the plain dict it replaces
    stats = build_driver_registry()
    stats["n_sme_seeds"] = int(sum(len(s) for s in sme_seeds))
    stats["n_dist_seeds"] = len(dist_seeds_all)
    for k in ("bytes_fetch", "bytes_verify", "bytes_wire_fetch",
              "bytes_wire_verify", "bytes_saved_cache", "cache_hits",
              "cache_probes", "compile_cache_hits", "compile_s",
              "wave_s_total", "sme_wall_us", "dist_wall_us"):
        stats[k] = 0.0
    for k in ("n_groups", "overflow_retries", "cap_escalations",
              "sme_count", "dist_count", "n_waves", "max_inflight_waves",
              "steal_events", "compiles"):
        stats[k] = 0
    stats["bytes_wire_fetch_dev"] = np.zeros(ndev)
    stats["bytes_wire_verify_dev"] = np.zeros(ndev)
    # subsystems set the instruments they own (declared in the schema)
    runner.exch.register_metrics(stats, comm_pipeline=cfg.comm_pipeline)
    register_wire_metrics(stats, cfg.wire_format, requested_wire,
                          wire_reason)
    if runner.cache is not None:
        runner.cache.register_metrics(stats)
    else:
        stats["cache_enabled"] = False
        stats["cache_bytes"] = 0
    stats["exec_cache_enabled"] = bool(runner.exec_cache is not None
                                       and runner.exec_cache.enabled)
    stats["plan_rounds"] = plan.n_rounds
    stats["pipeline_depth"] = cfg.pipeline_depth
    stats["storage_format"] = cfg.storage_format
    stats["peak_adj_bytes"] = int(runner.g.adj_bytes)
    stats["priors_preloaded"] = bool(prior)
    total = 0
    embs: set[tuple[int, ...]] = set()
    node_hist = np.zeros(HIST_BINS, dtype=np.int64)

    def consume(rows, alive, counts, st, phase: str):
        nonlocal total
        c = int(np.asarray(counts).sum())
        total += c
        stats[f"{phase}_count"] += c
        stats["bytes_fetch"] += float(st["bytes_fetch"])
        stats["bytes_verify"] += float(st["bytes_verify"])
        stats["bytes_wire_fetch"] += float(st["bytes_wire_fetch"])
        stats["bytes_wire_verify"] += float(st["bytes_wire_verify"])
        stats["bytes_wire_fetch_dev"] += np.asarray(
            st["bytes_wire_fetch_dev"], dtype=np.float64)
        stats["bytes_wire_verify_dev"] += np.asarray(
            st["bytes_wire_verify_dev"], dtype=np.float64)
        stats["bytes_fetch_compressed"] += float(st["bytes_fetch_compressed"])
        stats["bytes_saved_cache"] += float(st["bytes_saved_cache"])
        stats["cache_hits"] += float(st["cache_hits"])
        stats["cache_probes"] += float(st["cache_probes"])
        stats["compile_cache_hits"] += float(st["compile_cache_hits"])
        hist_update(node_hist, st["seed_node_counts"])
        if return_embeddings:
            embs.update(extract_embeddings(np.asarray(rows),
                                           np.asarray(alive), pd, pg))

    sched = PipelineScheduler(runner, stats, consume)

    # ---- SM-E phase ------------------------------------------------------- #
    per_seed_cost = 4.0 * pattern.n
    if prior and prior.get("per_seed_cost"):
        per_seed_cost = max(float(prior["per_seed_cost"]), 1.0)
    # priors v2: the persisted node_counts histogram sizes waves from a high
    # percentile of the per-seed cost *distribution* (skew-aware), and the
    # learned auto pipeline depth seeds the adaptive scheduler
    prior_hist = prior.get("node_hist") if prior else None
    prior_depth = prior.get("pipeline_depth") if prior else None
    auto_start = prior_depth if cfg.pipeline_depth == "auto" else None
    if prior_hist:
        stats["prior_cost_p90"] = hist_percentile(prior_hist, 0.90)
    max_sme = max((len(s) for s in sme_seeds), default=0)
    if max_sme > 0:
        scap = 1 << (min(max_sme, 4096) - 1).bit_length()
        if cfg.prewarm:
            # resolve the SM-E ladder on a background thread while the
            # queue setup below runs (compile — or store deserialization —
            # off the critical path); with preloaded priors the caps are
            # trustworthy, so also warm the escalation rung above them —
            # an overflow run then escalates onto already-resolved stages
            runner.prewarm_async(scap, local_only=True,
                                 escalation_rungs=1 if prior else 0)
        queues = [[np.asarray(s, dtype=np.int64)] if len(s) else []
                  for s in sme_seeds]
        c = sched.run(queues, scap, local_only=True, phase="sme",
                      auto_start=auto_start)
        if c is not None:
            per_seed_cost = max(c, 1.0)

    # ---- distributed phase: work stealing + region groups ----------------- #
    if dist_seeds_all:
        if cfg.enable_work_stealing:
            allseeds = np.array(sorted(dist_seeds_all), dtype=np.int64)
            per = -(-len(allseeds) // ndev)
            dist_seeds = [allseeds[t * per:(t + 1) * per] for t in range(ndev)]
        else:
            dist_seeds = [np.array(sorted(
                [s for s in dist_seeds_all if s // stride == t]),
                dtype=np.int64) for t in range(ndev)]

        # group formation is *lazy*: the scheduler pulls groups on demand,
        # so Algorithm-3 grouping of wave k+1 overlaps wave k's compute
        queues = []
        for t in range(ndev):
            est = np.full(len(dist_seeds[t]), per_seed_cost)
            queues.append(GroupQueue(
                lazy=iter_region_groups(pg, dist_seeds[t], est,
                                        float(cfg.region_group_budget),
                                        seed=cfg.seed),
                n_lazy_seeds=len(dist_seeds[t])))
        # static wave width from the grouping invariant (phi <= budget, one
        # rollback slot) — groups cannot be sized without forming them all.
        # With a persisted histogram the denominator is the p90 per-seed
        # cost, not the mean: hub-heavy groups stop overflowing their wave.
        size_cost = max(per_seed_cost, 1.0)
        if prior_hist:
            size_cost = max(size_cost, hist_percentile(prior_hist, 0.90))
        max_g = int(float(cfg.region_group_budget) // size_cost)
        max_g = max(1, min(max_g + 1, max(len(s) for s in dist_seeds)))
        scap = 1 << (max_g - 1).bit_length()
        if cfg.prewarm:
            # distributed-phase ladder warms while Algorithm-3 lazy group
            # formation runs inside the scheduler (plus one escalation
            # rung when priors made the caps trustworthy — see SM-E phase)
            runner.prewarm_async(scap, local_only=False,
                                 escalation_rungs=1 if prior else 0)
        c = sched.run(queues, scap, local_only=False, phase="dist",
                      auto_start=auto_start)
        if c is not None:
            per_seed_cost = max(c, 1.0)
        stats["n_groups"] = max(q.n_formed for q in queues)

    # settle background pre-warm before reading the compile counters, then
    # drain store hits banked by prewarm-only resolutions (waves that ran
    # already consumed theirs through finalize_wave's exec_hits argument)
    runner.join_prewarm()
    # total span-clock wall across phases — per-process honest under dist
    # (merge_process_stats max-merges it and derives wall_skew)
    stats["wall_us"] = (stats.get("sme_wall_us", 0.0)
                        + stats.get("dist_wall_us", 0.0))
    stats["compile_cache_hits"] += runner.take_hits()
    stats["compiles"] = runner.compiles - compiles0
    stats["compile_s"] = runner.compile_s - compile_s0
    if exec_stats0 is not None:
        stats["exec_cache"] = {k: runner.exec_cache.stats[k] - exec_stats0[k]
                               for k in exec_stats0}

    stats["final_caps"] = dict(frontier=runner.cfg.frontier_cap,
                               fetch=runner.cfg.fetch_cap,
                               verify=runner.cfg.verify_cap)
    stats["cache_hit_rate"] = (stats["cache_hits"] / stats["cache_probes"]
                               if stats["cache_probes"] else 0.0)
    stats["node_hist"] = node_hist.tolist()
    # per-device wire-byte attribution -> JSON-friendly lists + the skew
    # metric the scalability harness plots (max-per-process over mean; the
    # per-dev sums recover the scalar bytes_wire_* totals exactly)
    fetch_dev = np.asarray(stats["bytes_wire_fetch_dev"], dtype=np.float64)
    verify_dev = np.asarray(stats["bytes_wire_verify_dev"], dtype=np.float64)
    comm_dev = fetch_dev + verify_dev
    stats["bytes_wire_fetch_dev"] = fetch_dev.tolist()
    stats["bytes_wire_verify_dev"] = verify_dev.tolist()
    stats["bytes_wire_max_dev"] = float(comm_dev.max()) if ndev else 0.0
    mean_dev = float(comm_dev.mean()) if ndev else 0.0
    stats["comm_skew"] = (float(comm_dev.max()) / mean_dev
                          if mean_dev > 0 else 1.0)
    if pkey and compat.process_index() == 0:
        # under dist every process computes identical logical stats (the
        # merge asserts it), so only process 0 touches the shared priors
        # file — last-writer races between processes would drop trials
        entry = dict(per_seed_cost=float(per_seed_cost),
                     caps=stats["final_caps"],
                     node_hist=node_hist.tolist())
        if "auto_depth" in stats:
            entry["pipeline_depth"] = int(stats["auto_depth"])
        elif prior_depth:                 # keep the learned depth alive
            entry["pipeline_depth"] = int(prior_depth)
        # wire trials feed resolve_wire_format's measured selection: record
        # compute time net of compilation (prewarm hides most of it, but a
        # cold raw run must not look slower than a warm varint run)
        trials = dict(prior.get("wire_trials", {})) if prior else {}
        trials[f"{mode}:{cfg.wire_format}"] = dict(
            pipeline_s=max(stats["wave_s_total"] - stats["compile_s"], 0.0),
            wire_bytes=stats["bytes_wire_fetch"] + stats["bytes_wire_verify"])
        entry["wire_trials"] = trials
        choice = dict(prior.get("wire_choice", {})) if prior else {}
        if requested_wire == "auto":
            choice[mode] = cfg.wire_format   # hysteresis anchor for next run
        if choice:
            entry["wire_choice"] = choice
        save_priors(cfg.priors_path, pkey, entry)
    return EnumerationResult(count=total,
                             embeddings=embs if return_embeddings else None,
                             stats=stats.to_stats(), registry=stats)


# logical stats every process must agree on byte-for-byte under dist (the
# replicated finalize hands every host identical wave tuples, so any
# divergence here means the collectives themselves diverged)
_MERGE_EQUAL_KEYS = (
    "bytes_fetch", "bytes_verify", "bytes_wire_fetch", "bytes_wire_verify",
    "bytes_wire_fetch_dev", "bytes_wire_verify_dev", "bytes_wire_max_dev",
    "bytes_fetch_compressed", "bytes_saved_cache", "cache_hits",
    "cache_probes", "comm_skew", "n_waves", "n_groups", "sme_count",
    "dist_count", "overflow_retries", "cap_escalations", "wire_format")
# host-local wall/compile timings: the run is as slow as its slowest process
_MERGE_MAX_KEYS = ("wave_s_total", "compile_s", "sme_pipeline_s",
                   "dist_pipeline_s", "sme_wall_us", "dist_wall_us",
                   "wall_us")


def merge_process_stats(per_proc_stats: list[dict]) -> dict:
    """Merge the per-process stats dicts of one multi-process ``dist`` run.

    Logical counters (bytes, counts, waves) are *replicated* state — every
    process retires identical finalize tuples — so equality across
    processes is asserted, not averaged: a mismatch is a determinism bug,
    and papering over it with a mean would hide exactly the failure the
    parity gates exist to catch.  Wall-clock keys are host-local and merge
    via max (a wave is retired when its slowest process retires it).
    """
    if not per_proc_stats:
        raise ValueError("merge_process_stats needs at least one stats dict")
    base = per_proc_stats[0]
    mismatches = []
    for key in _MERGE_EQUAL_KEYS:
        if key not in base:
            continue
        for i, st in enumerate(per_proc_stats[1:], start=1):
            if key in st and st[key] != base[key]:
                mismatches.append(
                    f"{key}: proc0={base[key]!r} proc{i}={st[key]!r}")
    if mismatches:
        raise ValueError(
            "per-process logical stats diverged (determinism bug): "
            + "; ".join(mismatches))
    merged = dict(base)
    for key in _MERGE_MAX_KEYS:
        vals = [st[key] for st in per_proc_stats if key in st]
        if vals:
            merged[key] = max(float(v) for v in vals)
    merged["process_count"] = len(per_proc_stats)
    merged["per_process_wall_s"] = [
        float(st.get("wave_s_total", 0.0)) for st in per_proc_stats]
    # honest dist wall clock: each process's span-clock phase wall survives
    # the merge individually, and wall_skew (max/mean, like comm_skew for
    # bytes) is the load-balance signal the scalability bench plots
    walls = [float(st.get("wall_us", 0.0)) for st in per_proc_stats]
    merged["per_process_wall_us"] = walls
    mean_wall = sum(walls) / len(walls)
    merged["wall_skew"] = (max(walls) / mean_wall if mean_wall > 0 else 1.0)
    return merged
