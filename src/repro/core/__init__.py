"""The paper's primary contribution: RADS / R-Meef distributed subgraph
enumeration — planner, engines, trie, region groups, baselines."""
from repro.core.query import Pattern
from repro.core.plan import (Plan, Unit, best_plan, enumerate_plans,
                             minimum_cds, bfs_fallback_plan,
                             random_star_plan, min_rounds_unscored_plan,
                             compute_matching_order)
from repro.core.engine import (PlanData, build_plan_data, run_rounds,
                               WaveState, init_wave, fetch_stage,
                               expand_stage, verify_stage, finalize_wave)
from repro.core.cache import AdjCache, build_cache
from repro.core.scheduler import GroupQueue, PipelineScheduler, StageRunner
from repro.core.driver import (rads_enumerate, EnumerationResult,
                               extract_embeddings)
from repro.core.priors import load_priors, priors_key, save_priors
from repro.core.oracle import enumerate_oracle, count_oracle, canonicalize
from repro.core.trie import EmbeddingTrie, compression_report
from repro.core.region import (iter_region_groups, make_region_groups,
                               proximity_groups)
from repro.core.exchange import (Exchange, ExchangeBackend,
                                 exchange_backends,
                                 register_exchange_backend)

__all__ = [
    "Pattern", "Plan", "Unit", "best_plan", "enumerate_plans", "minimum_cds",
    "bfs_fallback_plan", "random_star_plan", "min_rounds_unscored_plan",
    "compute_matching_order", "PlanData", "build_plan_data", "run_rounds",
    "WaveState", "init_wave",
    "fetch_stage", "expand_stage", "verify_stage", "finalize_wave",
    "load_priors", "priors_key", "save_priors",
    "AdjCache", "build_cache",
    "GroupQueue", "PipelineScheduler", "StageRunner",
    "iter_region_groups",
    "rads_enumerate", "EnumerationResult", "extract_embeddings",
    "enumerate_oracle", "count_oracle", "canonicalize", "EmbeddingTrie",
    "compression_report", "make_region_groups", "proximity_groups", "Exchange",
    "ExchangeBackend", "exchange_backends", "register_exchange_backend",
]
