"""Brute-force single-machine subgraph-enumeration oracle (correctness ref).

Backtracking over pattern vertices in a fixed order, enforcing edges,
injectivity and the same symmetry-breaking constraints as the engines, so
result *sets* (not just counts) are directly comparable.
"""
from __future__ import annotations

import numpy as np

from repro.core.query import Pattern
from repro.graph.storage import Graph


def enumerate_oracle(graph: Graph, pattern: Pattern,
                     order: tuple[int, ...] | None = None,
                     constraints: list[tuple[int, int]] | None = None,
                     ) -> set[tuple[int, ...]]:
    """Return the set of embeddings as tuples indexed by *query vertex id*
    (i.e., result[u] = data vertex matched to query vertex u)."""
    n = pattern.n
    if order is None:
        # BFS order from vertex 0 keeps each new vertex adjacent to a prior one
        order = _bfs_order(pattern)
    if constraints is None:
        constraints = pattern.symmetry_constraints()
    pos = {u: i for i, u in enumerate(order)}
    # per-step: edges back to already-mapped vertices; symmetry pairs ready
    back_edges: list[list[int]] = []
    sym_lt: list[list[int]] = []  # f(u') < f(u) required, u' mapped earlier
    sym_gt: list[list[int]] = []  # f(u) < f(u') required
    for i, u in enumerate(order):
        back_edges.append([w for w in pattern.adj(u) if pos[w] < i])
        lt, gt = [], []
        for (a, b) in constraints:
            if b == u and pos[a] < i:
                lt.append(a)
            if a == u and pos[b] < i:
                gt.append(b)
        sym_lt.append(lt)
        sym_gt.append(gt)

    results: set[tuple[int, ...]] = set()
    mapping = np.full(n, -1, dtype=np.int64)
    used: set[int] = set()
    deg = pattern.degrees()

    def rec(i: int):
        if i == n:
            results.add(tuple(int(x) for x in mapping))
            return
        u = order[i]
        if i == 0:
            cand = range(graph.n)
        else:
            anchor = back_edges[i][0]
            cand = graph.neighbors(mapping[anchor])
        for v in cand:
            v = int(v)
            if v in used:
                continue
            if len(graph.neighbors(v)) < deg[u]:
                continue
            if any(not graph.has_edge(mapping[w], v) for w in back_edges[i]):
                continue
            if any(mapping[w] >= v for w in sym_lt[i]):
                continue
            if any(mapping[w] <= v for w in sym_gt[i]):
                continue
            mapping[u] = v
            used.add(v)
            rec(i + 1)
            used.discard(v)
            mapping[u] = -1

    rec(0)
    return results


def _bfs_order(pattern: Pattern) -> tuple[int, ...]:
    order = [0]
    seen = {0}
    i = 0
    while len(order) < pattern.n:
        u = order[i]
        i += 1
        for w in pattern.adj(u):
            if w not in seen:
                seen.add(w)
                order.append(w)
    return tuple(order)


def count_oracle(graph: Graph, pattern: Pattern) -> int:
    return len(enumerate_oracle(graph, pattern))


def canonicalize(embs: set[tuple[int, ...]], pattern: Pattern
                 ) -> set[tuple[int, ...]]:
    """Map each embedding to the lexicographically-smallest member of its
    automorphism class. Engines break symmetry on *renumbered* vertex ids,
    so representative choice may differ from the oracle's — canonical forms
    are the comparable invariant (and set sizes must be preserved)."""
    autos = pattern.automorphisms()
    out = set()
    for e in embs:
        out.add(min(tuple(e[a[u]] for u in range(pattern.n)) for a in autos))
    assert len(out) == len(embs), "duplicate embeddings within a class"
    return out
