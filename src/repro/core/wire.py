"""On-the-wire codecs for the exchange payloads (compressed fetchV/verifyE).

RADS's headline claim is minimal communication; after PR 4's cache absorbed
most of the fetchV traffic, the verifyE pair exchange dominates the wire.
This module turns the *modeled* delta+varint column of PR 4 into real
on-the-wire coding: every codec here encodes a payload lane into a compact
``uint8`` stream *inside the jitted stage*, the streams (plus per-lane byte
lengths) travel through ``ExchangeBackend.a2a_tree``, and the receiving
device decodes them back — ``encode ∘ decode`` is exact, so enumeration
results are wire-format-invariant by construction.

Stream layout
-------------
A *lane* is one (source device, peer device) payload of a batched exchange.
All codecs are fixed-capacity: a lane encodes into a static ``cap``-byte
buffer plus a dynamic byte ``length`` (the only bytes a real transport
would put on the wire — the accounting sums lengths, never capacities).

* **fetchV request ids** (:func:`encode_ids` / :func:`decode_ids`) —
  sorted-unique vertex ids, sentinel holes allowed (cache hits are masked
  off the wire).  The wire stream drops the holes: valid ids are
  delta-coded against the previous valid id (first id absolute) and each
  delta is LEB128-varint coded (7 payload bits per byte, high bit =
  continuation).  The value boundaries are self-describing (a clear high
  bit terminates a value), so the decoder recovers the id count from the
  stream alone.  The requester remembers its hole positions and scatters
  the positional responses back (:func:`scatter_compacted`).
* **fetchV response rows** (:func:`encode_rows` / :func:`decode_rows`) —
  one sorted sentinel-padded adjacency window per valid request, as two
  streams: a varint *degree* stream (one value per row) and a flat varint
  *id* stream (per row: first neighbor absolute, then deltas).  Row
  boundaries come from the degree stream, so the id stream carries no
  padding at all — on an avg-degree-8 graph this replaces the raw
  ``4·max_degree`` bytes/row with ~``1 + 2·deg`` bytes.
* **verifyE pairs** (:func:`encode_pairs` / :func:`decode_pairs`) — the
  per-peer EVI request lanes arrive lexicographically sorted, so the ``a``
  column is monotone: it is coded Elias-Fano style (``l`` low bits packed
  contiguously, high bits in unary; ``l`` is derived from (universe,
  count) by integer bit-length arithmetic so encoder and decoder agree
  without transmitting it).  The ``b`` column is varint coded: absolute at
  the start of each equal-``a`` run, delta inside a run (unique pairs make
  in-run deltas >= 1).  Pair count rides the control plane (the ``counts``
  matrix every exchange already computes).
* **verifyE answers** (:func:`pack_bools` / :func:`unpack_bools`) — one
  bit per queried pair (``ceil(count/8)`` bytes instead of one byte per
  bool).

Capacity / escalation contract
------------------------------
Stream capacities derive from the engine capacities
(:func:`fetch_stream_caps` / :func:`verify_stream_caps`), so a scheduler
capacity escalation doubles them alongside ``fetch_cap``/``verify_cap``
and the stages re-jit with the wider streams.  Every encoder still returns
an ``overflow`` flag (ORed into the wave's overflow, handled by the same
split/escalate loop) — but with the derived capacities a coded lane is
only ever *selected* when it fits, because of the raw escape below.

Raw escape (the ``<= raw`` guarantee)
-------------------------------------
Each encoder also materializes the lane in raw little-endian ``int32``
form and picks whichever is smaller (a per-lane ``raw`` flag rides the
control plane, like a real codec's stored-block bit).  Wire bytes
therefore never exceed the raw accounting — the per-wave identity
``bytes_wire_fetch <= bytes_fetch`` holds *exactly*, even for adversarial
id distributions where varint deltas would need 5 bytes.

Why delta+varint (and EF) for ids, not quantization
---------------------------------------------------
Vertex ids are exact references — a single flipped low bit verifies the
wrong edge — so the int8-quantization machinery used for gradients
(:mod:`repro.distributed.compression`) is unusable here.  Sorted id
vectors are instead *structurally* redundant: deltas of a sorted-unique
sequence over universe ``n`` carry ~``log2(n/count)`` bits of entropy, not
32, which is exactly what delta+varint (byte-granular) and Elias-Fano
(bit-granular, for the monotone verifyE ``a`` column) exploit — lossless
by construction.

Per-lane byte lengths, pair counts, and raw flags are control-plane
metadata (a real transport's message headers), mirroring how the raw path
never charges for its implicit sentinel structure; the accounting for both
formats charges payload bytes only.

The modeled :func:`repro.core.engine._varint_id_bytes` column caps varints
at 4 bytes (its escape is amortized); the real codec emits true 5-byte
LEB128 for deltas >= 2^28, so actual and modeled fetch id bytes agree
exactly for every graph with ``n < 2^28`` (all of ours) and may differ
beyond that.

All codecs are pure jnp (scatter/gather + cumulative sums, static shapes)
so they vmap over the ``(ndev, peer)`` lane grid and pass through
``jax.jit``/``shard_map`` untouched; the delta/varint-size pass of the id
encoder — the hot fetch-path op — routes through the Pallas kernel in
:mod:`repro.kernels.varint` when ``use_pallas_kernels`` is set (the jnp
reference stays the CPU path).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.varint.ops import delta_vlen
from repro.kernels.varint.ref import varint_size

WIRE_FORMATS = ("raw", "varint")

_U8 = jnp.uint8
_I32 = jnp.int32


# --------------------------------------------------------------------------- #
# Capacity helpers (derived from the engine caps => escalate together)
# --------------------------------------------------------------------------- #
def fetch_stream_caps(fcap: int, max_degree: int) -> tuple[int, int, int]:
    """(request id stream, response degree stream, response id stream) caps.

    Sized so the raw escape always fits: requests <= 4 B/id, responses
    <= 4·max_degree B/row; the coded form is only selected when smaller.
    """
    return 4 * fcap, 2 * fcap, 4 * max_degree * fcap


def verify_stream_caps(vcap: int) -> tuple[int, int, int]:
    """(a stream, b stream, answer stream) caps — raw escape fits 4 B/id
    per column; answers are bit-packed (always <= 1 B/pair)."""
    return 4 * vcap, 4 * vcap, (vcap + 7) // 8


# --------------------------------------------------------------------------- #
# Varint core (per-lane; callers vmap).  The LEB128 sizing ladder is
# shared with the kernel package (`repro.kernels.varint.ref.varint_size`)
# so the stream-length selection and the delta_vlen fast path can never
# drift apart.
# --------------------------------------------------------------------------- #
def _write_varints(vals: jnp.ndarray, vlen: jnp.ndarray, cap: int):
    """Scatter LEB128 codes into a ``cap``-byte stream.

    ``vals`` (K,) non-negative; ``vlen`` (K,) byte sizes with 0 = skip.
    Returns (stream (cap,) u8, total_bytes ()).  Entries are laid out in
    array order at offsets ``exclusive_cumsum(vlen)``; bytes past ``cap``
    are dropped (the caller's raw escape guarantees they are never
    selected)."""
    vals = vals.astype(_I32)
    offs = jnp.cumsum(vlen) - vlen
    total = vlen.sum()
    stream = jnp.zeros((cap,), _U8)
    for b in range(5):
        sel = vlen > b
        byte = ((vals >> (7 * b)) & 0x7F) | jnp.where(vlen > b + 1, 0x80, 0)
        stream = stream.at[jnp.where(sel, offs + b, cap)].set(
            byte.astype(_U8), mode="drop")
    return stream, total


def _parse_varints(stream: jnp.ndarray, length: jnp.ndarray, m_out: int):
    """Inverse of :func:`_write_varints`: fully vectorized LEB128 parse.

    Value boundaries are self-describing (a clear high bit ends a value):
    byte -> segment via a cumulative count of terminators, in-segment
    position via a running max over segment starts, then one scatter-add
    assembles the 7-bit payloads.  Returns (vals (m_out,), count ())."""
    cap = stream.shape[0]
    idx = jnp.arange(cap)
    inb = idx < length
    byte = stream.astype(_I32)
    term = inb & ((byte & 0x80) == 0)
    seg = jnp.cumsum(term.astype(_I32)) - term.astype(_I32)
    prev_term = jnp.concatenate([jnp.array([True]), term[:-1]])
    start = inb & prev_term
    sidx = jax.lax.cummax(jnp.where(start, idx, -1))
    p7 = jnp.clip(idx - sidx, 0, 4)
    contrib = (byte & 0x7F) << (7 * p7)
    vals = jnp.zeros((m_out,), _I32).at[jnp.where(inb, seg, m_out)].add(
        jnp.where(inb, contrib, 0), mode="drop")
    return vals, term.sum()


def _write_raw32(vals: jnp.ndarray, pos: jnp.ndarray, valid: jnp.ndarray,
                 cap: int, stream: jnp.ndarray | None = None):
    """Little-endian int32s at 4-byte slots ``pos`` (the raw escape)."""
    if stream is None:
        stream = jnp.zeros((cap,), _U8)
    vals = vals.astype(_I32)
    for b in range(4):
        byte = ((vals >> (8 * b)) & 0xFF).astype(_U8)
        stream = stream.at[jnp.where(valid, pos * 4 + b, cap)].set(
            byte, mode="drop")
    return stream


def _read_raw32(stream: jnp.ndarray, pos: jnp.ndarray) -> jnp.ndarray:
    """Gather little-endian int32s from 4-byte slots ``pos``."""
    cap = stream.shape[0]
    out = jnp.zeros(pos.shape, _I32)
    for b in range(4):
        out = out | (stream[jnp.clip(pos * 4 + b, 0, cap - 1)].astype(_I32)
                     << (8 * b))
    return out


# --------------------------------------------------------------------------- #
# fetchV request ids: delta + varint over a sorted-with-holes lane
# --------------------------------------------------------------------------- #
def _encode_ids_core(ids, delta, vlen, cap: int):
    valid = vlen > 0
    count = valid.sum()
    coded, total = _write_varints(delta, vlen, cap)
    raw_len = 4 * count
    use_raw = (total > raw_len) | (total > cap)
    rank = jnp.cumsum(valid) - 1
    raw = _write_raw32(ids, rank, valid, cap)
    stream = jnp.where(use_raw, raw, coded)
    length = jnp.where(use_raw, raw_len, total)
    return stream, length.astype(_I32), use_raw, length > cap


def encode_ids(ids: jnp.ndarray, sentinel: int, cap: int,
               use_pallas: bool = False, interpret: bool = True):
    """One lane: sorted ids with sentinel holes -> compacted varint stream.

    Returns ``(stream (cap,) u8, length (), raw (), overflow ())``."""
    delta, vlen = delta_vlen(ids[None], sentinel, use_kernel=use_pallas,
                             interpret=interpret)
    return _encode_ids_core(ids, delta[0], vlen[0], cap)


def decode_ids(stream: jnp.ndarray, length: jnp.ndarray, raw: jnp.ndarray,
               m_out: int, sentinel: int):
    """Inverse of :func:`encode_ids`: ids land compacted at the front.

    Returns ``(ids (m_out,) ascending, sentinel-filled; mask (m_out,))``."""
    deltas, count_c = _parse_varints(stream, length, m_out)
    ids_c = jnp.cumsum(deltas)
    k = jnp.arange(m_out)
    ids_r = _read_raw32(stream, k)
    count = jnp.where(raw, length // 4, count_c)
    mask = k < count
    ids = jnp.where(mask, jnp.where(raw, ids_r, ids_c), sentinel)
    return ids, mask


def scatter_compacted(rows_c: jnp.ndarray, valid: jnp.ndarray,
                      fill) -> jnp.ndarray:
    """Spread compacted per-lane responses back onto the holed request
    slots: ``out[j] = rows_c[rank(j)]`` where ``valid[j]``, else ``fill``.
    ``rows_c``: (m, ...) compacted at the front; ``valid``: (m,)."""
    rank = jnp.clip(jnp.cumsum(valid) - 1, 0, valid.shape[0] - 1)
    out = rows_c[rank]
    shape = valid.shape + (1,) * (rows_c.ndim - 1)
    return jnp.where(valid.reshape(shape), out, fill)


# --------------------------------------------------------------------------- #
# fetchV response rows: degree stream + flat delta id stream
# --------------------------------------------------------------------------- #
def encode_rows(rows: jnp.ndarray, valid: jnp.ndarray, sentinel: int,
                degs_cap: int, ids_cap: int):
    """One lane of adjacency windows ``rows (m, D)`` (sorted, sentinel
    padded; only ``valid`` rows coded, compacted to the front).

    Returns ``(degs_stream, degs_len, ids_stream, ids_len, raw, overflow)``.
    The raw escape stores the padded int32 rows in the id stream (degree
    stream empty)."""
    m, D = rows.shape
    deg = jnp.where(valid, (rows < sentinel).sum(-1), 0).astype(_I32)
    dvl = jnp.where(valid, varint_size(deg), 0)
    degs_s, degs_total = _write_varints(deg, dvl, degs_cap)

    col = jnp.arange(D)
    prev = jnp.concatenate([jnp.zeros((m, 1), _I32), rows[:, :-1]], axis=1)
    ok = valid[:, None] & (col[None, :] < deg[:, None])
    dmat = jnp.where(col[None, :] == 0, rows, rows - prev)
    dmat = jnp.where(ok, jnp.maximum(dmat, 0), 0)
    vl = jnp.where(ok, varint_size(dmat), 0)
    ids_s, ids_total = _write_varints(dmat.reshape(-1), vl.reshape(-1),
                                      ids_cap)

    count = valid.sum()
    raw_len = 4 * D * count
    use_raw = ((degs_total + ids_total > raw_len) | (ids_total > ids_cap)
               | (degs_total > degs_cap))
    rank = jnp.cumsum(valid) - 1
    rpos = rank[:, None] * D + col[None, :]
    raw_s = _write_raw32(rows.reshape(-1), rpos.reshape(-1),
                         jnp.repeat(valid, D), ids_cap)
    ids_stream = jnp.where(use_raw, raw_s, ids_s)
    degs_stream = jnp.where(use_raw, jnp.zeros_like(degs_s), degs_s)
    ids_len = jnp.where(use_raw, raw_len, ids_total).astype(_I32)
    degs_len = jnp.where(use_raw, 0, degs_total).astype(_I32)
    overflow = (ids_len > ids_cap) | (degs_len > degs_cap)
    return degs_stream, degs_len, ids_stream, ids_len, use_raw, overflow


def decode_rows(degs_s, degs_len, ids_s, ids_len, raw, m: int, D: int,
                sentinel: int) -> jnp.ndarray:
    """Inverse of :func:`encode_rows`: ``(m, D)`` windows, compacted at the
    front, sorted-then-sentinel exactly as ``DeviceGraph.rows_at`` emits."""
    degs, count_c = _parse_varints(degs_s, degs_len, m)
    rstart = jnp.cumsum(degs) - degs
    flat, _ = _parse_varints(ids_s, ids_len, m * D)
    col = jnp.arange(D)
    f = rstart[:, None] + col[None, :]
    dmat = flat[jnp.clip(f, 0, m * D - 1)]
    ok = (col[None, :] < degs[:, None]) & (jnp.arange(m)[:, None] < count_c)
    rows_c = jnp.cumsum(jnp.where(ok, dmat, 0), axis=1)
    rows_c = jnp.where(ok, rows_c, sentinel)

    count_r = ids_len // (4 * D)
    rpos = jnp.arange(m)[:, None] * D + col[None, :]
    rows_r = _read_raw32(ids_s, rpos)
    rows_r = jnp.where(jnp.arange(m)[:, None] < count_r, rows_r, sentinel)
    return jnp.where(raw, rows_r, rows_c)


# --------------------------------------------------------------------------- #
# verifyE pairs: Elias-Fano `a` column + run-delta varint `b` column
# --------------------------------------------------------------------------- #
def _bitlen(x) -> jnp.ndarray:
    """Integer bit length (floor(log2(x)) + 1 for x > 0; 0 for x <= 0) —
    pure integer compares, so encoder and decoder always agree."""
    x = jnp.asarray(x, _I32)
    out = jnp.zeros(jnp.shape(x), _I32)
    for k in range(31):
        out = out + (x >= (1 << k)).astype(_I32)
    return out


def _ef_lowbits(universe: int, count) -> jnp.ndarray:
    """EF low-bit width ~ floor(log2(universe / count)), integerized."""
    return jnp.clip(_bitlen(universe) - _bitlen(jnp.maximum(count, 1)),
                    0, 30)


def _set_bits(stream, bitpos, bit, valid, cap: int):
    """Scatter single bits (each position written at most once)."""
    byte = bitpos >> 3
    val = (bit.astype(_I32) << (bitpos & 7)).astype(_U8)
    sel = valid & (bit > 0)
    return stream.at[jnp.where(sel, byte, cap)].add(val, mode="drop")


def _get_bit(stream, bitpos):
    cap = stream.shape[0]
    return (stream[jnp.clip(bitpos >> 3, 0, cap - 1)].astype(_I32)
            >> (bitpos & 7)) & 1


def encode_pairs(a: jnp.ndarray, b: jnp.ndarray, universe: int,
                 a_cap: int, b_cap: int):
    """One verifyE lane: pairs valid-at-the-front (fill = ``universe``),
    ``a`` non-decreasing, ``b`` ascending inside equal-``a`` runs.

    Returns ``(a_stream, a_len, b_stream, b_len, raw, overflow)``; the
    pair count is control-plane metadata (the exchange's ``counts``)."""
    m = a.shape[0]
    idx = jnp.arange(m)
    valid = a < universe
    count = valid.sum()
    l = _ef_lowbits(universe, count)

    # -- a column: Elias-Fano (low bits packed, high bits unary) ------------ #
    a_s = jnp.zeros((a_cap,), _U8)
    av = jnp.where(valid, a, 0).astype(_I32)
    for j in range(31):
        a_s = _set_bits(a_s, idx * l + j, (av >> j) & 1,
                        valid & (j < l), a_cap)
    high = av >> l
    a_s = _set_bits(a_s, count * l + high + idx, jnp.ones((m,), _I32),
                    valid, a_cap)
    last_high = jnp.max(jnp.where(valid, high, -1))
    a_bits = count * l + jnp.where(count > 0, last_high + count, 0)
    a_total = (a_bits + 7) // 8

    # -- b column: varint, absolute at run starts, delta inside runs -------- #
    prev_a = jnp.concatenate([jnp.full((1,), -1, _I32), a[:-1]])
    prev_b = jnp.concatenate([jnp.zeros((1,), _I32), b[:-1]])
    new_run = a != prev_a
    bv = jnp.where(valid,
                   jnp.where(new_run, b, jnp.maximum(b - prev_b, 0)), 0)
    bvl = jnp.where(valid, varint_size(bv), 0)
    b_s, b_total = _write_varints(bv, bvl, b_cap)

    raw_len = 4 * count
    use_raw = ((a_total + b_total > 2 * raw_len) | (a_total > a_cap)
               | (b_total > b_cap))
    a_raw = _write_raw32(a, idx, valid, a_cap)
    b_raw = _write_raw32(b, idx, valid, b_cap)
    a_stream = jnp.where(use_raw, a_raw, a_s)
    b_stream = jnp.where(use_raw, b_raw, b_s)
    a_len = jnp.where(use_raw, raw_len, a_total).astype(_I32)
    b_len = jnp.where(use_raw, raw_len, b_total).astype(_I32)
    overflow = (a_len > a_cap) | (b_len > b_cap)
    return a_stream, a_len, b_stream, b_len, use_raw, overflow


def decode_pairs(a_s, a_len, b_s, b_len, raw, count, m_out: int,
                 universe: int, sentinel: int):
    """Inverse of :func:`encode_pairs`. Returns ``(a, b, mask)`` with the
    pairs valid-at-the-front and ``sentinel`` fill — positionally identical
    to the raw request buffers."""
    del a_len  # EF is sized by (universe, count); raw by count
    idx = jnp.arange(m_out)
    l = _ef_lowbits(universe, count)

    # -- a: EF decode ------------------------------------------------------- #
    low = jnp.zeros((m_out,), _I32)
    for j in range(31):
        low = low | jnp.where(j < l, _get_bit(a_s, idx * l + j) << j, 0)
    nbits = a_s.shape[0] * 8
    bidx = jnp.arange(nbits)
    bits = ((a_s[bidx >> 3].astype(_I32) >> (bidx & 7)) & 1)
    in_high = (bidx >= count * l) & (bits > 0)
    r = jnp.cumsum(in_high.astype(_I32)) - in_high.astype(_I32)
    h = bidx - count * l - r
    highs = jnp.zeros((m_out,), _I32).at[
        jnp.where(in_high, r, m_out)].set(h, mode="drop")
    a_c = (highs << l) | low

    # -- b: varint + segmented cumsum over equal-a runs --------------------- #
    bv, _ = _parse_varints(b_s, b_len, m_out)
    prev_a = jnp.concatenate([jnp.full((1,), -1, _I32), a_c[:-1]])
    new_run = a_c != prev_a
    c0 = jnp.cumsum(bv)
    sidx = jax.lax.cummax(jnp.where(new_run, idx, -1))
    c_before = jnp.where(sidx > 0, c0[jnp.clip(sidx - 1, 0, m_out - 1)], 0)
    b_c = c0 - c_before

    a_r = _read_raw32(a_s, idx)
    b_r = _read_raw32(b_s, idx)
    mask = idx < count
    a_out = jnp.where(mask, jnp.where(raw, a_r, a_c), sentinel)
    b_out = jnp.where(mask, jnp.where(raw, b_r, b_c), sentinel)
    return a_out, b_out, mask


# --------------------------------------------------------------------------- #
# verifyE answers: bit-packed bools
# --------------------------------------------------------------------------- #
def pack_bools(bits: jnp.ndarray, count, cap: int):
    """(m,) bools -> bit stream of the first ``count`` entries.
    Returns (stream (cap,) u8, length () = ceil(count/8))."""
    m = bits.shape[0]
    idx = jnp.arange(m)
    sel = bits & (idx < count)
    stream = jnp.zeros((cap,), _U8).at[
        jnp.where(sel, idx >> 3, cap)].add(
        (sel.astype(_I32) << (idx & 7)).astype(_U8), mode="drop")
    return stream, ((count + 7) // 8).astype(_I32)


def unpack_bools(stream: jnp.ndarray, count, m_out: int) -> jnp.ndarray:
    """Inverse of :func:`pack_bools` (False past ``count``)."""
    idx = jnp.arange(m_out)
    return (_get_bit(stream, idx) > 0) & (idx < count)


# --------------------------------------------------------------------------- #
# Lane-grid wrappers (ndev, peer, ...) — what the engine stages call
# --------------------------------------------------------------------------- #
def encode_ids_lanes(wire: jnp.ndarray, sentinel: int, cap: int,
                     use_pallas: bool = False, interpret: bool = True):
    """``wire`` (ndev, peer, m): per-lane :func:`encode_ids`, with the
    delta/varint-size pass batched over all lanes (Pallas fast path).

    Also returns the per-lane PR 4 *modeled* byte matrix (varints capped
    at 4 B — ``engine._varint_id_bytes`` semantics) reusing the same
    sizing pass, so the jitted fetch stage never sizes the lanes twice."""
    ndev, p, m = wire.shape
    flat = wire.reshape(-1, m)
    delta, vlen = delta_vlen(flat, sentinel, use_kernel=use_pallas,
                             interpret=interpret)
    s, ln, rw, ov = jax.vmap(
        lambda i, d, v: _encode_ids_core(i, d, v, cap))(flat, delta, vlen)
    model = jnp.minimum(vlen, 4).sum(-1).reshape(ndev, p)
    return (s.reshape(ndev, p, cap), ln.reshape(ndev, p),
            rw.reshape(ndev, p), ov.any(), model)


def decode_ids_lanes(stream, length, raw, m_out: int, sentinel: int):
    return jax.vmap(jax.vmap(
        lambda s, ln, r: decode_ids(s, ln, r, m_out, sentinel)))(
        stream, length, raw)


def encode_rows_lanes(rows, valid, sentinel: int, degs_cap: int,
                      ids_cap: int):
    dg, dl, ids, il, rw, ov = jax.vmap(jax.vmap(
        lambda r, v: encode_rows(r, v, sentinel, degs_cap, ids_cap)))(
        rows, valid)
    return dg, dl, ids, il, rw, ov.any()


def decode_rows_lanes(degs_s, degs_len, ids_s, ids_len, raw, m: int,
                      D: int, sentinel: int):
    return jax.vmap(jax.vmap(
        lambda ds, dl, is_, il, r: decode_rows(ds, dl, is_, il, r, m, D,
                                               sentinel)))(
        degs_s, degs_len, ids_s, ids_len, raw)


def scatter_compacted_lanes(rows_c, valid, fill):
    return jax.vmap(jax.vmap(
        lambda r, v: scatter_compacted(r, v, fill)))(rows_c, valid)


def encode_pairs_lanes(a, b, universe: int, a_cap: int, b_cap: int):
    a_s, al, b_s, bl, rw, ov = jax.vmap(jax.vmap(
        lambda x, y: encode_pairs(x, y, universe, a_cap, b_cap)))(a, b)
    return a_s, al, b_s, bl, rw, ov.any()


def decode_pairs_lanes(a_s, a_len, b_s, b_len, raw, count, m_out: int,
                       universe: int, sentinel: int):
    return jax.vmap(jax.vmap(
        lambda as_, al, bs, bl, r, c: decode_pairs(
            as_, al, bs, bl, r, c, m_out, universe, sentinel)))(
        a_s, a_len, b_s, b_len, raw, count)


def pack_bools_lanes(bits, count, cap: int):
    return jax.vmap(jax.vmap(lambda b, c: pack_bools(b, c, cap)))(
        bits, count)


def unpack_bools_lanes(stream, count, m_out: int):
    return jax.vmap(jax.vmap(
        lambda s, c: unpack_bools(s, c, m_out)))(stream, count)


# --------------------------------------------------------------------------- #
# Measured wire-format auto-selection (EngineConfig.wire_format="auto")
# --------------------------------------------------------------------------- #
def resolve_wire_format(requested: str, mode: str, prior: dict | None = None,
                        hysteresis: float = 0.05) -> tuple[str, str]:
    """Resolve ``wire_format="auto"`` to a concrete codec for this run.

    The driver persists one *trial* per ``(exchange mode, format)`` into
    the priors entry (``wire_trials[f"{mode}:{fmt}"] = {"pipeline_s": ...,
    "wire_bytes": ...}``, compile time already subtracted) — the measured
    bytes-vs-wall tradeoff the CPU sim needs to stop paying 3x wall for
    compression whose bytes are free intra-process.  Resolution:

    * both formats measured -> the lower ``pipeline_s`` wins, with
      ``hysteresis`` sticking to the previously recorded choice unless the
      challenger is more than that fraction faster (a stable choice keeps
      warm runs on already-persisted executables — flapping would re-trace
      fetch/verify every run);
    * one format measured -> *explore* the other (deterministic, so two
      runs complete the table);
    * nothing measured -> heuristic: transports whose bytes cost real
      time (``spmd`` collectives, ``dist`` across process boundaries)
      default to ``varint``, the intra-process reference backends to
      ``raw`` (their bytes are free, codec compute is not).

    Returns ``(format, reason)`` with reason in ``{"explicit", "measured",
    "explore", "heuristic"}`` — the driver reports it as
    ``stats["wire_auto_reason"]``."""
    if requested != "auto":
        return requested, "explicit"
    trials = (prior or {}).get("wire_trials", {})
    t = {f: trials.get(f"{mode}:{f}") for f in ("raw", "varint")}
    have = [f for f in ("raw", "varint") if t[f]]
    if len(have) == 2:
        best = min(("raw", "varint"), key=lambda f: t[f]["pipeline_s"])
        prev = (prior or {}).get("wire_choice", {}).get(mode)
        if prev in ("raw", "varint") and best != prev \
                and t[best]["pipeline_s"] >= (1.0 - hysteresis) \
                * t[prev]["pipeline_s"]:
            best = prev
        return best, "measured"
    if len(have) == 1:
        return ("varint" if have[0] == "raw" else "raw"), "explore"
    return ("varint" if mode in ("spmd", "dist") else "raw"), "heuristic"


def register_wire_metrics(reg, chosen: str, requested: str,
                          reason: str) -> None:
    """Set the wire-codec instruments on a stats registry (declared in
    :mod:`repro.obs.schema`): the format actually on the wire
    (``wire_format``), what the config asked for
    (``wire_format_requested``), why auto-selection picked it
    (``wire_auto_reason``), and the modeled compressed-fetch baseline
    accumulator (``bytes_fetch_compressed``) the per-wave stats add into."""
    reg["wire_format"] = chosen
    reg["wire_format_requested"] = requested
    reg["wire_auto_reason"] = reason
    reg["bytes_fetch_compressed"] = 0.0
