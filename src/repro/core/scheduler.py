"""Asynchronous region-group wave scheduler (the RADS pipeline driver).

The engine exposes each R-Meef unit as three separately-jittable stages
over an immutable :class:`~repro.core.engine.WaveState`:

    fetch_stage  -> expand_stage -> verify_stage        (one unit)

This module pipelines those stages across *region-group waves*.  JAX's
async dispatch means a jitted stage call returns immediately with futures;
the scheduler therefore keeps up to ``EngineConfig.pipeline_depth`` waves
in flight and interleaves their stage dispatches oldest-first, blocking
(the only ``jax.block_until_ready``-style sync point) solely when the
oldest wave is retired.  With ``pipeline_depth=2`` (double buffering) the timeline is::

    wave k   : fetchV[u0] expand[u0] verifyE[u0] fetchV[u1] ...  ──┐ retire k
    wave k+1 :     fetchV[u0]  expand[u0]  verifyE[u0]     ...  ───┼────┐
    wave k+2 :                         (admitted when k retires)  ─┘    │ ...
               ── device queue: stages execute in dispatch order ──────────►

i.e. while wave ``k`` is still executing its ``verify_stage``, wave
``k+1``'s ``fetch_stage`` is already dispatched — the paper's asynchronous
region-group processing (§3, §6) without host threads.  ``pipeline_depth=1``
degrades to the old synchronous driver loop (one wave at a time).

The scheduler also owns the robustness mechanisms that used to live in the
driver's ``run_batches``:

* **overflow split** (§6 memory control): an incomplete wave is halved and
  both halves re-queued (LIFO, so sub-waves finish before new groups start);
* **capacity escalation**: a single-seed wave that still overflows doubles
  the engine capacities and re-jits the stages (elastic capacities —
  enumeration never silently drops results);
* **steal-from-longest** (the paper's checkR/shareR): when a device's group
  queue drains before its peers', the next wave refills its slot from the
  tail of the longest surviving queue;
* **per-seed cost calibration**: trie-node counts are accumulated as a
  *running mean over every completed wave* (not the last batch), feeding
  the region-group budget of the distributed phase;
* **per-wave timing / byte stats** so benchmarks can report overlap
  efficiency (``wave_s_total`` vs ``*_pipeline_s`` wall time);
* **adaptive pipeline depth** (``EngineConfig.pipeline_depth="auto"``):
  the achieved concurrency ``Σ wave latency / wall`` steers the in-flight
  limit up when the pipeline saturates and back down when waves stop
  overlapping — a pure host-side scheduling decision, never a recompile.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from dataclasses import dataclass, field

import jax
import numpy as np

from repro.configs.rads import EngineConfig
from repro.core.cache import AdjCache, build_cache
from repro.core.engine import (PlanData, WaveState, expand_stage,
                               fetch_stage, finalize_wave, init_wave,
                               verify_stage)
from repro.core.exchange import ExchangeBackend
from repro.graph.storage import DeviceGraph

_MAX_CAP = 1 << 22
_AUTO_START_DEPTH = 2       # pipeline_depth="auto" begins double-buffered
_MAX_AUTO_DEPTH = 8


def _pad_seeds(seeds_per_dev: list[np.ndarray], ndev: int, scap: int,
               sentinel: int) -> tuple[np.ndarray, np.ndarray]:
    out = np.full((ndev, scap), sentinel, dtype=np.int32)
    mask = np.zeros((ndev, scap), dtype=bool)
    for t, s in enumerate(seeds_per_dev):
        k = min(len(s), scap)
        out[t, :k] = s[:k]
        mask[t, :k] = True
    return out, mask


# --------------------------------------------------------------------------- #
# GroupQueue: one device's FIFO of region groups, optionally lazily formed
# --------------------------------------------------------------------------- #
class GroupQueue:
    """Per-device queue of region groups.

    Backed by either a pre-formed list or a *lazy* group generator (see
    :func:`repro.core.region.iter_region_groups`): with a lazy source the
    Python-side group formation of wave ``k+1`` runs while wave ``k``
    computes on the device — grouping cost is hidden inside the pipeline.

    ``seeds_left`` (pre-formed + an estimate of unformed seeds) is the
    steal-from-longest load metric."""

    def __init__(self, groups=(), lazy=None, n_lazy_seeds: int = 0):
        self._buf: deque[np.ndarray] = deque(groups)
        self._lazy = lazy
        self._lazy_left = int(n_lazy_seeds) if lazy is not None else 0
        self.n_formed = len(self._buf)

    @property
    def seeds_left(self) -> int:
        return sum(len(g) for g in self._buf) + self._lazy_left

    def __bool__(self) -> bool:
        return self.seeds_left > 0

    def _form(self) -> np.ndarray | None:
        if self._lazy is None:
            return None
        g = next(self._lazy, None)
        if g is None:
            self._lazy_left = 0
            return None
        self._lazy_left = max(0, self._lazy_left - len(g))
        self.n_formed += 1
        return g

    def pop_head(self) -> np.ndarray | None:
        if self._buf:
            return self._buf.popleft()
        return self._form()

    def pop_tail(self) -> np.ndarray | None:
        """Steal entry point: take buffered work from the tail, else form
        the victim's next group."""
        if self._buf:
            return self._buf.pop()
        return self._form()


# --------------------------------------------------------------------------- #
# StageRunner: the jitted per-unit stage functions
# --------------------------------------------------------------------------- #
class StageRunner:
    """Holds the on-device graph (any registered ``DeviceGraph`` format)
    plus a lazily-built cache of jitted stage functions keyed by
    ``(stage, unit, local_only)``; capacity escalation doubles the engine
    caps and clears the jit cache (re-jit).  The graph travels through the
    jitted stages as a pytree argument, so sharded (spmd) and device-local
    formats use the same code path.

    The runner also *owns* the foreign-adjacency cache state
    (:class:`~repro.core.cache.AdjCache`): every dispatched ``fetch_stage``
    consumes ``self.cache`` and replaces it with the post-admission state
    (futures — JAX async keeps the host loop non-blocking), sequencing the
    cache through fetches in dispatch order across waves *and* across the
    capacity-escalation re-jits (cache geometry is independent of the
    engine capacities, so escalation re-traces the stages around the same
    cache arrays).  Pass ``cache=`` explicitly to share or shard a
    prebuilt cache (the spmd driver does); the default builds one from
    ``cfg`` (``None`` when disabled)."""

    def __init__(self, g: DeviceGraph, pd: PlanData,
                 cfg: EngineConfig, exch: ExchangeBackend,
                 cache: AdjCache | None | str = "auto"):
        self.g = g
        self.pd, self.exch = pd, exch
        self.cfg = cfg
        self.cache = build_cache(cfg, g) if cache == "auto" else cache
        self._fns: dict = {}

    @property
    def n_units(self) -> int:
        return len(self.pd.unit_steps)

    def escalate(self) -> bool:
        """Double every engine capacity (up to the ceiling) and re-jit.

        The wire-codec stream capacities (:mod:`repro.core.wire`) are
        derived from ``fetch_cap``/``verify_cap`` inside the stages, so
        they escalate — and re-jit — alongside the engine caps; the cache
        geometry alone stays fixed."""
        c = self.cfg
        if c.frontier_cap >= _MAX_CAP:
            return False
        self.cfg = dataclasses.replace(
            c, frontier_cap=min(c.frontier_cap * 2, _MAX_CAP),
            fetch_cap=min(c.fetch_cap * 2, _MAX_CAP),
            verify_cap=min(c.verify_cap * 2, _MAX_CAP))
        self._fns.clear()
        return True

    def _get(self, key, make):
        fn = self._fns.get(key)
        if fn is None:
            fn = self._fns[key] = make()
        return fn

    def init(self, seeds: np.ndarray, mask: np.ndarray) -> WaveState:
        fn = self._get("init", lambda: jax.jit(
            lambda gg, s, m: init_wave(gg, s, m)))
        return fn(self.g, seeds, mask)

    def fetch(self, ui: int, state: WaveState, local_only: bool):
        if local_only:                       # SM-E: no collectives at all
            return state, None
        pd, cfg, exch = self.pd, self.cfg, self.exch
        # cache=None is a valid (empty) pytree argument, so one closure
        # serves both the cached and the uncached configuration
        fn = self._get(("fetch", ui), lambda: jax.jit(
            lambda gg, s, c: fetch_stage(gg, pd, cfg, exch, ui, s,
                                         False, c)))
        state, bufs, self.cache = fn(self.g, state, self.cache)
        return state, bufs

    def expand(self, ui: int, state: WaveState, bufs, local_only: bool):
        pd, cfg = self.pd, self.cfg
        fn = self._get(("expand", ui, local_only), lambda: jax.jit(
            lambda gg, s, b: expand_stage(gg, pd, cfg, ui, s, b,
                                          local_only)))
        return fn(self.g, state, bufs)

    def verify(self, ui: int, state: WaveState, local_only: bool):
        pd, cfg, exch = self.pd, self.cfg, self.exch
        fn = self._get(("verify", ui, local_only), lambda: jax.jit(
            lambda gg, s: verify_stage(gg, pd, cfg, exch, ui, s,
                                       local_only)))
        return fn(self.g, state)


# --------------------------------------------------------------------------- #
# Pipeline scheduler
# --------------------------------------------------------------------------- #
@dataclass
class _Wave:
    """One in-flight region-group wave: host-side batches (for the split
    loop), the device-side state futures, and a stage cursor."""
    batches: list[np.ndarray]
    mask: np.ndarray
    state: WaveState
    stages: list[tuple[str, int]]
    pos: int = 0
    bufs: object = None
    t_start: float = field(default_factory=time.perf_counter)


class PipelineScheduler:
    """Drives region-group waves through the staged engine with up to
    ``cfg.pipeline_depth`` waves in flight (see module docstring)."""

    def __init__(self, runner: StageRunner, stats: dict, consume):
        self.runner = runner
        self.stats = stats
        self.consume = consume      # (rows, alive, counts, st, phase) -> None

    # -- wave formation ----------------------------------------------------- #
    def _next_wave(self, queues: list[GroupQueue], retry: list,
                   scap: int, local_only: bool):
        """Pop the next wave: retries first (LIFO — finish split sub-waves
        before admitting new groups), else one group per device queue with
        steal-from-longest refill; oversized batches are chunked to scap."""
        cfg = self.runner.cfg
        empty = np.array([], dtype=np.int64)
        while True:
            if retry:
                wave = retry.pop()
            elif any(queues):
                wave = [q.pop_head() if q else None for q in queues]
                wave = [empty if b is None else b for b in wave]
                # both knobs gate the checkR/shareR analogue: --no-steal
                # (enable_work_stealing) must disable the group-queue
                # rebalance too, or the ablation silently still steals
                if (cfg.enable_work_stealing and cfg.steal_from_longest
                        and not local_only):
                    for t, b in enumerate(wave):
                        if len(b) > 0:
                            continue
                        src = max(range(len(queues)),
                                  key=lambda u: queues[u].seeds_left)
                        if queues[src]:       # this device drained early:
                            stolen = queues[src].pop_tail()
                            if stolen is not None:
                                wave[t] = stolen
                                self.stats["steal_events"] += 1
            else:
                return None
            if max((len(b) for b in wave), default=0) == 0:
                continue
            if max(len(b) for b in wave) > scap:
                retry.append([b[scap:] for b in wave])
                wave = [b[:scap] for b in wave]
            return wave

    def _admit(self, wave: list[np.ndarray], scap: int) -> _Wave:
        g = self.runner.g
        seeds, mask = _pad_seeds(wave, g.ndev, scap, g.n)
        state = self.runner.init(seeds, mask)
        stages = [(kind, ui) for ui in range(self.runner.n_units)
                  for kind in ("fetch", "expand", "verify")]
        return _Wave(batches=wave, mask=mask, state=state, stages=stages)

    def _dispatch(self, w: _Wave, local_only: bool):
        kind, ui = w.stages[w.pos]
        if kind == "fetch":
            w.state, w.bufs = self.runner.fetch(ui, w.state, local_only)
        elif kind == "expand":
            w.state = self.runner.expand(ui, w.state, w.bufs, local_only)
            w.bufs = None
        else:
            w.state = self.runner.verify(ui, w.state, local_only)
        w.pos += 1

    # -- retire + robustness loop ------------------------------------------- #
    def _retire(self, w: _Wave, retry: list, phase: str
                ) -> tuple[float, int]:
        """Drain point: block on the wave's completeness flag; consume on
        success, split/escalate on overflow.  Returns (node_cost_sum, n)."""
        # One batched device->host transfer per retired wave — the pipeline's
        # only blocking sync.  A single device_get replaces the old scattered
        # reads (bool(complete), np.asarray(node_counts) here, then eight
        # scalar float() casts inside the driver's consume), each of which
        # was its own tiny blocking round-trip serializing the async
        # pipeline behind host latency (the bench's async <= sync signature).
        rows, alive, counts, complete, st = jax.device_get(
            finalize_wave(w.state))
        if not complete:
            if max(len(b) for b in w.batches) <= 1:
                if not self.runner.escalate():
                    raise RuntimeError("capacity ceiling reached")
                self.stats["cap_escalations"] += 1
                retry.append(w.batches)
            else:
                self.stats["overflow_retries"] += 1
                retry.append([b[len(b) // 2:] for b in w.batches])
                retry.append([b[:len(b) // 2] for b in w.batches])
            return 0.0, 0
        # per-real-seed trie-node counts (padding slots masked) — consumers
        # use these for the persisted node_counts histogram (priors v2)
        nc = st["node_counts"][w.mask]
        st["seed_node_counts"] = nc
        self.consume(rows, alive, counts, st, phase)
        self.stats["wave_s_total"] += time.perf_counter() - w.t_start
        return float(nc.sum()), int(nc.size)

    # -- main loop ----------------------------------------------------------- #
    def run(self, queues, scap: int,
            local_only: bool, phase: str, depth=None,
            auto_start: int | None = None) -> float | None:
        """Process per-device group queues (GroupQueue instances or plain
        lists of seed arrays) until empty.  Returns the mean trie-node cost
        per completed seed (running mean over *all* waves).

        ``depth`` overrides ``cfg.pipeline_depth`` — it is a host-side
        scheduling knob only (no recompilation), which lets benchmarks time
        sync (1) vs async (>=2) on the same warm jitted stages.

        ``pipeline_depth="auto"`` (or ``depth="auto"``) picks the depth from
        the per-wave timing stats the scheduler already collects: the ratio
        ``Σ wave latency / pipeline wall`` is the concurrency the pipeline
        *achieved*.  When it saturates the current depth the limit rises
        (up to ``_MAX_AUTO_DEPTH``); when waves stop overlapping (uniform
        runtimes, single surviving queue) it falls back toward synchronous —
        all host-side, so adaptation never recompiles a stage.
        ``auto_start`` seeds the adaptive depth (the priors cache passes the
        depth a previous run on the same workload converged to)."""
        if depth is None:
            depth = self.runner.cfg.pipeline_depth
        auto = depth == "auto"               # the "auto" setting
        if auto:
            depth = int(auto_start) if auto_start else _AUTO_START_DEPTH
            depth = max(1, min(depth, _MAX_AUTO_DEPTH))
        else:
            depth = max(1, int(depth))
        queues = [q if isinstance(q, GroupQueue) else GroupQueue(q)
                  for q in queues]
        retry: list[list[np.ndarray]] = []
        inflight: deque[_Wave] = deque()
        cost_sum, cost_n = 0.0, 0
        waves_done, wave_s_phase = 0, 0.0
        t0 = time.perf_counter()
        while True:
            # 1. advance every in-flight wave one stage, oldest first — this
            #    enqueues fetchV of wave k+1 behind (not after!) verifyE of
            #    wave k on the device stream, and crucially keeps the device
            #    fed *before* any slow host-side work below.
            for w in tuple(inflight):
                if w.pos < len(w.stages):
                    self._dispatch(w, local_only)
            # 2. top up the pipeline with at most ONE wave per tick; its
            #    first stage dispatches immediately.  Lazy group formation
            #    (the expensive Algorithm-3 Python loop) therefore overlaps
            #    the already-dispatched compute of the older waves.
            if len(inflight) < depth:
                wave = self._next_wave(queues, retry, scap, local_only)
                if wave is not None:
                    w = self._admit(wave, scap)
                    inflight.append(w)
                    self._dispatch(w, local_only)
                    self.stats["n_waves"] += 1
                    self.stats["max_inflight_waves"] = max(
                        self.stats["max_inflight_waves"], len(inflight))
            if not inflight:
                break
            # 3. retire the oldest wave once fully dispatched
            if inflight[0].pos >= len(inflight[0].stages):
                # NOTE: if retiring escalates capacities, a younger in-flight
                # wave keeps its already-dispatched old-capacity futures but
                # its *remaining* stages re-jit at the new capacities — a
                # mixed-capacity wave is still exact (overflow is monotone
                # and re-checked at its own retire).
                oldest = inflight.popleft()
                s, n = self._retire(oldest, retry, phase)
                cost_sum += s
                cost_n += n
                waves_done += 1
                wave_s_phase += time.perf_counter() - oldest.t_start
                if auto and waves_done >= 2:
                    wall = max(time.perf_counter() - t0, 1e-9)
                    achieved = wave_s_phase / wall   # mean in-flight waves
                    if achieved >= depth - 0.5 and depth < _MAX_AUTO_DEPTH:
                        depth += 1
                    elif achieved < depth - 1.25 and depth > 1:
                        depth -= 1
                    self.stats["auto_depth"] = depth
        if auto:
            self.stats["auto_depth"] = depth     # persisted via priors v2
        self.stats[f"{phase}_pipeline_s"] = (
            self.stats.get(f"{phase}_pipeline_s", 0.0)
            + time.perf_counter() - t0)
        return cost_sum / cost_n if cost_n else None
