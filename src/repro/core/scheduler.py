"""Asynchronous region-group wave scheduler (the RADS pipeline driver).

The engine exposes each R-Meef unit as three separately-jittable stages
over an immutable :class:`~repro.core.engine.WaveState`:

    fetch_stage  -> expand_stage -> verify_stage        (one unit)

This module pipelines those stages across *region-group waves*.  JAX's
async dispatch means a compiled stage call returns immediately with
futures; the scheduler therefore keeps up to ``EngineConfig.pipeline_depth``
waves in flight, dispatches each wave's stages **contiguously** (stages +
a jitted ``finalize_wave`` back-to-back on the device stream), and blocks
(the only sync point) solely on the single ``device_get`` that retires the
oldest wave.  With ``pipeline_depth=2`` (double buffering) the timeline is::

    wave k   : fetchV[u0] expand[u0] verifyE[u0] fetchV[u1] ...  ──┐ retire k
    wave k+1 :     fetchV[u0]  expand[u0]  verifyE[u0]     ...  ───┼────┐
    wave k+2 :                         (admitted when k retires)  ─┘    │ ...
               ── device queue: stages execute in dispatch order ──────────►

i.e. while wave ``k`` is still executing its ``verify_stage``, wave
``k+1``'s ``fetch_stage`` is already dispatched — the paper's asynchronous
region-group processing (§3, §6) without host threads.  ``pipeline_depth=1``
degrades to the old synchronous driver loop (one wave at a time).

The scheduler also owns the robustness mechanisms that used to live in the
driver's ``run_batches``:

* **overflow split** (§6 memory control): an incomplete wave is halved and
  both halves re-queued (LIFO, so sub-waves finish before new groups start);
* **capacity escalation**: a single-seed wave that still overflows doubles
  the engine capacities and re-resolves the stages (elastic capacities —
  enumeration never silently drops results; against a warm executable
  store the re-resolve is deserialization, not recompilation);
* **AOT stage resolution + persistent executable cache**: stages are
  compiled explicitly (``.lower().compile()``) through a two-level cache —
  in-process slots, then the on-disk
  :class:`~repro.runtime.compile_cache.StageExecCache` — with a background
  pre-warm of the whole ladder, so a warm server performs **zero**
  traces/compiles (``stats["compiles"] == 0``) and cold compiles move off
  the critical path;
* **steal-from-longest** (the paper's checkR/shareR): when a device's group
  queue drains before its peers', the next wave refills its slot from the
  tail of the longest surviving queue;
* **per-seed cost calibration**: trie-node counts are accumulated as a
  *running mean over every completed wave* (not the last batch), feeding
  the region-group budget of the distributed phase;
* **per-wave timing / byte stats** so benchmarks can report overlap
  efficiency (``wave_s_total`` vs ``*_pipeline_s`` wall time);
* **wave-level tracing** (:mod:`repro.obs`): with a
  :class:`~repro.obs.trace.TraceRecorder` injected, every admission /
  stage dispatch / finalize / retire records a span on a per-wave lane
  with a dispatch->retire flow arrow, and steals / splits / escalations
  become instants — all guarded by ``tracer.enabled`` so the default
  (:data:`~repro.obs.trace.NULL_TRACER`) path runs zero instrumentation;
* **adaptive pipeline depth** (``EngineConfig.pipeline_depth="auto"``):
  the achieved concurrency ``Σ wave latency / wall`` steers the in-flight
  limit up when the pipeline saturates and back down when waves stop
  overlapping — a pure host-side scheduling decision, never a recompile.
"""
from __future__ import annotations

import dataclasses
import threading
import time
import warnings
from collections import deque
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.rads import EngineConfig
from repro.core.cache import AdjCache, build_cache
from repro.core.engine import (PlanData, WaveState, expand_stage,
                               fetch_stage, finalize_wave, init_wave,
                               verify_stage)
from repro.core.exchange import ExchangeBackend
from repro.graph.storage import DeviceGraph
from repro.obs.trace import (NULL_TRACER, TRACK_PREWARM, TRACK_RETIRE,
                             TRACK_SCHED, TRACK_WAVE0, now_us)
from repro.runtime.compile_cache import (arg_signature, build_exec_cache,
                                         stage_context)

_MAX_CAP = 1 << 22
_AUTO_START_DEPTH = 2       # pipeline_depth="auto" begins double-buffered
_MAX_AUTO_DEPTH = 8


def _pad_seeds(seeds_per_dev: list[np.ndarray], ndev: int, scap: int,
               sentinel: int) -> tuple[np.ndarray, np.ndarray]:
    out = np.full((ndev, scap), sentinel, dtype=np.int32)
    mask = np.zeros((ndev, scap), dtype=bool)
    for t, s in enumerate(seeds_per_dev):
        k = min(len(s), scap)
        out[t, :k] = s[:k]
        mask[t, :k] = True
    return out, mask


# --------------------------------------------------------------------------- #
# GroupQueue: one device's FIFO of region groups, optionally lazily formed
# --------------------------------------------------------------------------- #
class GroupQueue:
    """Per-device queue of region groups.

    Backed by either a pre-formed list or a *lazy* group generator (see
    :func:`repro.core.region.iter_region_groups`): with a lazy source the
    Python-side group formation of wave ``k+1`` runs while wave ``k``
    computes on the device — grouping cost is hidden inside the pipeline.

    ``seeds_left`` (pre-formed + an estimate of unformed seeds) is the
    steal-from-longest load metric."""

    def __init__(self, groups=(), lazy=None, n_lazy_seeds: int = 0):
        self._buf: deque[np.ndarray] = deque(groups)
        self._lazy = lazy
        self._lazy_left = int(n_lazy_seeds) if lazy is not None else 0
        self.n_formed = len(self._buf)

    @property
    def seeds_left(self) -> int:
        return sum(len(g) for g in self._buf) + self._lazy_left

    def __bool__(self) -> bool:
        return self.seeds_left > 0

    def _form(self) -> np.ndarray | None:
        if self._lazy is None:
            return None
        g = next(self._lazy, None)
        if g is None:
            self._lazy_left = 0
            return None
        self._lazy_left = max(0, self._lazy_left - len(g))
        self.n_formed += 1
        return g

    def pop_head(self) -> np.ndarray | None:
        if self._buf:
            return self._buf.popleft()
        return self._form()

    def pop_tail(self) -> np.ndarray | None:
        """Steal entry point: take buffered work from the tail, else form
        the victim's next group."""
        if self._buf:
            return self._buf.pop()
        return self._form()


# --------------------------------------------------------------------------- #
# StageRunner: the jitted per-unit stage functions
# --------------------------------------------------------------------------- #
class StageRunner:
    """Holds the on-device graph (any registered ``DeviceGraph`` format)
    plus a two-level cache of **AOT-compiled** stage executables:

    1. an in-process slot table keyed ``(stage key, argument signature)``
       — the classic jit cache, now holding ``jax.stages.Compiled``
       objects resolved via ``jax.jit(...).lower(*args).compile()``;
    2. the optional persistent per-host store
       (:class:`~repro.runtime.compile_cache.StageExecCache`, enabled by
       ``EngineConfig.compile_cache_dir``) consulted on every slot miss
       *before* tracing — a populated store makes a whole run compile-free.

    Because stages are compiled explicitly, the runner knows exactly when
    XLA work happened: ``compiles``/``compile_s`` count actual stage
    compilations (a warm run must end with ``compiles == 0``) and
    ``take_hits()`` drains the number of resolutions served from the
    persistent store; the scheduler threads that count into the wave's
    jitted ``finalize_wave`` as ``exec_hits`` so it reaches the driver
    stats through the normal single retire ``device_get``.

    ``prewarm``/``prewarm_async`` resolve the full stage ladder for a seed
    capacity from *abstract* ``jax.eval_shape`` values — a background
    pre-warm moves compilation (or store deserialization) off the critical
    path while host-side group formation runs.  Resolution is thread-safe:
    concurrent resolvers of one slot rendezvous on an event instead of
    compiling twice, and ``escalate`` bumps a generation counter so a
    stale pre-warm resolution is never installed over the new capacities.

    Capacity escalation doubles the engine caps; slots are keyed by the
    capacities they were traced at, so the table survives escalation —
    old-rung entries keep serving in-flight waves, and the rung above the
    priors caps can be pre-warmed ahead of time
    (``prewarm(..., escalation_rungs=1)``) so an overflow run never
    compiles on the critical path.  The graph travels through the compiled
    stages as a pytree argument, so sharded (spmd/dist) and device-local
    formats use the same code path.

    The runner also *owns* the foreign-adjacency cache state
    (:class:`~repro.core.cache.AdjCache`): every dispatched ``fetch_stage``
    consumes ``self.cache`` and replaces it with the post-admission state
    (futures — JAX async keeps the host loop non-blocking), sequencing the
    cache through fetches in dispatch order across waves *and* across the
    capacity-escalation re-resolves (cache geometry is independent of the
    engine capacities).  Pass ``cache=`` explicitly to share or shard a
    prebuilt cache (the spmd driver does); the default builds one from
    ``cfg`` (``None`` when disabled).  ``exec_cache`` follows the same
    convention: ``"auto"`` builds the store from ``cfg.compile_cache_dir``,
    an explicit instance shares one store across runners (the benchmark
    sweep does), ``None`` disables persistence."""

    def __init__(self, g: DeviceGraph, pd: PlanData,
                 cfg: EngineConfig, exch: ExchangeBackend,
                 cache: AdjCache | None | str = "auto",
                 exec_cache="auto", tracer=NULL_TRACER):
        self.g = g
        self.pd, self.exch = pd, exch
        self.cfg = cfg
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.cache = build_cache(cfg, g) if cache == "auto" else cache
        self.exec_cache = (build_exec_cache(cfg) if exec_cache == "auto"
                           else exec_cache)
        if exch.mode in ("spmd", "dist"):
            # a Compiled executable bakes its input *shardings*, which the
            # store key (treedef + shape/dtype signature) does not capture
            # and the abstract pre-warm path cannot reproduce — spmd/dist
            # resolve concretely (shardings taken from the live args) and
            # in-process only; see prewarm()
            self.exec_cache = None
        self.compiles = 0        # stage executables actually XLA-compiled
        self.compile_s = 0.0     # wall seconds spent lowering + compiling
        self._slots: dict = {}   # (key, caps, sig) -> Compiled | pending Event
        self._lock = threading.Lock()
        self._gen = 0            # bumped by escalate(): aborts in-flight
                                 # pre-warm walks of the old capacities
        self._hits_pending = 0.0  # store hits awaiting wave attribution
        self._plan_repr = repr(pd)
        self._prewarm_threads: list[threading.Thread] = []
        self._tl = threading.local()   # per-thread last-resolve source
                                       # ("slot" | "store" | "compile")

    @property
    def n_units(self) -> int:
        return len(self.pd.unit_steps)

    @staticmethod
    def _escalated(cfg: EngineConfig) -> EngineConfig:
        """One rung up the capacity ladder — the exact replacement
        ``escalate()`` applies, shared with the rung pre-warm so a warmed
        rung lands on the same slot keys a live escalation resolves."""
        return dataclasses.replace(
            cfg, frontier_cap=min(cfg.frontier_cap * 2, _MAX_CAP),
            fetch_cap=min(cfg.fetch_cap * 2, _MAX_CAP),
            verify_cap=min(cfg.verify_cap * 2, _MAX_CAP))

    def escalate(self) -> bool:
        """Double every engine capacity (up to the ceiling) and re-resolve.

        The wire-codec stream capacities (:mod:`repro.core.wire`) are
        derived from ``fetch_cap``/``verify_cap`` inside the stages, so
        they escalate — and re-resolve — alongside the engine caps; the
        cache geometry alone stays fixed.  The slot table is *kept*: slots
        are keyed by the capacities they were traced at, so entries for
        the old rung stay valid for in-flight waves and entries pre-warmed
        for the new rung (``prewarm(..., escalation_rungs=1)``) are found
        immediately — an escalation against a warmed rung resolves without
        compiling on the critical path."""
        c = self.cfg
        if c.frontier_cap >= _MAX_CAP:
            return False
        with self._lock:
            self.cfg = self._escalated(c)
            self._gen += 1
        return True

    # -- persistent-store hit accounting ------------------------------------ #
    def take_hits(self) -> float:
        """Drain the pending persistent-store hit count; the scheduler
        attributes it to the wave whose finalize is being dispatched."""
        with self._lock:
            h, self._hits_pending = self._hits_pending, 0.0
        return h

    def credit_hits(self, h: float) -> None:
        """Re-credit hits whose wave was discarded (overflow split /
        escalation) so the run total stays exact."""
        with self._lock:
            self._hits_pending += float(h)

    # -- stage resolution ---------------------------------------------------- #
    @staticmethod
    def _caps_key(key, cfg: EngineConfig) -> tuple:
        """The capacity-ladder component of a slot key.  ``init`` and
        ``finalize`` trace independently of the engine capacities (their
        shapes come entirely from the argument signature), so they key on
        ``()`` and survive escalations without re-resolving; every
        per-unit stage keys on the caps it closed over."""
        if key in ("init", "finalize"):
            return ()
        return (cfg.frontier_cap, cfg.fetch_cap, cfg.verify_cap)

    def _resolve(self, key, make, args, cfg: EngineConfig):
        """The stage executable for ``(key, caps(cfg), signature(args))``:
        in-process slot, else persistent store, else AOT trace + compile
        (counted).  ``cfg`` is the caller's snapshot — the closures in
        ``make`` and the slot key both use it, so a concurrent
        ``escalate`` can never mismatch a traced executable and its key.

        A second thread resolving an in-flight slot waits on the first
        instead of compiling twice.  Resolved slots are always installed:
        with capacities in the key a resolution is valid forever (an old
        rung's entry still serves in-flight waves; a pre-warmed higher
        rung's entry serves the escalation that reaches it)."""
        sig = arg_signature(args)
        skey = (key, self._caps_key(key, cfg), sig)
        while True:
            with self._lock:
                entry = self._slots.get(skey)
                if entry is None:
                    ev = threading.Event()
                    self._slots[skey] = ev
                    break
                if not isinstance(entry, threading.Event):
                    self._tl.last = "slot"
                    return entry
            entry.wait()
        fn = None
        tr = self.tracer
        t0_us = tr.now_us() if tr.enabled else 0.0
        source = "compile"
        try:
            ctx = digest = None
            if self.exec_cache is not None:
                ctx = stage_context(key, cfg, self.exch.mode,
                                    self._plan_repr)
                digest = self.exec_cache.digest(key, sig, ctx)
                fn = self.exec_cache.load(digest, sig, ctx)
                if fn is not None:
                    source = "store"
                    with self._lock:
                        self._hits_pending += 1.0
            if fn is None:
                t0 = time.perf_counter()
                fn = make().lower(*args).compile()
                dt = time.perf_counter() - t0
                with self._lock:
                    self.compiles += 1
                    self.compile_s += dt
                if self.exec_cache is not None:
                    self.exec_cache.store(digest, sig, ctx, fn)
            self._tl.last = source
            if tr.enabled:
                # resolve work (store deserialization or XLA compile) on the
                # prewarm lane when it ran on the background pre-warm thread,
                # else on the scheduler lane — all args are host scalars
                tid = (TRACK_PREWARM
                       if threading.current_thread().name
                       == "rads-stage-prewarm" else TRACK_SCHED)
                stage = key if isinstance(key, str) else ":".join(
                    str(k) for k in key)
                tr.complete(f"resolve:{stage}", tid, t0_us, source=source,
                            frontier_cap=cfg.frontier_cap)
            return fn
        finally:
            with self._lock:
                if fn is not None:
                    self._slots[skey] = fn
                elif self._slots.get(skey) is ev:
                    del self._slots[skey]
            ev.set()

    # the jax.jit(lambda ...) literals below are the stage call sites the
    # radslint call graph roots on — keep them literal
    def _make_init(self):
        return jax.jit(lambda gg, s, m: init_wave(gg, s, m))

    def _make_fetch(self, ui: int, cfg: EngineConfig):
        pd, exch = self.pd, self.exch
        # cache=None is a valid (empty) pytree argument, so one closure
        # serves both the cached and the uncached configuration
        return jax.jit(lambda gg, s, c: fetch_stage(gg, pd, cfg, exch, ui,
                                                    s, False, c))

    def _make_expand(self, ui: int, local_only: bool, cfg: EngineConfig):
        pd = self.pd
        return jax.jit(lambda gg, s, b: expand_stage(gg, pd, cfg, ui, s, b,
                                                     local_only))

    def _make_verify(self, ui: int, local_only: bool, cfg: EngineConfig):
        pd, exch = self.pd, self.exch
        return jax.jit(lambda gg, s: verify_stage(gg, pd, cfg, exch, ui, s,
                                                  local_only))

    def _make_finalize(self):
        if self.exch.mode == "dist":
            # multi-process retire: the single blocking device_get at
            # _retire can only read *addressable* shards, so the finalize
            # all-gathers its outputs to every process — each host then
            # holds the full (identical) result tuple, and the downstream
            # stat merge is a pure equality check
            shard = jax.sharding.NamedSharding(
                self.exch.mesh, jax.sharding.PartitionSpec())
            return jax.jit(lambda s, h: finalize_wave(s, h),
                           out_shardings=shard)
        return jax.jit(lambda s, h: finalize_wave(s, h))

    # -- stage dispatch ------------------------------------------------------ #
    def init(self, seeds: np.ndarray, mask: np.ndarray) -> WaveState:
        args = (self.g, seeds, mask)
        return self._resolve("init", self._make_init, args, self.cfg)(*args)

    def fetch(self, ui: int, state: WaveState, local_only: bool):
        if local_only:                       # SM-E: no collectives at all
            return state, None
        cfg = self.cfg
        args = (self.g, state, self.cache)
        fn = self._resolve(("fetch", ui),
                           lambda: self._make_fetch(ui, cfg), args, cfg)
        state, bufs, self.cache = fn(*args)
        return state, bufs

    def expand(self, ui: int, state: WaveState, bufs, local_only: bool):
        cfg = self.cfg
        args = (self.g, state, bufs)
        fn = self._resolve(("expand", ui, local_only),
                           lambda: self._make_expand(ui, local_only, cfg),
                           args, cfg)
        return fn(*args)

    def verify(self, ui: int, state: WaveState, local_only: bool):
        cfg = self.cfg
        args = (self.g, state)
        fn = self._resolve(("verify", ui, local_only),
                           lambda: self._make_verify(ui, local_only, cfg),
                           args, cfg)
        return fn(*args)

    def finalize(self, state: WaveState, exec_hits: float = 0.0):
        """Dispatch the jitted drain stage (``finalize_wave``) — the wave's
        classic result tuple as device futures, with the runner's
        persistent-store hit count riding along as a traced scalar."""
        args = (state, np.float32(exec_hits))
        fn = self._resolve("finalize", self._make_finalize, args, self.cfg)
        return fn(*args)

    # -- pre-warm ------------------------------------------------------------ #
    def _prewarm_ladder(self, scap: int, local_only: bool,
                        cfg: EngineConfig, gen: int) -> int:
        """Resolve the full stage ladder at ``cfg``'s capacities from
        abstract values; returns stages resolved, 0 if aborted by a
        concurrent escalation (the rung being warmed is still installed —
        slots key on their capacities — but further walking is pointless
        work the escalated run will redo at its own caps)."""
        g, pd, exch = self.g, self.pd, self.exch
        seeds = jax.ShapeDtypeStruct((g.ndev, scap), jnp.int32)
        mask = jax.ShapeDtypeStruct((g.ndev, scap), jnp.bool_)
        args = (g, seeds, mask)
        self._resolve("init", self._make_init, args, cfg)
        state = jax.eval_shape(lambda gg, s, m: init_wave(gg, s, m), *args)
        n = 1
        for ui in range(self.n_units):
            if self._gen != gen:
                return 0
            bufs = None
            if not local_only:
                args = (g, state, self.cache)
                self._resolve(("fetch", ui),
                              lambda: self._make_fetch(ui, cfg), args, cfg)
                state, bufs, _ = jax.eval_shape(
                    lambda gg, s, c: fetch_stage(gg, pd, cfg, exch, ui, s,
                                                 False, c), *args)
                n += 1
            args = (g, state, bufs)
            self._resolve(("expand", ui, local_only),
                          lambda: self._make_expand(ui, local_only, cfg),
                          args, cfg)
            state = jax.eval_shape(
                lambda gg, s, b: expand_stage(gg, pd, cfg, ui, s, b,
                                              local_only), *args)
            args = (g, state)
            self._resolve(("verify", ui, local_only),
                          lambda: self._make_verify(ui, local_only, cfg),
                          args, cfg)
            state = jax.eval_shape(
                lambda gg, s: verify_stage(gg, pd, cfg, exch, ui, s,
                                           local_only), *args)
            n += 2
        args = (state, np.float32(0.0))
        self._resolve("finalize", self._make_finalize, args, cfg)
        return n + 1

    def prewarm(self, scap: int, local_only: bool,
                escalation_rungs: int = 0) -> int:
        """Resolve the whole stage ladder for seed capacity ``scap`` from
        abstract values (``jax.eval_shape`` chains the inter-stage shapes;
        no device work happens beyond compilation itself).  Abstract and
        concrete dispatches share argument signatures, so a later real
        wave lands exactly on the slots resolved here.

        ``escalation_rungs > 0`` additionally warms that many capacity
        rungs *above* the current caps (doubled exactly as ``escalate()``
        doubles them) — slots are keyed by capacities, so a later
        escalation finds its stages already resolved and an overflow run
        never compiles on the critical path.

        Returns the number of stages resolved — 0 when aborted by a
        concurrent escalation (the ladder being warmed no longer matches
        the live capacities) or under the spmd/dist backends
        (ShapeDtypeStruct placeholders carry no mesh sharding, and a
        Compiled stage rejects calls whose input shardings differ from the
        ones it was lowered with — sharded stages must be resolved from
        the live sharded arrays)."""
        if self.exch.mode in ("spmd", "dist"):
            return 0
        gen = self._gen
        cfg = self.cfg
        with self.tracer.span("prewarm", TRACK_PREWARM, scap=int(scap),
                              local_only=bool(local_only),
                              rungs=int(escalation_rungs)):
            n = self._prewarm_ladder(scap, local_only, cfg, gen)
            for _ in range(max(0, int(escalation_rungs))):
                if n == 0 or cfg.frontier_cap >= _MAX_CAP:
                    break
                cfg = self._escalated(cfg)
                r = self._prewarm_ladder(scap, local_only, cfg, gen)
                n = n + r if r else n
        return n

    def prewarm_async(self, scap: int, local_only: bool,
                      escalation_rungs: int = 0) -> threading.Thread:
        """Run :meth:`prewarm` on a daemon thread (the driver launches this
        right before each scheduler phase, so compilation overlaps group
        formation).  Join via :meth:`join_prewarm` before reading
        ``compiles``/``compile_s``.  Pre-warm is advisory: a failure warns
        and the main path compiles on demand as before."""
        def work():
            try:
                self.prewarm(scap, local_only, escalation_rungs)
            except Exception as e:
                warnings.warn(f"stage pre-warm (scap={scap}, local_only="
                              f"{local_only}) failed: {e!r}", RuntimeWarning)
        th = threading.Thread(target=work, name="rads-stage-prewarm",
                              daemon=True)
        th.start()
        self._prewarm_threads.append(th)
        return th

    def join_prewarm(self) -> None:
        for th in self._prewarm_threads:
            th.join()
        self._prewarm_threads.clear()


# --------------------------------------------------------------------------- #
# Pipeline scheduler
# --------------------------------------------------------------------------- #
@dataclass
class _Wave:
    """One in-flight region-group wave: host-side batches (for the split
    loop), the device-side state futures, a stage cursor, and — once every
    stage is dispatched — the jitted-finalize result futures (``fin``)
    whose ``device_get`` is the wave's single retire sync."""
    batches: list[np.ndarray]
    mask: np.ndarray
    state: WaveState
    stages: list[tuple[str, int]]
    pos: int = 0
    bufs: object = None
    fin: object = None
    t_start: float = field(default_factory=time.perf_counter)
    seq: int = 0                # wave sequence number == trace flow id
    tid: int = 0                # trace lane (TRACK_WAVE0 + lane), 0 = untraced
    t0_us: float = 0.0          # span-clock admit time (traced runs only)


class PipelineScheduler:
    """Drives region-group waves through the staged engine with up to
    ``cfg.pipeline_depth`` waves in flight (see module docstring).

    ``stats`` may be a plain dict or a
    :class:`repro.obs.metrics.MetricsRegistry` (a ``MutableMapping``) —
    the scheduler only reads/writes mapping keys.  ``tracer`` defaults to
    the runner's (itself :data:`repro.obs.trace.NULL_TRACER` unless the
    caller injected a recorder); every hot-loop record site is guarded by
    ``tracer.enabled`` so the off path runs zero instrumentation."""

    def __init__(self, runner: StageRunner, stats: dict, consume,
                 tracer=None):
        self.runner = runner
        self.stats = stats
        self.consume = consume      # (rows, alive, counts, st, phase) -> None
        self.tracer = (tracer if tracer is not None
                       else getattr(runner, "tracer", NULL_TRACER))
        self._wave_seq = 0          # monotone wave counter (trace flow ids)
        self._free_lanes: list[int] = []
        self._n_lanes = 0

    # -- wave formation ----------------------------------------------------- #
    def _next_wave(self, queues: list[GroupQueue], retry: list,
                   scap: int, local_only: bool):
        """Pop the next wave: retries first (LIFO — finish split sub-waves
        before admitting new groups), else one group per device queue with
        steal-from-longest refill; oversized batches are chunked to scap."""
        cfg = self.runner.cfg
        empty = np.array([], dtype=np.int64)
        while True:
            if retry:
                wave = retry.pop()
            elif any(queues):
                wave = [q.pop_head() if q else None for q in queues]
                wave = [empty if b is None else b for b in wave]
                # both knobs gate the checkR/shareR analogue: --no-steal
                # (enable_work_stealing) must disable the group-queue
                # rebalance too, or the ablation silently still steals
                if (cfg.enable_work_stealing and cfg.steal_from_longest
                        and not local_only):
                    for t, b in enumerate(wave):
                        if len(b) > 0:
                            continue
                        src = max(range(len(queues)),
                                  key=lambda u: queues[u].seeds_left)
                        if queues[src]:       # this device drained early:
                            stolen = queues[src].pop_tail()
                            if stolen is not None:
                                wave[t] = stolen
                                self.stats["steal_events"] += 1
                                if self.tracer.enabled:
                                    self.tracer.instant(
                                        "steal", TRACK_SCHED, dev=t,
                                        victim=src, seeds=len(stolen))
            else:
                return None
            if max((len(b) for b in wave), default=0) == 0:
                continue
            if max(len(b) for b in wave) > scap:
                retry.append([b[scap:] for b in wave])
                wave = [b[:scap] for b in wave]
            return wave

    def _admit(self, wave: list[np.ndarray], scap: int) -> _Wave:
        g = self.runner.g
        seeds, mask = _pad_seeds(wave, g.ndev, scap, g.n)
        tr = self.tracer
        if not tr.enabled:
            state = self.runner.init(seeds, mask)
            stages = [(kind, ui) for ui in range(self.runner.n_units)
                      for kind in ("fetch", "expand", "verify")]
            return _Wave(batches=wave, mask=mask, state=state, stages=stages)
        # traced admission: allocate the smallest free wave lane, open the
        # whole-life flow (dispatch -> retire arrow) inside the init span
        if self._free_lanes:
            lane = min(self._free_lanes)
            self._free_lanes.remove(lane)
        else:
            lane = self._n_lanes
            self._n_lanes += 1
        seq = self._wave_seq
        self._wave_seq += 1
        tid = TRACK_WAVE0 + lane
        tr.name_track(tid, f"wave lane {lane}")
        t0 = tr.now_us()
        state = self.runner.init(seeds, mask)
        tr.flow_start(seq, tid)
        tr.complete("init", tid, t0, wave=seq,
                    seeds=int(sum(len(b) for b in wave)), scap=int(scap))
        stages = [(kind, ui) for ui in range(self.runner.n_units)
                  for kind in ("fetch", "expand", "verify")]
        return _Wave(batches=wave, mask=mask, state=state, stages=stages,
                     seq=seq, tid=tid, t0_us=t0)

    def _dispatch_one(self, kind: str, ui: int, w: _Wave, local_only: bool):
        if kind == "fetch":
            w.state, w.bufs = self.runner.fetch(ui, w.state, local_only)
        elif kind == "expand":
            w.state = self.runner.expand(ui, w.state, w.bufs, local_only)
            w.bufs = None
        else:
            w.state = self.runner.verify(ui, w.state, local_only)

    def _dispatch(self, w: _Wave, local_only: bool):
        kind, ui = w.stages[w.pos]
        tr = self.tracer
        if tr.enabled:
            # per-stage span on the wave's lane, annotated with unit, caps
            # rung, and how the executable resolved (slot/store/compile) —
            # every argument is a pre-fetched host scalar (dispatch returns
            # futures; nothing here blocks on the device)
            name = f"{kind}:u{ui}"
            t0 = tr.now_us()
            with tr.device_span(name):
                self._dispatch_one(kind, ui, w, local_only)
            tr.complete(name, w.tid, t0, wave=w.seq, unit=ui,
                        frontier_cap=self.runner.cfg.frontier_cap,
                        exec=getattr(self.runner._tl, "last", "slot"))
        else:
            self._dispatch_one(kind, ui, w, local_only)
        w.pos += 1

    def _drain(self, w: _Wave, local_only: bool):
        """Dispatch ALL of a wave's remaining stages, then its jitted
        finalize — contiguously, so the wave's ops sit back-to-back on the
        (in-order) device stream and the retire ``device_get`` never waits
        behind a younger wave's stages.  The old one-stage-per-tick
        interleave plus an *eager* host-side ``finalize_wave`` at retire
        time was exactly the bench's async<=sync failure: wave ``k``'s
        finalize ops were enqueued behind wave ``k+1``'s stages, so the
        blocking read paid for both waves.

        The finalize carries the runner's drained persistent-store hit
        count: every stage this wave needed was resolved during its own
        dispatches above, so attribution is exact (pre-warm hits land on
        whichever wave finalizes next — same run, same totals)."""
        while w.pos < len(w.stages):
            self._dispatch(w, local_only)
        if w.fin is None:
            tr = self.tracer
            if tr.enabled:
                t0 = tr.now_us()
                w.fin = self.runner.finalize(w.state,
                                             self.runner.take_hits())
                tr.complete("finalize", w.tid, t0, wave=w.seq)
            else:
                w.fin = self.runner.finalize(w.state,
                                             self.runner.take_hits())

    # -- retire + robustness loop ------------------------------------------- #
    def _retire(self, w: _Wave, retry: list, phase: str
                ) -> tuple[float, int]:
        """Drain point: block on the wave's finalized result tuple; consume
        on success, split/escalate on overflow.  Returns (node_cost_sum, n)."""
        # One batched device->host transfer per retired wave — the pipeline's
        # only blocking sync.  finalize_wave itself was jitted and dispatched
        # right behind the wave's last stage (_drain), so this transfers
        # already-scheduled values instead of eagerly dispatching a tail of
        # host-side ops behind the whole device queue (the old async<=sync
        # failure mode); the old scattered reads (bool(complete), eight
        # scalar float() casts in the driver's consume) stay batched too.
        tr = self.tracer
        t0 = tr.now_us() if tr.enabled else 0.0
        rows, alive, counts, complete, st = jax.device_get(w.fin)
        if tr.enabled and w.tid:
            # flow end binds (bp="e") to the enclosing retire span on the
            # retire track — Perfetto draws the dispatch->retire arrow; the
            # wave-summary span closes the wave's whole lane life
            tr.flow_end(w.seq, TRACK_RETIRE)
            tr.complete("retire", TRACK_RETIRE, t0, wave=w.seq,
                        complete=bool(complete))
            tr.complete("wave", w.tid, w.t0_us, wave=w.seq,
                        complete=bool(complete))
            self._free_lanes.append(w.tid - TRACK_WAVE0)
        if not complete:
            # a discarded wave's stats never reach consume — hand its
            # persistent-store hit credit back so the run total stays exact
            self.runner.credit_hits(float(st["compile_cache_hits"]))
            if max(len(b) for b in w.batches) <= 1:
                if not self.runner.escalate():
                    raise RuntimeError("capacity ceiling reached")
                self.stats["cap_escalations"] += 1
                retry.append(w.batches)
                if tr.enabled:
                    tr.instant("cap_escalation", TRACK_SCHED, wave=w.seq,
                               frontier_cap=self.runner.cfg.frontier_cap)
            else:
                self.stats["overflow_retries"] += 1
                retry.append([b[len(b) // 2:] for b in w.batches])
                retry.append([b[:len(b) // 2] for b in w.batches])
                if tr.enabled:
                    tr.instant("overflow_split", TRACK_SCHED, wave=w.seq)
            return 0.0, 0
        # per-real-seed trie-node counts (padding slots masked) — consumers
        # use these for the persisted node_counts histogram (priors v2)
        nc = st["node_counts"][w.mask]
        st["seed_node_counts"] = nc
        self.consume(rows, alive, counts, st, phase)
        self.stats["wave_s_total"] += time.perf_counter() - w.t_start
        return float(nc.sum()), int(nc.size)

    # -- main loop ----------------------------------------------------------- #
    def run(self, queues, scap: int,
            local_only: bool, phase: str, depth=None,
            auto_start: int | None = None) -> float | None:
        """Process per-device group queues (GroupQueue instances or plain
        lists of seed arrays) until empty.  Returns the mean trie-node cost
        per completed seed (running mean over *all* waves).

        ``depth`` overrides ``cfg.pipeline_depth`` — it is a host-side
        scheduling knob only (no recompilation), which lets benchmarks time
        sync (1) vs async (>=2) on the same warm jitted stages.

        ``pipeline_depth="auto"`` (or ``depth="auto"``) picks the depth from
        the per-wave timing stats the scheduler already collects: the ratio
        ``Σ wave latency / pipeline wall`` is the concurrency the pipeline
        *achieved*.  When it saturates the current depth the limit rises
        (up to ``_MAX_AUTO_DEPTH``); when waves stop overlapping (uniform
        runtimes, single surviving queue) it falls back toward synchronous —
        all host-side, so adaptation never recompiles a stage.
        ``auto_start`` seeds the adaptive depth (the priors cache passes the
        depth a previous run on the same workload converged to)."""
        if depth is None:
            depth = self.runner.cfg.pipeline_depth
        auto = depth == "auto"               # the "auto" setting
        if auto:
            depth = int(auto_start) if auto_start else _AUTO_START_DEPTH
            depth = max(1, min(depth, _MAX_AUTO_DEPTH))
        else:
            depth = max(1, int(depth))
        queues = [q if isinstance(q, GroupQueue) else GroupQueue(q)
                  for q in queues]
        retry: list[list[np.ndarray]] = []
        inflight: deque[_Wave] = deque()
        cost_sum, cost_n = 0.0, 0
        waves_done, wave_s_phase = 0, 0.0
        tr = self.tracer
        if tr.enabled:
            tr.name_track(TRACK_SCHED, "scheduler")
            tr.name_track(TRACK_RETIRE, "retire")
            tr.name_track(TRACK_PREWARM, "prewarm")
        t0 = time.perf_counter()
        tp0 = now_us()     # span clock — same domain as every trace event
        while True:
            # 1. fill the pipeline to ``depth``: each admitted wave
            #    dispatches ALL its stages plus its jitted finalize
            #    contiguously (see _drain), so the device stream is fed
            #    deep before the blocking read below.  Lazy Algorithm-3
            #    group formation for wave k+1 (a slow host-side Python
            #    loop) therefore overlaps wave k's already-dispatched
            #    device compute.
            while len(inflight) < depth:
                if tr.enabled:
                    # spans the lazy Algorithm-3 GroupQueue._form pull
                    # (plus steal decisions) feeding the next admission
                    t0g = tr.now_us()
                    wave = self._next_wave(queues, retry, scap, local_only)
                    tr.complete("group_form", TRACK_SCHED, t0g,
                                got=wave is not None)
                else:
                    wave = self._next_wave(queues, retry, scap, local_only)
                if wave is None:
                    break
                w = self._admit(wave, scap)
                inflight.append(w)
                self._drain(w, local_only)
                self.stats["n_waves"] += 1
                self.stats["max_inflight_waves"] = max(
                    self.stats["max_inflight_waves"], len(inflight))
            if not inflight:
                break
            # 2. retire the oldest wave — fully dispatched (finalize
            #    included) at admission, so this is the pure device_get
            #    sync.  If retiring escalates capacities, every younger
            #    in-flight wave already dispatched entirely at the old
            #    capacities; overflow is monotone and re-checked at its
            #    own retire, so a stale-capacity wave is still exact.
            oldest = inflight.popleft()
            s, n = self._retire(oldest, retry, phase)
            cost_sum += s
            cost_n += n
            waves_done += 1
            wave_s_phase += time.perf_counter() - oldest.t_start
            if auto and waves_done >= 2:
                wall = max(time.perf_counter() - t0, 1e-9)
                achieved = wave_s_phase / wall       # mean in-flight waves
                if achieved >= depth - 0.5 and depth < _MAX_AUTO_DEPTH:
                    depth += 1
                elif achieved < depth - 1.25 and depth > 1:
                    depth -= 1
                self.stats["auto_depth"] = depth
        if auto:
            self.stats["auto_depth"] = depth     # persisted via priors v2
        self.stats[f"{phase}_pipeline_s"] = (
            self.stats.get(f"{phase}_pipeline_s", 0.0)
            + time.perf_counter() - t0)
        # per-phase wall on the span clock (satellite: honest dist wall) —
        # recorded unconditionally so `wall_us` exists with tracing off and
        # max-merges across processes in merge_process_stats
        wall = now_us() - tp0
        self.stats[f"{phase}_wall_us"] = (
            self.stats.get(f"{phase}_wall_us", 0.0) + wall)
        if tr.enabled:
            tr.complete(f"phase:{phase}", TRACK_SCHED, tp0, dur_us=wall,
                        depth=depth, local_only=bool(local_only))
        return cost_sum / cost_n if cost_n else None
