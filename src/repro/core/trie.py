"""Embedding trie (§5): prefix-sharing SoA storage of intermediate results.

TPU adaptation (DESIGN.md §2): the paper's pointer-chasing trie becomes a
structure-of-arrays — per level ``vertex``, ``parent`` (index into previous
level), ``child_count`` and ``alive`` arrays. All four paper properties are
preserved: *compression* (shared prefixes stored once), *unique ID* (leaf
row index), *retrieval* (parent-index walk), *removal* (childCount cascade).

Host-side numpy implementation: the engine computes on flat frontiers and
uses the trie as its storage/compression layer; the EL-vs-ET benchmark
(Tables 3-4) reads ``nbytes`` here.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

NODE_BYTES = 12  # v (4) + parentN (4) + childCount (4) — matches Def. 11


@dataclass
class TrieLevel:
    vertex: np.ndarray        # (k,) int32
    parent: np.ndarray        # (k,) int32 (index into previous level; -1 at root)
    child_count: np.ndarray   # (k,) int32
    alive: np.ndarray         # (k,) bool

    @property
    def n_alive(self) -> int:
        return int(self.alive.sum())


@dataclass
class EmbeddingTrie:
    levels: list[TrieLevel] = field(default_factory=list)

    # ------------------------------------------------------------------ #
    @staticmethod
    def from_rows(rows: np.ndarray) -> "EmbeddingTrie":
        """Merge-construction (§5 steps (1)-(4)): rows (k, depth) -> trie."""
        rows = np.asarray(rows)
        k, depth = rows.shape
        t = EmbeddingTrie()
        parent_of_row = np.full(k, -1, dtype=np.int64)
        for lvl in range(depth):
            key = np.stack([parent_of_row, rows[:, lvl]], axis=1)
            uniq, inv = np.unique(key, axis=0, return_inverse=True)
            t.levels.append(TrieLevel(
                vertex=uniq[:, 1].astype(np.int32),
                parent=uniq[:, 0].astype(np.int32),
                child_count=np.zeros(len(uniq), dtype=np.int32),
                alive=np.ones(len(uniq), dtype=bool)))
            if lvl > 0:
                np.add.at(t.levels[lvl - 1].child_count,
                          uniq[:, 0], 1)
            parent_of_row = inv
        return t

    # ------------------------------------------------------------------ #
    def materialize(self) -> np.ndarray:
        """All alive leaf-to-root paths -> rows (k, depth)."""
        if not self.levels:
            return np.zeros((0, 0), dtype=np.int32)
        depth = len(self.levels)
        leaf = self.levels[-1]
        ids = np.flatnonzero(leaf.alive)
        out = np.zeros((len(ids), depth), dtype=np.int32)
        cur = ids
        for lvl in range(depth - 1, -1, -1):
            out[:, lvl] = self.levels[lvl].vertex[cur]
            cur = self.levels[lvl].parent[cur]
        return out

    def remove_result(self, leaf_id: int) -> None:
        """Removal with childCount cascade (§5.1 'Removal'): kill the leaf,
        decrement its parent's childCount; if that reaches 0 the parent is
        removed too, recursively."""
        lvl = len(self.levels) - 1
        node = leaf_id
        while lvl >= 0 and node >= 0:
            level = self.levels[lvl]
            level.alive[node] = False
            if lvl == 0:
                break
            parent = int(level.parent[node])
            self.levels[lvl - 1].child_count[parent] -= 1
            if self.levels[lvl - 1].child_count[parent] > 0:
                break
            node = parent
            lvl -= 1

    def filter_leaves(self, keep: np.ndarray) -> None:
        """Vectorized bulk removal: keep (n_alive_leaves,) bool in alive order."""
        leaf = self.levels[-1]
        ids = np.flatnonzero(leaf.alive)
        drop = ids[~np.asarray(keep)]
        for leaf_id in drop:
            self.remove_result(int(leaf_id))

    # ------------------------------------------------------------------ #
    @property
    def nbytes(self) -> int:
        return sum(lv.n_alive * NODE_BYTES for lv in self.levels)

    @property
    def n_nodes(self) -> int:
        return sum(lv.n_alive for lv in self.levels)

    @property
    def n_results(self) -> int:
        return self.levels[-1].n_alive if self.levels else 0


def embedding_list_bytes(rows: np.ndarray) -> int:
    """The EL baseline: flat (k, depth) int32 rows."""
    return int(rows.shape[0] * rows.shape[1] * 4)


def compression_report(rows: np.ndarray) -> dict:
    t = EmbeddingTrie.from_rows(rows)
    el = embedding_list_bytes(rows)
    et = t.nbytes
    return dict(n_results=int(rows.shape[0]), el_bytes=el, et_bytes=et,
                ratio=el / max(et, 1))
