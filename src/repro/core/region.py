"""Region groups — memory-control strategy (§6, Algorithm 3).

The candidate set of ``dp0.piv`` on each device is split into groups whose
*estimated* memory cost (trie nodes, calibrated from the SM-E pass) fits the
budget; groups are processed sequentially. Grouping maximizes neighborhood
sharing via the paper's ``proximity`` measure (Eq. 5) for small candidate
sets, falling back to sorted-id blocks (block partitions make id-adjacent
vertices neighborhood-similar) for large ones.
"""
from __future__ import annotations

import numpy as np

from repro.graph.storage import PartitionedGraph


def proximity_groups(pg: PartitionedGraph, cands: np.ndarray,
                     est_cost: np.ndarray, budget: float,
                     seed: int = 0) -> list[np.ndarray]:
    """Algorithm 3, run to exhaustion (returns all groups, not just one)."""
    rng = np.random.default_rng(seed)
    remaining = list(map(int, cands))
    cost = {int(v): float(c) for v, c in zip(cands, est_cost)}
    groups: list[np.ndarray] = []
    while remaining:
        i = int(rng.integers(len(remaining)))
        v0 = remaining.pop(i)
        rg = [v0]
        phi = cost[v0]
        nbr_set = set(map(int, pg.neighbors(v0)))
        while remaining and phi < budget:
            # argmax proximity(v, rg) = |adj(v) ∩ N(rg)| / |adj(v)|   (Eq. 5)
            best_j, best_p = 0, -1.0
            for j, v in enumerate(remaining):
                nb = pg.neighbors(v)
                if len(nb) == 0:
                    p = 0.0
                else:
                    p = sum(1 for x in nb if int(x) in nbr_set) / len(nb)
                if p > best_p:
                    best_j, best_p = j, p
            v = remaining.pop(best_j)
            if phi + cost[v] > budget and len(rg) >= 1:
                remaining.append(v)        # Alg. 3 line 8-9: roll back
                break
            rg.append(v)
            phi += cost[v]
            nbr_set.update(map(int, pg.neighbors(v)))
        groups.append(np.array(rg, dtype=np.int64))
    return groups


def block_groups(cands: np.ndarray, est_cost: np.ndarray,
                 budget: float) -> list[np.ndarray]:
    """Sorted-id greedy packing (locality from block partitioning)."""
    order = np.argsort(cands)
    cands, est_cost = cands[order], est_cost[order]
    groups, cur, phi = [], [], 0.0
    for v, c in zip(cands, est_cost):
        if cur and phi + c > budget:
            groups.append(np.array(cur, dtype=np.int64))
            cur, phi = [], 0.0
        cur.append(int(v))
        phi += float(c)
    if cur:
        groups.append(np.array(cur, dtype=np.int64))
    return groups


def make_region_groups(pg: PartitionedGraph, cands: np.ndarray,
                       est_cost: np.ndarray, budget: float,
                       proximity_threshold: int = 256,
                       seed: int = 0) -> list[np.ndarray]:
    if len(cands) == 0:
        return []
    if len(cands) <= proximity_threshold:
        return proximity_groups(pg, cands, est_cost, budget, seed)
    return block_groups(cands, est_cost, budget)
