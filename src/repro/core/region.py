"""Region groups — memory-control strategy (§6, Algorithm 3).

The candidate set of ``dp0.piv`` on each device is split into groups whose
*estimated* memory cost (trie nodes, calibrated from the SM-E pass) fits the
budget; groups are processed sequentially. Grouping maximizes neighborhood
sharing via the paper's ``proximity`` measure (Eq. 5) for small candidate
sets, falling back to sorted-id blocks (block partitions make id-adjacent
vertices neighborhood-similar) for large ones.

Both strategies are *incremental* generators (``iter_*``): Algorithm 3
grows one group at a time, so the async wave scheduler pulls groups on
demand and the (Python-side) grouping of wave ``k+1`` overlaps the device
compute of wave ``k``.  The list-returning wrappers run the generators to
exhaustion and are what the synchronous callers and the tests use; the
generator and list forms produce *identical* groups (same RNG stream).
"""
from __future__ import annotations

from typing import Iterator

import numpy as np

from repro.graph.storage import PartitionedGraph


def iter_proximity_groups(pg: PartitionedGraph, cands: np.ndarray,
                          est_cost: np.ndarray, budget: float,
                          seed: int = 0) -> Iterator[np.ndarray]:
    """Algorithm 3, one group per ``next()`` (run to exhaustion for all)."""
    rng = np.random.default_rng(seed)
    remaining = list(map(int, cands))
    cost = {int(v): float(c) for v, c in zip(cands, est_cost)}
    while remaining:
        i = int(rng.integers(len(remaining)))
        v0 = remaining.pop(i)
        rg = [v0]
        phi = cost[v0]
        nbr_set = set(map(int, pg.neighbors(v0)))
        while remaining and phi < budget:
            # argmax proximity(v, rg) = |adj(v) ∩ N(rg)| / |adj(v)|   (Eq. 5)
            best_j, best_p = 0, -1.0
            for j, v in enumerate(remaining):
                nb = pg.neighbors(v)
                if len(nb) == 0:
                    p = 0.0
                else:
                    p = sum(1 for x in nb if int(x) in nbr_set) / len(nb)
                if p > best_p:
                    best_j, best_p = j, p
            v = remaining.pop(best_j)
            if phi + cost[v] > budget and len(rg) >= 1:
                remaining.append(v)        # Alg. 3 line 8-9: roll back
                break
            rg.append(v)
            phi += cost[v]
            nbr_set.update(map(int, pg.neighbors(v)))
        yield np.array(rg, dtype=np.int64)


def iter_block_groups(cands: np.ndarray, est_cost: np.ndarray,
                      budget: float) -> Iterator[np.ndarray]:
    """Sorted-id greedy packing (locality from block partitioning)."""
    order = np.argsort(cands)
    cands, est_cost = cands[order], est_cost[order]
    cur, phi = [], 0.0
    for v, c in zip(cands, est_cost):
        if cur and phi + c > budget:
            yield np.array(cur, dtype=np.int64)
            cur, phi = [], 0.0
        cur.append(int(v))
        phi += float(c)
    if cur:
        yield np.array(cur, dtype=np.int64)


def iter_region_groups(pg: PartitionedGraph, cands: np.ndarray,
                       est_cost: np.ndarray, budget: float,
                       proximity_threshold: int = 256,
                       seed: int = 0) -> Iterator[np.ndarray]:
    if len(cands) == 0:
        return iter(())
    if len(cands) <= proximity_threshold:
        return iter_proximity_groups(pg, cands, est_cost, budget, seed)
    return iter_block_groups(cands, est_cost, budget)


def proximity_groups(pg: PartitionedGraph, cands: np.ndarray,
                     est_cost: np.ndarray, budget: float,
                     seed: int = 0) -> list[np.ndarray]:
    return list(iter_proximity_groups(pg, cands, est_cost, budget, seed))


def block_groups(cands: np.ndarray, est_cost: np.ndarray,
                 budget: float) -> list[np.ndarray]:
    return list(iter_block_groups(cands, est_cost, budget))


def make_region_groups(pg: PartitionedGraph, cands: np.ndarray,
                       est_cost: np.ndarray, budget: float,
                       proximity_threshold: int = 256,
                       seed: int = 0) -> list[np.ndarray]:
    return list(iter_region_groups(pg, cands, est_cost, budget,
                                   proximity_threshold, seed))
