"""Collective-exchange backends for the R-Meef engine.

Engine state is *stacked*: every array carries a leading ``ndev`` axis.  A
backend supplies the two collectives the engine needs — ``a2a`` (the
paper's batched fetchV/verifyE request/response routing, ``out[t, s] =
x[s, t]``) and ``all_reduce_sum`` — plus the off-device byte accounting
that keeps ``stats["bytes_fetch"]``/``stats["bytes_verify"]`` comparable
across backends.

Built-in backends, selected with ``Exchange(mode)``:

* ``sim``    — whole stack on one device, a2a is an axis swap.  Bit-exact
               reference semantics for tests.
* ``spmd``   — leading axis sharded over the mesh's ``data`` axis, a2a is a
               real ``jax.lax.all_to_all`` under ``shard_map`` (resolved
               through :mod:`repro.compat`) — the single-process
               production path.
* ``gather`` — the same request/response protocol as ``sim`` implemented
               with plain device-local gathers; runs on CPU-only
               single-process hosts with no mesh at all.
* ``dist``   — ``spmd`` across **process boundaries**: the mesh spans every
               ``jax.distributed``-initialized process (one engine
               "machine" M_t per process), so the same shard_map
               ``all_to_all`` lowers to real cross-process collectives
               (gloo TCP on CPU, ICI/DCN on TPU).

Every backend also carries a **wire format** (``wire_format="raw" |
"varint"``, selected via ``EngineConfig.wire_format`` / ``--wire``): with
``"varint"`` the engine stages hand ``a2a``/``a2a_tree`` the coded ``uint8``
streams plus per-lane byte lengths from :mod:`repro.core.wire` instead of
the raw int32 slabs, and the ``bytes_wire_fetch``/``bytes_wire_verify``
accounting sums the *actual* stream lengths
(:meth:`ExchangeBackend.off_device_payload_bytes`) rather than the modeled
element sizes.  Results are wire-format-invariant (the codecs are exact).

New backends register with ``@register_exchange_backend("name")``.

The ``dist`` backend: bootstrap protocol
----------------------------------------
Launch is coordinator-based and flag-compatible with real multi-host: every
process runs the same program (:mod:`repro.launch.dist_worker`) with
``--coordinator HOST:PORT --num-processes N --process-id I``.  Each worker
(1) selects the CPU gloo collectives *before* any backend client exists,
(2) calls ``jax.distributed.initialize``, (3) builds the identical
deterministic dataset/partition/plan from the shared flags, and (4) builds
a 1-D ``("data",)`` mesh over all N processes' devices.  All of the
version-sensitive steps live in :mod:`repro.compat`
(``enable_cpu_collectives`` / ``distributed_initialize`` /
``global_shard``); this module only assumes a mesh whose ``data`` axis may
span processes.  Graph and cache pytrees become process-global arrays via
``compat.global_shard`` (each process contributes its own partition
block); everything else — seeds, scheduler decisions, retry/escalation —
is computed redundantly and identically on every process, which is the
standing SPMD contract: **every process must dispatch the same collectives
in the same order**, so the driver pins ``pipeline_depth="auto"`` to a
fixed depth under ``dist`` (timing-adaptive depth could diverge) and only
process 0 persists priors/artifacts.

Pipelined group communication (``comm_chunks``)
-----------------------------------------------
``EngineConfig.comm_pipeline`` splits each wave's a2a into ``comm_chunks``
sub-exchanges dispatched back-to-back (the pipelined adaptive-group
communication of arXiv:1804.09764): on transports with real latency the
transfer of chunk *k* overlaps the encode/decode compute of chunk *k+1*,
riding the same contiguous-drain dispatch order the scheduler already
guarantees.  The chunking contract: buffers are split **positionally along
the fixed per-peer capacity axis** (axis 2 of the ``(src, peer, cap, ...)``
request layout) *after* any wire coding, and the transpose protocol
``out[t, s] = x[s, t]`` is applied per chunk — concatenating the chunk
results is bit-identical to the unchunked exchange, and all ``bytes_*``
accounting is computed from the per-peer count/length matrices (never from
the chunk layout), so byte stats are chunk-invariant by construction.
Buffers whose capacity axis does not divide evenly (or 2-D length
matrices) go in one shot.

Why stats merge host-side: per-wave stats ride the replicated finalize
output, so every process computes identical *logical* totals (bytes,
counts, hits) — cross-process agreement is therefore a correctness check,
not a reduction.  Wall-clock and compile seconds genuinely differ per
process, so the scalability harness collects each process's stats dict and
merges them in :func:`repro.core.driver.merge_process_stats` (asserts the
logical stats agree byte-for-byte, takes the max over wall stats) instead
of burning a collective on numbers the device never needs.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import ClassVar

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro import compat


# --------------------------------------------------------------------------- #
# Backend registry
# --------------------------------------------------------------------------- #
@dataclass(frozen=True)
class ExchangeBackend:
    """Base class: collectives over the stacked ``(ndev, ...)`` layout."""

    mode: ClassVar[str] = "abstract"

    mesh: Mesh | None = None
    axis: str = "data"
    wire_format: str = "raw"   # 'raw' int32 slabs | 'varint' coded u8 streams
    comm_chunks: int = 1       # >1: split each a2a into that many
                               # back-to-back sub-exchanges along the
                               # per-peer capacity axis (comm pipelining —
                               # see module docstring; bit-identical)

    def a2a(self, x: jnp.ndarray) -> jnp.ndarray:
        """x: (ndev_src, ndev_dst, ...) -> out[t, s] = x[s, t].

        With ``comm_chunks > 1`` the exchange is dispatched as that many
        positional sub-exchanges along axis 2 (the fixed per-peer capacity
        axis) so chunk k's transfer overlaps chunk k+1's encode/decode on
        latency-bound transports; the transpose only permutes axes 0/1, so
        the concatenated result is bit-identical.  Buffers without an
        evenly-divisible capacity axis (e.g. the 2-D per-peer length
        matrices of the coded wire paths) go in one shot."""
        c = self.comm_chunks
        if c > 1 and x.ndim >= 3 and x.shape[2] >= c and x.shape[2] % c == 0:
            return jnp.concatenate(
                [self._a2a(part) for part in jnp.split(x, c, axis=2)],
                axis=2)
        return self._a2a(x)

    def _a2a(self, x: jnp.ndarray) -> jnp.ndarray:
        """Single-shot transport: out[t, s] = x[s, t] (backend-specific)."""
        raise NotImplementedError

    def a2a_tree(self, tree):
        """``a2a`` mapped over an arbitrary pytree of (ndev, ndev, ...)
        buffers — the engine stages exchange whole sub-states (e.g. the
        verifyE (a, b) request pair) in one call so a backend can fuse or
        coalesce the flight however it likes."""
        return compat.tree_map(self.a2a, tree)

    def all_reduce_sum(self, x: jnp.ndarray) -> jnp.ndarray:
        """x: (ndev, ...) -> summed-over-devices, broadcast back."""
        raise NotImplementedError

    def off_device_bytes(self, counts: jnp.ndarray,
                         elem_bytes: float) -> jnp.ndarray:
        """Wire bytes implied by a per-peer request count matrix.

        ``counts[t, p]`` = entries device ``t`` sends to peer ``p``; the
        diagonal (self-traffic) is free on every backend.  All built-in
        backends share this *logical* accounting — sim and gather report
        the bytes the spmd path would put on the wire, so stats stay
        comparable when swapping backends.
        """
        ndev = counts.shape[0]
        off = counts * (1 - jnp.eye(ndev, dtype=counts.dtype))
        return off.sum().astype(jnp.float32) * elem_bytes

    def off_device_payload_bytes(self, byte_matrix: jnp.ndarray
                                 ) -> jnp.ndarray:
        """Like :meth:`off_device_bytes` for *pre-summed* per-peer byte
        matrices (``byte_matrix[t, p]`` = payload bytes ``t`` sends to
        ``p``) — used when entries have variable size, e.g. the modeled
        delta+varint coding of fetchV id payloads.  The diagonal
        (self-traffic) is free, identically on every built-in backend."""
        ndev = byte_matrix.shape[0]
        off = byte_matrix * (1 - jnp.eye(ndev, dtype=byte_matrix.dtype))
        return off.sum().astype(jnp.float32)

    def register_metrics(self, reg, comm_pipeline: bool | None = None
                         ) -> None:
        """Set the exchange-owned instruments on a stats registry (declared
        in :mod:`repro.obs.schema`): process topology + comm-pipelining
        knobs.  The backend owns these keys — the driver hands its registry
        over instead of poking them blind.  ``comm_pipeline`` defaults to
        whether chunking is actually active."""
        reg["process_index"] = compat.process_index()
        reg["process_count"] = compat.process_count()
        reg["comm_pipeline"] = (self.comm_chunks > 1 if comm_pipeline is None
                                else bool(comm_pipeline))
        reg["comm_chunks"] = self.comm_chunks

    def per_dev_sent_bytes(self, byte_matrix: jnp.ndarray) -> jnp.ndarray:
        """Per-device off-device *sent* bytes: row sums of a per-peer byte
        matrix (``byte_matrix[t, p]`` = payload bytes ``t`` sends to ``p``)
        with the free diagonal masked.  Returns ``(ndev,)`` f32; summing it
        recovers the matching scalar accounting exactly, which is the
        invariant the scalability harness's skew curves (max-per-process vs
        mean) are gated on."""
        ndev = byte_matrix.shape[0]
        off = byte_matrix * (1 - jnp.eye(ndev, dtype=byte_matrix.dtype))
        return off.sum(axis=1).astype(jnp.float32)


_BACKENDS: dict[str, type[ExchangeBackend]] = {}


def register_exchange_backend(name: str):
    """Class decorator: make ``Exchange(name)`` resolve to this backend."""
    def deco(cls: type[ExchangeBackend]) -> type[ExchangeBackend]:
        cls.mode = name
        _BACKENDS[name] = cls
        return cls
    return deco


def exchange_backends() -> tuple[str, ...]:
    """Registered backend names (sorted)."""
    return tuple(sorted(_BACKENDS))


def Exchange(mode: str = "sim", mesh: Mesh | None = None,
             axis: str = "data", wire_format: str = "raw",
             comm_chunks: int = 1) -> ExchangeBackend:
    """Factory kept name-compatible with the old two-branch dataclass:
    ``Exchange("sim")`` / ``Exchange(mode="spmd", mesh=mesh)``.
    ``wire_format`` selects the on-the-wire payload coding and
    ``comm_chunks`` the pipelined sub-exchange count (see module
    docstring); both are transport-independent, so every backend supports
    them."""
    try:
        cls = _BACKENDS[mode]
    except KeyError:
        raise ValueError(
            f"unknown exchange mode {mode!r}; registered backends: "
            f"{list(exchange_backends())}") from None
    if wire_format not in ("raw", "varint"):
        raise ValueError(
            f"unknown wire format {wire_format!r}; expected 'raw' or "
            f"'varint'")
    if not isinstance(comm_chunks, int) or comm_chunks < 1:
        raise ValueError(
            f"comm_chunks must be an int >= 1, got {comm_chunks!r}")
    return cls(mesh=mesh, axis=axis, wire_format=wire_format,
               comm_chunks=comm_chunks)


# --------------------------------------------------------------------------- #
# Built-in backends
# --------------------------------------------------------------------------- #
@register_exchange_backend("sim")
@dataclass(frozen=True)
class SimExchange(ExchangeBackend):
    """Single-device reference: the all-to-all is an axis swap."""

    def _a2a(self, x: jnp.ndarray) -> jnp.ndarray:
        return jnp.swapaxes(x, 0, 1)

    def all_reduce_sum(self, x: jnp.ndarray) -> jnp.ndarray:
        return jnp.broadcast_to(x.sum(axis=0, keepdims=True), x.shape)


@register_exchange_backend("gather")
@dataclass(frozen=True)
class GatherExchange(ExchangeBackend):
    """Device-local gathers, no mesh, no collectives.

    Semantically identical to ``sim`` (both realize the exact transpose
    protocol) but lowers to per-destination gathers — the shape a real
    RDMA/queue-pair transport would take on a CPU-only single-process
    host, and a third registry entry proving backends are pluggable."""

    def _a2a(self, x: jnp.ndarray) -> jnp.ndarray:
        ndev = x.shape[0]
        # destination t gathers its column from every source's row
        return jax.vmap(lambda t: jnp.take(x, t, axis=1))(jnp.arange(ndev))

    def all_reduce_sum(self, x: jnp.ndarray) -> jnp.ndarray:
        total = x.sum(axis=0)
        return jax.vmap(lambda _: total)(jnp.arange(x.shape[0]))


@register_exchange_backend("spmd")
@dataclass(frozen=True)
class SpmdExchange(ExchangeBackend):
    """Production path: leading axis sharded over ``mesh[axis]``; exchanges
    are real collectives under ``shard_map``."""

    def __post_init__(self):
        if self.mesh is None:
            raise ValueError("spmd exchange needs a mesh")

    def _spec(self, ndim: int) -> P:
        return P(self.axis, *([None] * (ndim - 1)))

    def _a2a(self, x: jnp.ndarray) -> jnp.ndarray:
        def body(xl):  # (1, ndev, ...)
            out = jax.lax.all_to_all(xl[0], self.axis, split_axis=0,
                                     concat_axis=0, tiled=True)
            return out[None]

        spec = self._spec(x.ndim)
        return compat.shard_map(body, mesh=self.mesh, in_specs=spec,
                                out_specs=spec)(x)

    def all_reduce_sum(self, x: jnp.ndarray) -> jnp.ndarray:
        def body(xl):
            return jax.lax.psum(xl, self.axis)

        spec = self._spec(x.ndim)
        return compat.shard_map(body, mesh=self.mesh, in_specs=spec,
                                out_specs=spec)(x)


@register_exchange_backend("dist")
@dataclass(frozen=True)
class DistExchange(SpmdExchange):
    """``spmd`` across process boundaries: same shard_map collectives, but
    the mesh spans every ``jax.distributed``-initialized process, so each
    ``all_to_all`` crosses the gloo TCP transport between processes.  All
    transport mechanics are inherited — the backend exists as a distinct
    registry entry so the driver, scheduler, and wire heuristics can gate
    multi-process-only behaviour (host-side stat merging, replicated
    finalize shardings, pinned pipeline depth) on ``mode == "dist"``
    without sniffing the mesh.  Bootstrap lives in ``compat`` (see the
    module docstring's protocol note)."""


# --------------------------------------------------------------------------- #
# Static-shape primitives shared by the engines
# --------------------------------------------------------------------------- #
def compact(mask: jnp.ndarray, cap_out: int, *arrays: jnp.ndarray,
            fill: int = 0, fills: tuple | None = None) -> tuple:
    """Stable-compact rows where ``mask`` is True into ``cap_out`` slots.

    Returns (new_mask (cap_out,), overflow (bool), *gathered arrays). Rows
    beyond cap_out are dropped and flagged.  Per-device (no leading axis).
    ``fills`` overrides ``fill`` per array (one entry per array) so
    heterogeneous columns — ids, flags, payload rows — share one argsort.
    """
    n = mask.shape[0]
    order = jnp.argsort(~mask, stable=True)
    take = order[:cap_out] if cap_out <= n else jnp.pad(
        order, (0, cap_out - n), constant_values=n - 1)
    count = mask.sum()
    new_mask = jnp.arange(cap_out) < jnp.minimum(count, cap_out)
    overflow = count > cap_out
    if fills is None:
        fills = (fill,) * len(arrays)
    outs = []
    for a, fl in zip(arrays, fills):
        g = a[take]
        g = jnp.where(
            new_mask.reshape((-1,) + (1,) * (g.ndim - 1)), g, fl)
        outs.append(g)
    return (new_mask, overflow, *outs)


def membership(sorted_rows: jnp.ndarray, vals: jnp.ndarray) -> jnp.ndarray:
    """sorted_rows (R, M) ascending (sentinel-padded), vals (R, K) ->
    bool (R, K): vals[r, k] in sorted_rows[r]."""
    idx = jax.vmap(jnp.searchsorted)(sorted_rows, vals)
    idx = jnp.clip(idx, 0, sorted_rows.shape[-1] - 1)
    found = jnp.take_along_axis(sorted_rows, idx, axis=-1) == vals
    return found


def unique_ids(ids: jnp.ndarray, mask: jnp.ndarray, sentinel: int
               ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Sorted-unique of masked ids. Returns (uids (n,), umask (n,)) with
    invalid slots pushed to the back as ``sentinel``. Output length == input
    (a unique id count never exceeds the input count)."""
    x = jnp.where(mask, ids, sentinel)
    xs = jnp.sort(x)
    first = jnp.concatenate([jnp.array([True]), xs[1:] != xs[:-1]])
    valid = first & (xs < sentinel)
    order = jnp.argsort(~valid, stable=True)
    uids = jnp.where(jnp.arange(x.shape[0]) < valid.sum(), xs[order], sentinel)
    umask = jnp.arange(x.shape[0]) < valid.sum()
    return uids, umask


def unique_pairs(a: jnp.ndarray, b: jnp.ndarray, mask: jnp.ndarray,
                 sentinel: int) -> tuple:
    """Dedup (a, b) pairs without 64-bit keys (EVI, Def. 5).

    Returns (ua, ub, umask, rank) where (ua[j], ub[j]) are the unique pairs
    (sorted lexicographically, invalid at the back) and rank[i] gives the
    unique-slot of input pair i (undefined where ~mask, but always a safe
    index in [0, n)). Output length == input length."""
    n = a.shape[0]
    av = jnp.where(mask, a, sentinel)
    bv = jnp.where(mask, b, sentinel)
    order = jnp.lexsort((bv, av))
    a_s, b_s = av[order], bv[order]
    first = jnp.concatenate(
        [jnp.array([True]), (a_s[1:] != a_s[:-1]) | (b_s[1:] != b_s[:-1])])
    valid_s = first & (a_s < sentinel)
    # group id (in sorted order) and unique slot of each group's head
    grp = jnp.cumsum(first) - 1
    uniq_slot_of_grp = jnp.cumsum(valid_s) - 1
    # scatter unique pairs
    ucount = valid_s.sum()
    slot = jnp.where(valid_s, uniq_slot_of_grp, n - 1)
    ua = jnp.full((n,), sentinel, dtype=a.dtype).at[slot].set(
        jnp.where(valid_s, a_s, sentinel), mode="drop")
    ub = jnp.full((n,), sentinel, dtype=b.dtype).at[slot].set(
        jnp.where(valid_s, b_s, sentinel), mode="drop")
    umask = jnp.arange(n) < ucount
    # rank per input: per-group table of head slots, then invert the sort
    slot_of_grp = jnp.zeros((n,), dtype=jnp.int32).at[grp].max(
        jnp.where(first, uniq_slot_of_grp, 0).astype(jnp.int32), mode="drop")
    rank_sorted = slot_of_grp[grp]
    inv = jnp.zeros((n,), dtype=jnp.int32).at[order].set(
        jnp.arange(n, dtype=jnp.int32))
    rank = rank_sorted[inv]
    return ua, ub, umask, rank
