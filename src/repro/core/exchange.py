"""Collective-exchange abstraction for the R-Meef engine.

Engine state is *stacked*: every array carries a leading ``ndev`` axis.  In
``sim`` mode the whole stack lives on one device and the all-to-all is an
axis swap — bit-identical reference semantics for tests.  In ``spmd`` mode
the leading axis is sharded over the mesh's ``data`` axis and the exchange
is a real ``jax.lax.all_to_all`` under ``shard_map`` — the production path
(this is the paper's fetchV/verifyE request/response, batched per round).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclass(frozen=True)
class Exchange:
    """mode: 'sim' (axis swap) or 'spmd' (shard_map + lax.all_to_all)."""

    mode: str = "sim"
    mesh: Mesh | None = None
    axis: str = "data"

    def a2a(self, x: jnp.ndarray) -> jnp.ndarray:
        """x: (ndev_src, ndev_dst, ...) -> out[t, s] = x[s, t]."""
        if self.mode == "sim":
            return jnp.swapaxes(x, 0, 1)
        assert self.mesh is not None, "spmd exchange needs a mesh"
        ndev = x.shape[0]

        def body(xl):  # (1, ndev, ...)
            out = jax.lax.all_to_all(xl[0], self.axis, split_axis=0,
                                     concat_axis=0, tiled=True)
            return out[None]

        spec = P(self.axis, *([None] * (x.ndim - 1)))
        return jax.shard_map(body, mesh=self.mesh, in_specs=spec,
                             out_specs=spec)(x)

    def all_reduce_sum(self, x: jnp.ndarray) -> jnp.ndarray:
        """x: (ndev, ...) -> scalar-summed-over-devices broadcast back."""
        if self.mode == "sim":
            return jnp.broadcast_to(x.sum(axis=0, keepdims=True), x.shape)
        assert self.mesh is not None

        def body(xl):
            return jax.lax.psum(xl, self.axis)

        spec = P(self.axis, *([None] * (x.ndim - 1)))
        return jax.shard_map(body, mesh=self.mesh, in_specs=spec,
                             out_specs=spec)(x)


# --------------------------------------------------------------------------- #
# Static-shape primitives shared by the engines
# --------------------------------------------------------------------------- #
def compact(mask: jnp.ndarray, cap_out: int, *arrays: jnp.ndarray,
            fill: int = 0) -> tuple:
    """Stable-compact rows where ``mask`` is True into ``cap_out`` slots.

    Returns (new_mask (cap_out,), overflow (bool), *gathered arrays). Rows
    beyond cap_out are dropped and flagged.  Per-device (no leading axis).
    """
    n = mask.shape[0]
    order = jnp.argsort(~mask, stable=True)
    take = order[:cap_out] if cap_out <= n else jnp.pad(
        order, (0, cap_out - n), constant_values=n - 1)
    count = mask.sum()
    new_mask = jnp.arange(cap_out) < jnp.minimum(count, cap_out)
    overflow = count > cap_out
    outs = []
    for a in arrays:
        g = a[take]
        g = jnp.where(
            new_mask.reshape((-1,) + (1,) * (g.ndim - 1)), g, fill)
        outs.append(g)
    return (new_mask, overflow, *outs)


def membership(sorted_rows: jnp.ndarray, vals: jnp.ndarray) -> jnp.ndarray:
    """sorted_rows (R, M) ascending (sentinel-padded), vals (R, K) ->
    bool (R, K): vals[r, k] in sorted_rows[r]."""
    idx = jax.vmap(jnp.searchsorted)(sorted_rows, vals)
    idx = jnp.clip(idx, 0, sorted_rows.shape[-1] - 1)
    found = jnp.take_along_axis(sorted_rows, idx, axis=-1) == vals
    return found


def unique_ids(ids: jnp.ndarray, mask: jnp.ndarray, sentinel: int
               ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Sorted-unique of masked ids. Returns (uids (n,), umask (n,)) with
    invalid slots pushed to the back as ``sentinel``. Output length == input
    (a unique id count never exceeds the input count)."""
    x = jnp.where(mask, ids, sentinel)
    xs = jnp.sort(x)
    first = jnp.concatenate([jnp.array([True]), xs[1:] != xs[:-1]])
    valid = first & (xs < sentinel)
    order = jnp.argsort(~valid, stable=True)
    uids = jnp.where(jnp.arange(x.shape[0]) < valid.sum(), xs[order], sentinel)
    umask = jnp.arange(x.shape[0]) < valid.sum()
    return uids, umask


def unique_pairs(a: jnp.ndarray, b: jnp.ndarray, mask: jnp.ndarray,
                 sentinel: int) -> tuple:
    """Dedup (a, b) pairs without 64-bit keys (EVI, Def. 5).

    Returns (ua, ub, umask, rank) where (ua[j], ub[j]) are the unique pairs
    (sorted lexicographically, invalid at the back) and rank[i] gives the
    unique-slot of input pair i (undefined where ~mask). Output length ==
    input length."""
    n = a.shape[0]
    av = jnp.where(mask, a, sentinel)
    bv = jnp.where(mask, b, sentinel)
    order = jnp.lexsort((bv, av))
    a_s, b_s = av[order], bv[order]
    first = jnp.concatenate(
        [jnp.array([True]), (a_s[1:] != a_s[:-1]) | (b_s[1:] != b_s[:-1])])
    valid_s = first & (a_s < sentinel)
    # rank (in sorted order) of each sorted element's unique group
    grp = jnp.cumsum(first) - 1                      # group id in sorted order
    # unique slot j = rank among valid uniques; invalid groups map to n-1
    uniq_slot_of_grp = jnp.cumsum(valid_s) - 1       # per sorted elem
    # scatter unique pairs
    ucount = valid_s.sum()
    slot = jnp.where(valid_s, uniq_slot_of_grp, n - 1)
    ua = jnp.full((n,), sentinel, dtype=a.dtype).at[slot].set(
        jnp.where(valid_s, a_s, sentinel), mode="drop")
    ub = jnp.full((n,), sentinel, dtype=b.dtype).at[slot].set(
        jnp.where(valid_s, b_s, sentinel), mode="drop")
    umask = jnp.arange(n) < ucount
    # rank per input: invert the sort, then map group -> unique slot
    grp_slot = uniq_slot_of_grp  # per sorted position, slot of its group head?
    # each sorted elem's group head slot: gather slot at the head position
    head_pos = jnp.maximum(jnp.cumsum(first) - 1, 0)
    # slot for group g = uniq_slot at the head of group g; build per-group table
    slot_of_grp = jnp.zeros((n,), dtype=jnp.int32).at[grp].max(
        jnp.where(first, uniq_slot_of_grp, 0).astype(jnp.int32), mode="drop")
    rank_sorted = slot_of_grp[grp]
    inv = jnp.zeros((n,), dtype=jnp.int32).at[order].set(
        jnp.arange(n, dtype=jnp.int32))
    rank = rank_sorted[inv]
    return ua, ub, umask, rank
