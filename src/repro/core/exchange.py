"""Collective-exchange backends for the R-Meef engine.

Engine state is *stacked*: every array carries a leading ``ndev`` axis.  A
backend supplies the two collectives the engine needs — ``a2a`` (the
paper's batched fetchV/verifyE request/response routing, ``out[t, s] =
x[s, t]``) and ``all_reduce_sum`` — plus the off-device byte accounting
that keeps ``stats["bytes_fetch"]``/``stats["bytes_verify"]`` comparable
across backends.

Built-in backends, selected with ``Exchange(mode)``:

* ``sim``    — whole stack on one device, a2a is an axis swap.  Bit-exact
               reference semantics for tests.
* ``spmd``   — leading axis sharded over the mesh's ``data`` axis, a2a is a
               real ``jax.lax.all_to_all`` under ``shard_map`` (resolved
               through :mod:`repro.compat`) — the production path.
* ``gather`` — the same request/response protocol as ``sim`` implemented
               with plain device-local gathers; runs on CPU-only
               single-process hosts with no mesh at all.

Every backend also carries a **wire format** (``wire_format="raw" |
"varint"``, selected via ``EngineConfig.wire_format`` / ``--wire``): with
``"varint"`` the engine stages hand ``a2a``/``a2a_tree`` the coded ``uint8``
streams plus per-lane byte lengths from :mod:`repro.core.wire` instead of
the raw int32 slabs, and the ``bytes_wire_fetch``/``bytes_wire_verify``
accounting sums the *actual* stream lengths
(:meth:`ExchangeBackend.off_device_payload_bytes`) rather than the modeled
element sizes.  Results are wire-format-invariant (the codecs are exact).

New backends register with ``@register_exchange_backend("name")``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import ClassVar

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro import compat


# --------------------------------------------------------------------------- #
# Backend registry
# --------------------------------------------------------------------------- #
@dataclass(frozen=True)
class ExchangeBackend:
    """Base class: collectives over the stacked ``(ndev, ...)`` layout."""

    mode: ClassVar[str] = "abstract"

    mesh: Mesh | None = None
    axis: str = "data"
    wire_format: str = "raw"   # 'raw' int32 slabs | 'varint' coded u8 streams

    def a2a(self, x: jnp.ndarray) -> jnp.ndarray:
        """x: (ndev_src, ndev_dst, ...) -> out[t, s] = x[s, t]."""
        raise NotImplementedError

    def a2a_tree(self, tree):
        """``a2a`` mapped over an arbitrary pytree of (ndev, ndev, ...)
        buffers — the engine stages exchange whole sub-states (e.g. the
        verifyE (a, b) request pair) in one call so a backend can fuse or
        coalesce the flight however it likes."""
        return compat.tree_map(self.a2a, tree)

    def all_reduce_sum(self, x: jnp.ndarray) -> jnp.ndarray:
        """x: (ndev, ...) -> summed-over-devices, broadcast back."""
        raise NotImplementedError

    def off_device_bytes(self, counts: jnp.ndarray,
                         elem_bytes: float) -> jnp.ndarray:
        """Wire bytes implied by a per-peer request count matrix.

        ``counts[t, p]`` = entries device ``t`` sends to peer ``p``; the
        diagonal (self-traffic) is free on every backend.  All built-in
        backends share this *logical* accounting — sim and gather report
        the bytes the spmd path would put on the wire, so stats stay
        comparable when swapping backends.
        """
        ndev = counts.shape[0]
        off = counts * (1 - jnp.eye(ndev, dtype=counts.dtype))
        return off.sum().astype(jnp.float32) * elem_bytes

    def off_device_payload_bytes(self, byte_matrix: jnp.ndarray
                                 ) -> jnp.ndarray:
        """Like :meth:`off_device_bytes` for *pre-summed* per-peer byte
        matrices (``byte_matrix[t, p]`` = payload bytes ``t`` sends to
        ``p``) — used when entries have variable size, e.g. the modeled
        delta+varint coding of fetchV id payloads.  The diagonal
        (self-traffic) is free, identically on every built-in backend."""
        ndev = byte_matrix.shape[0]
        off = byte_matrix * (1 - jnp.eye(ndev, dtype=byte_matrix.dtype))
        return off.sum().astype(jnp.float32)


_BACKENDS: dict[str, type[ExchangeBackend]] = {}


def register_exchange_backend(name: str):
    """Class decorator: make ``Exchange(name)`` resolve to this backend."""
    def deco(cls: type[ExchangeBackend]) -> type[ExchangeBackend]:
        cls.mode = name
        _BACKENDS[name] = cls
        return cls
    return deco


def exchange_backends() -> tuple[str, ...]:
    """Registered backend names (sorted)."""
    return tuple(sorted(_BACKENDS))


def Exchange(mode: str = "sim", mesh: Mesh | None = None,
             axis: str = "data", wire_format: str = "raw") -> ExchangeBackend:
    """Factory kept name-compatible with the old two-branch dataclass:
    ``Exchange("sim")`` / ``Exchange(mode="spmd", mesh=mesh)``.
    ``wire_format`` selects the on-the-wire payload coding (see module
    docstring); it is transport-independent, so every backend supports
    both."""
    try:
        cls = _BACKENDS[mode]
    except KeyError:
        raise ValueError(
            f"unknown exchange mode {mode!r}; registered backends: "
            f"{list(exchange_backends())}") from None
    if wire_format not in ("raw", "varint"):
        raise ValueError(
            f"unknown wire format {wire_format!r}; expected 'raw' or "
            f"'varint'")
    return cls(mesh=mesh, axis=axis, wire_format=wire_format)


# --------------------------------------------------------------------------- #
# Built-in backends
# --------------------------------------------------------------------------- #
@register_exchange_backend("sim")
@dataclass(frozen=True)
class SimExchange(ExchangeBackend):
    """Single-device reference: the all-to-all is an axis swap."""

    def a2a(self, x: jnp.ndarray) -> jnp.ndarray:
        return jnp.swapaxes(x, 0, 1)

    def all_reduce_sum(self, x: jnp.ndarray) -> jnp.ndarray:
        return jnp.broadcast_to(x.sum(axis=0, keepdims=True), x.shape)


@register_exchange_backend("gather")
@dataclass(frozen=True)
class GatherExchange(ExchangeBackend):
    """Device-local gathers, no mesh, no collectives.

    Semantically identical to ``sim`` (both realize the exact transpose
    protocol) but lowers to per-destination gathers — the shape a real
    RDMA/queue-pair transport would take on a CPU-only single-process
    host, and a third registry entry proving backends are pluggable."""

    def a2a(self, x: jnp.ndarray) -> jnp.ndarray:
        ndev = x.shape[0]
        # destination t gathers its column from every source's row
        return jax.vmap(lambda t: jnp.take(x, t, axis=1))(jnp.arange(ndev))

    def all_reduce_sum(self, x: jnp.ndarray) -> jnp.ndarray:
        total = x.sum(axis=0)
        return jax.vmap(lambda _: total)(jnp.arange(x.shape[0]))


@register_exchange_backend("spmd")
@dataclass(frozen=True)
class SpmdExchange(ExchangeBackend):
    """Production path: leading axis sharded over ``mesh[axis]``; exchanges
    are real collectives under ``shard_map``."""

    def __post_init__(self):
        if self.mesh is None:
            raise ValueError("spmd exchange needs a mesh")

    def _spec(self, ndim: int) -> P:
        return P(self.axis, *([None] * (ndim - 1)))

    def a2a(self, x: jnp.ndarray) -> jnp.ndarray:
        def body(xl):  # (1, ndev, ...)
            out = jax.lax.all_to_all(xl[0], self.axis, split_axis=0,
                                     concat_axis=0, tiled=True)
            return out[None]

        spec = self._spec(x.ndim)
        return compat.shard_map(body, mesh=self.mesh, in_specs=spec,
                                out_specs=spec)(x)

    def all_reduce_sum(self, x: jnp.ndarray) -> jnp.ndarray:
        def body(xl):
            return jax.lax.psum(xl, self.axis)

        spec = self._spec(x.ndim)
        return compat.shard_map(body, mesh=self.mesh, in_specs=spec,
                                out_specs=spec)(x)


# --------------------------------------------------------------------------- #
# Static-shape primitives shared by the engines
# --------------------------------------------------------------------------- #
def compact(mask: jnp.ndarray, cap_out: int, *arrays: jnp.ndarray,
            fill: int = 0, fills: tuple | None = None) -> tuple:
    """Stable-compact rows where ``mask`` is True into ``cap_out`` slots.

    Returns (new_mask (cap_out,), overflow (bool), *gathered arrays). Rows
    beyond cap_out are dropped and flagged.  Per-device (no leading axis).
    ``fills`` overrides ``fill`` per array (one entry per array) so
    heterogeneous columns — ids, flags, payload rows — share one argsort.
    """
    n = mask.shape[0]
    order = jnp.argsort(~mask, stable=True)
    take = order[:cap_out] if cap_out <= n else jnp.pad(
        order, (0, cap_out - n), constant_values=n - 1)
    count = mask.sum()
    new_mask = jnp.arange(cap_out) < jnp.minimum(count, cap_out)
    overflow = count > cap_out
    if fills is None:
        fills = (fill,) * len(arrays)
    outs = []
    for a, fl in zip(arrays, fills):
        g = a[take]
        g = jnp.where(
            new_mask.reshape((-1,) + (1,) * (g.ndim - 1)), g, fl)
        outs.append(g)
    return (new_mask, overflow, *outs)


def membership(sorted_rows: jnp.ndarray, vals: jnp.ndarray) -> jnp.ndarray:
    """sorted_rows (R, M) ascending (sentinel-padded), vals (R, K) ->
    bool (R, K): vals[r, k] in sorted_rows[r]."""
    idx = jax.vmap(jnp.searchsorted)(sorted_rows, vals)
    idx = jnp.clip(idx, 0, sorted_rows.shape[-1] - 1)
    found = jnp.take_along_axis(sorted_rows, idx, axis=-1) == vals
    return found


def unique_ids(ids: jnp.ndarray, mask: jnp.ndarray, sentinel: int
               ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Sorted-unique of masked ids. Returns (uids (n,), umask (n,)) with
    invalid slots pushed to the back as ``sentinel``. Output length == input
    (a unique id count never exceeds the input count)."""
    x = jnp.where(mask, ids, sentinel)
    xs = jnp.sort(x)
    first = jnp.concatenate([jnp.array([True]), xs[1:] != xs[:-1]])
    valid = first & (xs < sentinel)
    order = jnp.argsort(~valid, stable=True)
    uids = jnp.where(jnp.arange(x.shape[0]) < valid.sum(), xs[order], sentinel)
    umask = jnp.arange(x.shape[0]) < valid.sum()
    return uids, umask


def unique_pairs(a: jnp.ndarray, b: jnp.ndarray, mask: jnp.ndarray,
                 sentinel: int) -> tuple:
    """Dedup (a, b) pairs without 64-bit keys (EVI, Def. 5).

    Returns (ua, ub, umask, rank) where (ua[j], ub[j]) are the unique pairs
    (sorted lexicographically, invalid at the back) and rank[i] gives the
    unique-slot of input pair i (undefined where ~mask, but always a safe
    index in [0, n)). Output length == input length."""
    n = a.shape[0]
    av = jnp.where(mask, a, sentinel)
    bv = jnp.where(mask, b, sentinel)
    order = jnp.lexsort((bv, av))
    a_s, b_s = av[order], bv[order]
    first = jnp.concatenate(
        [jnp.array([True]), (a_s[1:] != a_s[:-1]) | (b_s[1:] != b_s[:-1])])
    valid_s = first & (a_s < sentinel)
    # group id (in sorted order) and unique slot of each group's head
    grp = jnp.cumsum(first) - 1
    uniq_slot_of_grp = jnp.cumsum(valid_s) - 1
    # scatter unique pairs
    ucount = valid_s.sum()
    slot = jnp.where(valid_s, uniq_slot_of_grp, n - 1)
    ua = jnp.full((n,), sentinel, dtype=a.dtype).at[slot].set(
        jnp.where(valid_s, a_s, sentinel), mode="drop")
    ub = jnp.full((n,), sentinel, dtype=b.dtype).at[slot].set(
        jnp.where(valid_s, b_s, sentinel), mode="drop")
    umask = jnp.arange(n) < ucount
    # rank per input: per-group table of head slots, then invert the sort
    slot_of_grp = jnp.zeros((n,), dtype=jnp.int32).at[grp].max(
        jnp.where(first, uniq_slot_of_grp, 0).astype(jnp.int32), mode="drop")
    rank_sorted = slot_of_grp[grp]
    inv = jnp.zeros((n,), dtype=jnp.int32).at[order].set(
        jnp.arange(n, dtype=jnp.int32))
    rank = rank_sorted[inv]
    return ua, ub, umask, rank
