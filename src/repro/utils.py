"""Small shared utilities (no jax imports at module scope beyond jax itself)."""
from __future__ import annotations

import dataclasses
import json
import math
import os
import time
from typing import Any, Callable, Iterable


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    return cdiv(a, b) * b


def human_bytes(n: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB", "TiB"):
        if abs(n) < 1024.0:
            return f"{n:,.2f} {unit}"
        n /= 1024.0
    return f"{n:,.2f} PiB"


def human_count(n: float) -> str:
    for unit in ("", "K", "M", "B", "T"):
        if abs(n) < 1000.0:
            return f"{n:,.2f}{unit}"
        n /= 1000.0
    return f"{n:,.2f}Q"


class Timer:
    """Context-manager wall timer."""

    def __init__(self, name: str = ""):
        self.name = name
        self.elapsed = 0.0

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.elapsed = time.perf_counter() - self._t0
        return False


def asdict_shallow(obj: Any) -> dict:
    if dataclasses.is_dataclass(obj):
        return {f.name: getattr(obj, f.name) for f in dataclasses.fields(obj)}
    raise TypeError(obj)


def dump_json(path: str, obj: Any) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)

    def default(o):
        if dataclasses.is_dataclass(o):
            return dataclasses.asdict(o)
        if hasattr(o, "tolist"):
            return o.tolist()
        return str(o)

    with open(path, "w") as f:
        json.dump(obj, f, indent=2, default=default, sort_keys=True)


def load_json(path: str) -> Any:
    with open(path) as f:
        return json.load(f)


def fmt_table(rows: Iterable[Iterable[Any]], header: list[str] | None = None) -> str:
    rows = [[str(c) for c in r] for r in rows]
    if header:
        rows = [list(header)] + rows
    if not rows:
        return ""
    widths = [max(len(r[i]) for r in rows) for i in range(len(rows[0]))]
    lines = []
    for ri, r in enumerate(rows):
        lines.append("  ".join(c.ljust(w) for c, w in zip(r, widths)))
        if header and ri == 0:
            lines.append("  ".join("-" * w for w in widths))
    return "\n".join(lines)
