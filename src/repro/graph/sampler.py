"""Neighbor sampler for sampled-training GNN shapes (``minibatch_lg``).

GraphSAGE-style fanout sampling: for a seed batch, sample ``fanout[h]``
neighbors per node per hop, emitting a *fixed-shape padded subgraph*
(static shapes for jit): node list, edge (src,dst) pairs into the local
node numbering, and a validity mask. This is a real sampler (uniform
without replacement when degree allows), not a stub.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.graph.storage import Graph


@dataclass
class SampledSubgraph:
    nodes: np.ndarray       # (max_nodes,) int32 global ids (padded with -1)
    n_nodes: int
    edge_src: np.ndarray    # (max_edges,) int32 local index
    edge_dst: np.ndarray    # (max_edges,) int32 local index
    edge_mask: np.ndarray   # (max_edges,) bool
    seed_mask: np.ndarray   # (max_nodes,) bool — True for the seed batch rows

    @property
    def max_nodes(self) -> int:
        return int(self.nodes.shape[0])

    @property
    def max_edges(self) -> int:
        return int(self.edge_src.shape[0])


def sample_capacities(batch_nodes: int, fanout: tuple[int, ...]) -> tuple[int, int]:
    """Static (max_nodes, max_edges) for a given batch/fanout — shared by the
    sampler and the dry-run input_specs."""
    layer = batch_nodes
    max_nodes = batch_nodes
    max_edges = 0
    for f in fanout:
        max_edges += layer * f
        layer = layer * f
        max_nodes += layer
    return max_nodes, max_edges


def sample_neighbors(graph: Graph, seeds: np.ndarray, fanout: tuple[int, ...],
                     rng: np.random.Generator) -> SampledSubgraph:
    seeds = np.asarray(seeds, dtype=np.int64)
    max_nodes, max_edges = sample_capacities(len(seeds), fanout)

    node_of: dict[int, int] = {}
    nodes: list[int] = []

    def local(v: int) -> int:
        if v not in node_of:
            node_of[v] = len(nodes)
            nodes.append(v)
        return node_of[v]

    for s in seeds:
        local(int(s))
    edge_src: list[int] = []
    edge_dst: list[int] = []
    frontier = [int(s) for s in seeds]
    for f in fanout:
        nxt: list[int] = []
        for u in frontier:
            nbrs = graph.neighbors(u)
            if len(nbrs) == 0:
                continue
            if len(nbrs) <= f:
                pick = nbrs
            else:
                pick = rng.choice(nbrs, size=f, replace=False)
            lu = local(u)
            for w in pick:
                lw = local(int(w))
                # message flows neighbor -> node being updated
                edge_src.append(lw)
                edge_dst.append(lu)
                nxt.append(int(w))
        frontier = nxt

    n_nodes = len(nodes)
    n_edges = len(edge_src)
    nodes_arr = np.full(max_nodes, -1, dtype=np.int32)
    nodes_arr[:n_nodes] = np.asarray(nodes, dtype=np.int32)
    src = np.zeros(max_edges, dtype=np.int32)
    dst = np.zeros(max_edges, dtype=np.int32)
    mask = np.zeros(max_edges, dtype=bool)
    src[:n_edges] = edge_src
    dst[:n_edges] = edge_dst
    mask[:n_edges] = True
    seed_mask = np.zeros(max_nodes, dtype=bool)
    seed_mask[:len(seeds)] = True
    return SampledSubgraph(nodes=nodes_arr, n_nodes=n_nodes, edge_src=src,
                           edge_dst=dst, edge_mask=mask, seed_mask=seed_mask)
