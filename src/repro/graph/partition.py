"""Vertex partitioners.

The paper partitions with Metis (multilevel k-way). Offline stand-ins:

* ``block``    — contiguous id blocks (good for lattice/road graphs whose ids
                 are already spatial).
* ``bfs``      — Metis-lite: grow ``ndev`` regions by round-robin BFS from
                 spread-out seeds; minimizes cut on community graphs without
                 external deps.
* ``hash``     — worst-case scatter (ablation baseline: maximal cut).
"""
from __future__ import annotations

import numpy as np

from repro.graph.storage import (DeviceGraph, Graph, PartitionedGraph,
                                 build_partitioned, device_graph)


def assign_block(graph: Graph, ndev: int) -> np.ndarray:
    per = -(-graph.n // ndev)
    return (np.arange(graph.n) // per).astype(np.int32)


def assign_hash(graph: Graph, ndev: int) -> np.ndarray:
    # splitmix-style integer hash for a deterministic scatter
    v = np.arange(graph.n, dtype=np.uint64)
    v = (v ^ (v >> 30)) * np.uint64(0xBF58476D1CE4E5B9)
    v = (v ^ (v >> 27)) * np.uint64(0x94D049BB133111EB)
    v = v ^ (v >> 31)
    return (v % np.uint64(ndev)).astype(np.int32)


def assign_bfs(graph: Graph, ndev: int, seed: int = 0) -> np.ndarray:
    """Round-robin multi-seed BFS growth with per-part capacity (Metis-lite)."""
    n = graph.n
    rng = np.random.default_rng(seed)
    cap = -(-n // ndev)
    assignment = np.full(n, -1, dtype=np.int32)
    # spread seeds: random start, then farthest-point-ish via BFS layers
    seeds = [int(rng.integers(n))]
    dist = np.full(n, np.iinfo(np.int32).max, dtype=np.int64)
    for _ in range(ndev - 1):
        frontier = [seeds[-1]]
        dist[seeds[-1]] = 0
        d = 0
        while frontier:
            d += 1
            nxt = []
            for u in frontier:
                for w in graph.neighbors(u):
                    if dist[w] > d:
                        dist[w] = d
                        nxt.append(int(w))
            frontier = nxt
        seeds.append(int(np.argmax(dist)))
    counts = np.zeros(ndev, dtype=np.int64)
    frontiers: list[list[int]] = [[] for _ in range(ndev)]
    for t, s in enumerate(seeds):
        if assignment[s] < 0:
            assignment[s] = t
            counts[t] += 1
            frontiers[t] = [s]
    # round-robin growth
    active = True
    while active:
        active = False
        for t in range(ndev):
            if counts[t] >= cap or not frontiers[t]:
                continue
            nxt: list[int] = []
            for u in frontiers[t]:
                for w in graph.neighbors(u):
                    if assignment[w] < 0 and counts[t] < cap:
                        assignment[w] = t
                        counts[t] += 1
                        nxt.append(int(w))
            frontiers[t] = nxt
            if nxt:
                active = True
    # orphans (disconnected remainder): fill least-loaded parts
    for v in np.flatnonzero(assignment < 0):
        t = int(np.argmin(counts))
        assignment[v] = t
        counts[t] += 1
    return assignment


_METHODS = {"block": assign_block, "hash": assign_hash, "bfs": assign_bfs}


def partition(graph: Graph, ndev: int, method: str = "bfs",
              max_degree: int | None = None, **kw) -> PartitionedGraph:
    if method not in _METHODS:
        raise KeyError(f"unknown partition method {method!r}: {list(_METHODS)}")
    assignment = _METHODS[method](graph, ndev, **kw) if method == "bfs" \
        else _METHODS[method](graph, ndev)
    return build_partitioned(graph, ndev, assignment, max_degree=max_degree)


def partition_device(graph: Graph, ndev: int, method: str = "bfs",
                     fmt: str = "dense", max_degree: int | None = None,
                     **kw) -> tuple[PartitionedGraph, DeviceGraph]:
    """Partition and export in one go: the host-side partition plus its
    on-device adjacency in the registered storage format ``fmt``."""
    pg = partition(graph, ndev, method=method, max_degree=max_degree, **kw)
    return pg, device_graph(pg, fmt)


def edge_cut(graph: Graph, assignment: np.ndarray) -> float:
    """Fraction of edges crossing partitions (quality metric)."""
    e = graph.edge_array()
    cut = np.count_nonzero(assignment[e[:, 0]] != assignment[e[:, 1]])
    return cut / max(len(e), 1)
