"""Synthetic graph generators — offline stand-ins for the paper's datasets
(RoadNet / DBLP / LiveJournal / UK2002; see DESIGN.md §5) plus GNN-shape
graphs (cora-like, products-like, molecule batches) and GraphCast's
icosahedral multi-mesh.
"""
from __future__ import annotations

import numpy as np

from repro.graph.storage import Graph


def road_graph(n: int = 4096, seed: int = 0) -> Graph:
    """RoadNet stand-in: sqrt(n) x sqrt(n) lattice with a few shortcuts.

    Avg degree ~2-4 and diameter O(sqrt(n)) — like a road network, most
    vertices sit far from any partition border (SM-E heaven).
    """
    side = int(np.sqrt(n))
    n = side * side
    rng = np.random.default_rng(seed)
    idx = np.arange(n).reshape(side, side)
    e = []
    e.append(np.stack([idx[:, :-1].ravel(), idx[:, 1:].ravel()], 1))
    e.append(np.stack([idx[:-1, :].ravel(), idx[1:, :].ravel()], 1))
    # sparse shortcuts (bridges/ramps): 1% of n
    k = max(n // 100, 1)
    extra = rng.integers(0, n, size=(k, 2))
    edges = np.concatenate(e + [extra], axis=0)
    return Graph.from_edges(n, edges)


def powerlaw_graph(n: int, avg_deg: int, seed: int = 0) -> Graph:
    """Barabasi-Albert-style preferential attachment (social/web stand-in)."""
    m = max(avg_deg // 2, 1)
    rng = np.random.default_rng(seed)
    edges = []
    targets = list(range(m))          # initial clique-ish core
    repeated: list[int] = list(range(m))
    for v in range(m, n):
        # preferential: sample from the repeated-endpoint pool
        pool = np.array(repeated, dtype=np.int64)
        tg = rng.choice(pool, size=m, replace=True)
        tg = np.unique(tg)
        for t in tg:
            edges.append((v, int(t)))
            repeated.append(int(t))
            repeated.append(v)
    return Graph.from_edges(n, np.array(edges, dtype=np.int64))


def erdos_graph(n: int, avg_deg: float, seed: int = 0) -> Graph:
    rng = np.random.default_rng(seed)
    n_edges = int(n * avg_deg / 2)
    edges = rng.integers(0, n, size=(n_edges, 2))
    return Graph.from_edges(n, edges)


def community_graph(n: int, n_comm: int, p_in_deg: float, p_out_deg: float,
                    seed: int = 0) -> Graph:
    """DBLP-like: dense communities + sparse cross edges."""
    rng = np.random.default_rng(seed)
    comm = rng.integers(0, n_comm, size=n)
    edges = []
    n_in = int(n * p_in_deg / 2)
    order = np.argsort(comm)
    bounds = np.searchsorted(comm[order], np.arange(n_comm + 1))
    for c in range(n_comm):
        mem = order[bounds[c]:bounds[c + 1]]
        if len(mem) < 2:
            continue
        k = max(int(len(mem) * p_in_deg / 2), 1)
        e = rng.choice(mem, size=(k, 2))
        edges.append(e)
    k_out = max(int(n * p_out_deg / 2), 1)
    edges.append(rng.integers(0, n, size=(k_out, 2)))
    return Graph.from_edges(n, np.concatenate(edges))


def molecule_batch(batch: int, n_nodes: int = 30, n_edges: int = 64,
                   seed: int = 0) -> Graph:
    """``batch`` disjoint small molecules packed in one graph (batched-small)."""
    rng = np.random.default_rng(seed)
    edges = []
    for b in range(batch):
        base = b * n_nodes
        # random spanning chain + extra bonds, degree <= 4 (chemistry-ish)
        chain = np.stack([np.arange(n_nodes - 1), np.arange(1, n_nodes)], 1)
        extra = rng.integers(0, n_nodes, size=(max(n_edges // 2 - (n_nodes - 1), 0), 2))
        e = np.concatenate([chain, extra]) + base
        edges.append(e)
    return Graph.from_edges(batch * n_nodes, np.concatenate(edges))


def icosahedral_mesh(refinement: int) -> tuple[np.ndarray, np.ndarray]:
    """GraphCast multi-mesh: icosahedron refined ``refinement`` times.

    Returns (vertices (V,3) float32 on unit sphere, multi-mesh undirected
    edge list (E,2) — union of edges of *all* refinement levels, as in
    GraphCast).
    """
    phi = (1 + np.sqrt(5)) / 2
    verts = np.array(
        [(-1, phi, 0), (1, phi, 0), (-1, -phi, 0), (1, -phi, 0),
         (0, -1, phi), (0, 1, phi), (0, -1, -phi), (0, 1, -phi),
         (phi, 0, -1), (phi, 0, 1), (-phi, 0, -1), (-phi, 0, 1)],
        dtype=np.float64)
    verts /= np.linalg.norm(verts, axis=1, keepdims=True)
    faces = np.array(
        [(0, 11, 5), (0, 5, 1), (0, 1, 7), (0, 7, 10), (0, 10, 11),
         (1, 5, 9), (5, 11, 4), (11, 10, 2), (10, 7, 6), (7, 1, 8),
         (3, 9, 4), (3, 4, 2), (3, 2, 6), (3, 6, 8), (3, 8, 9),
         (4, 9, 5), (2, 4, 11), (6, 2, 10), (8, 6, 7), (9, 8, 1)],
        dtype=np.int64)

    def face_edges(fs):
        e = np.concatenate([fs[:, [0, 1]], fs[:, [1, 2]], fs[:, [2, 0]]])
        return e

    all_edges = [face_edges(faces)]
    vlist = [verts]
    cache: dict[tuple[int, int], int] = {}

    def midpoint(a: int, b: int) -> int:
        key = (min(a, b), max(a, b))
        if key in cache:
            return cache[key]
        m = vlist[0][a] + vlist[0][b]
        m /= np.linalg.norm(m)
        vlist[0] = np.concatenate([vlist[0], m[None]], axis=0)
        cache[key] = len(vlist[0]) - 1
        return cache[key]

    for _ in range(refinement):
        new_faces = []
        for (a, b, c) in faces:
            ab, bc, ca = midpoint(a, b), midpoint(b, c), midpoint(c, a)
            new_faces += [(a, ab, ca), (b, bc, ab), (c, ca, bc), (ab, bc, ca)]
        faces = np.array(new_faces, dtype=np.int64)
        all_edges.append(face_edges(faces))

    edges = np.unique(np.sort(np.concatenate(all_edges), axis=1), axis=0)
    return vlist[0].astype(np.float32), edges


def make_dataset(kind: str, **kw) -> Graph:
    if kind == "road":
        return road_graph(**kw)
    if kind == "powerlaw":
        return powerlaw_graph(**kw)
    if kind == "erdos":
        return erdos_graph(**kw)
    if kind == "community":
        return community_graph(**kw)
    if kind == "molecule":
        return molecule_batch(**kw)
    raise KeyError(kind)


def load_dataset(name: str) -> Graph:
    from repro.configs.rads import DATASETS
    spec = dict(DATASETS[name])
    return make_dataset(spec.pop("kind"), **spec)
