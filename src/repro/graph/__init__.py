from repro.graph.storage import (Graph, PartitionedGraph, build_partitioned,
                                 DeviceGraph, DenseDeviceGraph,
                                 BucketedDeviceGraph, device_graph,
                                 device_formats, register_device_format)
from repro.graph.partition import partition, partition_device, edge_cut
from repro.graph.generators import (road_graph, powerlaw_graph, erdos_graph,
                                    community_graph, molecule_batch,
                                    icosahedral_mesh, make_dataset, load_dataset)
from repro.graph.sampler import SampledSubgraph, sample_neighbors, sample_capacities

__all__ = [
    "Graph", "PartitionedGraph", "build_partitioned", "partition", "edge_cut",
    "DeviceGraph", "DenseDeviceGraph", "BucketedDeviceGraph", "device_graph",
    "device_formats", "register_device_format", "partition_device",
    "road_graph", "powerlaw_graph", "erdos_graph", "community_graph",
    "molecule_batch", "icosahedral_mesh", "make_dataset", "load_dataset",
    "SampledSubgraph", "sample_neighbors", "sample_capacities",
]
