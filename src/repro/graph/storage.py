"""Graph storage: host-side CSR, device partitioning, and the pluggable
on-device adjacency formats (:class:`DeviceGraph`).

The data graph is undirected and unlabeled (paper §2). On host we keep a
numpy CSR with *sorted* adjacency rows (dedup'd, no self-loops) inside
:class:`Graph`; :func:`build_partitioned` renumbers vertices
device-contiguously into a :class:`PartitionedGraph` (ownership map,
border flags, border distances — §3.2 / Def. 1).

What actually lives on the accelerators is a :class:`DeviceGraph` — the
format-pluggable device-side adjacency the R-Meef engine reads.  Every
format exposes the same tiny device-side interface (``rows_at``/``deg_at``
over the stacked ``(ndev, ...)`` layout, sentinel ``n``-padded rows of
width ``max_degree``) so the engine stages, the exchange answer paths and
the scheduler are format-agnostic; formats register with
``@register_device_format(name)`` and are selected via
``EngineConfig.storage_format`` / ``device_graph(pg, fmt)``:

* ``dense``    — today's padded layout ``adj[dev, local_v, :max_degree]``;
  O(n_local × d_max) memory, one gather per row, and the bit-exact
  reference the other formats are tested against.
* ``bucketed`` — degree-bucketed padded CSR slabs: vertices are grouped
  into power-of-two degree buckets and each bucket is padded only to its
  own cap, so adjacency memory is ~O(Σ_b n_b · cap_b) ≈ O(2 · Σ deg(v))
  instead of O(n · d_max).  On power-law graphs (the "memory crisis" skew
  RADS is built to survive) this decouples the resident footprint from the
  single worst hub vertex; ``rows_at`` reassembles the dense sentinel-padded
  window on the fly, so results stay byte-identical to ``dense``.

Both formats are pytrees, so they pass straight through ``jax.jit`` /
``shard_map`` (the leading ``ndev`` axis shards via
:meth:`DeviceGraph.shard`); ``adj_bytes`` reports the resident adjacency
footprint (the ``peak_adj_bytes`` benchmark column).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import ClassVar

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class Graph:
    """Host-side undirected graph in CSR form (rows sorted ascending)."""

    n: int
    indptr: np.ndarray   # (n+1,) int64
    indices: np.ndarray  # (2E,) int32, row-sorted

    @property
    def n_edges(self) -> int:
        return int(self.indices.shape[0]) // 2

    @property
    def degrees(self) -> np.ndarray:
        return np.diff(self.indptr).astype(np.int32)

    @property
    def max_degree(self) -> int:
        return int(self.degrees.max()) if self.n else 0

    def neighbors(self, v: int) -> np.ndarray:
        return self.indices[self.indptr[v]:self.indptr[v + 1]]

    def has_edge(self, u: int, v: int) -> bool:
        row = self.neighbors(u)
        i = np.searchsorted(row, v)
        return bool(i < row.shape[0] and row[i] == v)

    def edge_array(self) -> np.ndarray:
        """(2E, 2) directed edge list (src, dst) — both directions present."""
        src = np.repeat(np.arange(self.n, dtype=np.int32), self.degrees)
        return np.stack([src, self.indices.astype(np.int32)], axis=1)

    @staticmethod
    def from_edges(n: int, edges: np.ndarray) -> "Graph":
        """Build from an (E, 2) array of undirected edges (any order/dups)."""
        edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
        # drop self loops, symmetrize, dedup
        edges = edges[edges[:, 0] != edges[:, 1]]
        both = np.concatenate([edges, edges[:, ::-1]], axis=0)
        key = both[:, 0] * n + both[:, 1]
        _, uniq = np.unique(key, return_index=True)
        both = both[uniq]
        order = np.lexsort((both[:, 1], both[:, 0]))
        both = both[order]
        indptr = np.zeros(n + 1, dtype=np.int64)
        np.add.at(indptr, both[:, 0] + 1, 1)
        indptr = np.cumsum(indptr)
        return Graph(n=n, indptr=indptr, indices=both[:, 1].astype(np.int32))


@dataclass
class PartitionedGraph:
    """Device-partitioned graph, padded for SPMD.

    All per-device arrays carry a leading ``ndev`` axis so they can be fed to
    ``shard_map`` sharded on that axis. Vertices are *globally renumbered* so
    that device t owns the contiguous id range [t*stride, t*stride + n_local[t])
    — the ownership map is then ``owner(v) = v // stride`` (one integer, even
    cheaper than the paper's one-byte-per-vertex map) and local index is
    ``v - t*stride``. ``old2new``/``new2old`` translate to original ids.
    """

    n: int                 # number of (renumbered) global vertices = ndev*stride
    n_real: int            # actual vertex count (n_real <= n; rest are padding)
    ndev: int
    stride: int            # owned id-range width per device
    max_degree: int
    adj: np.ndarray        # (ndev, stride, max_degree) int32, sentinel = n
    deg: np.ndarray        # (ndev, stride) int32
    n_local: np.ndarray    # (ndev,) int32 — real vertices per device
    border: np.ndarray     # (ndev, stride) bool — has a foreign neighbor
    border_dist: np.ndarray  # (ndev, stride) int32 — hops to nearest border vertex
    old2new: np.ndarray    # (n_real,) int32
    new2old: np.ndarray    # (n,) int32 (padding rows = -1)

    @property
    def sentinel(self) -> int:
        return self.n

    def owner(self, v: np.ndarray | int):
        return v // self.stride

    def global_deg(self) -> np.ndarray:
        return self.deg.reshape(-1)

    def neighbors(self, v: int) -> np.ndarray:
        t, i = divmod(int(v), self.stride)
        return self.adj[t, i, : self.deg[t, i]]

    def has_edge(self, u: int, v: int) -> bool:
        row = self.neighbors(u)
        j = np.searchsorted(row, v)
        return bool(j < row.shape[0] and row[j] == v)

    def to_device(self, fmt: str = "dense") -> "DeviceGraph":
        """Export this partition in a registered on-device format."""
        return device_graph(self, fmt)


def build_partitioned(graph: Graph, ndev: int, assignment: np.ndarray,
                      max_degree: int | None = None) -> PartitionedGraph:
    """Partition ``graph`` given a per-vertex device ``assignment`` (n,).

    Renumbers vertices device-contiguously, builds padded adjacency, border
    flags and the border-distance map (multi-source BFS inside each local
    subgraph — Definition 1).
    """
    n = graph.n
    assignment = np.asarray(assignment, dtype=np.int32)
    counts = np.bincount(assignment, minlength=ndev)
    stride = int(counts.max()) if n else 1
    stride = max(stride, 1)

    # renumber: vertices of device t -> [t*stride, t*stride+counts[t])
    order = np.argsort(assignment, kind="stable")
    old2new = np.empty(n, dtype=np.int32)
    offs = np.zeros(ndev + 1, dtype=np.int64)
    offs[1:] = np.cumsum(counts)
    for t in range(ndev):
        vs = order[offs[t]:offs[t + 1]]
        old2new[vs] = t * stride + np.arange(len(vs), dtype=np.int32)
    n_new = ndev * stride
    new2old = np.full(n_new, -1, dtype=np.int32)
    new2old[old2new] = np.arange(n, dtype=np.int32)

    md = max_degree if max_degree is not None else max(graph.max_degree, 1)
    adj = np.full((ndev, stride, md), n_new, dtype=np.int32)
    deg = np.zeros((ndev, stride), dtype=np.int32)
    border = np.zeros((ndev, stride), dtype=bool)

    for old_v in range(n):
        nv = int(old2new[old_v])
        t, i = divmod(nv, stride)
        nbrs = np.sort(old2new[graph.neighbors(old_v)]).astype(np.int32)
        d = len(nbrs)
        if d > md:
            raise ValueError(f"vertex degree {d} exceeds max_degree {md}")
        adj[t, i, :d] = nbrs
        deg[t, i] = d
        if d and (np.any(nbrs // stride != t)):
            border[t, i] = True

    border_dist = _border_distance(adj, deg, border, stride, n_new)
    return PartitionedGraph(
        n=n_new, n_real=n, ndev=ndev, stride=stride, max_degree=md,
        adj=adj, deg=deg, n_local=counts.astype(np.int32), border=border,
        border_dist=border_dist, old2new=old2new, new2old=new2old)


def _border_distance(adj: np.ndarray, deg: np.ndarray, border: np.ndarray,
                     stride: int, n_new: int) -> np.ndarray:
    """Multi-source BFS from border vertices over *local* edges (Def. 1).

    Non-border components with no border vertex get distance INF (2**30) —
    their seeds are always SM-E eligible.
    """
    ndev = adj.shape[0]
    INF = np.int32(1 << 30)
    out = np.full((ndev, stride), INF, dtype=np.int32)
    for t in range(ndev):
        dist = out[t]
        frontier = np.flatnonzero(border[t])
        dist[frontier] = 0
        d = 0
        while frontier.size:
            d += 1
            nxt = []
            for i in frontier:
                nbrs = adj[t, i, : deg[t, i]]
                local = nbrs[(nbrs // stride) == t] - t * stride
                fresh = local[dist[local] > d]
                dist[fresh] = d
                nxt.append(fresh)
            frontier = np.unique(np.concatenate(nxt)) if nxt else np.array([], np.int64)
    return out


# --------------------------------------------------------------------------- #
# DeviceGraph: pluggable on-device adjacency formats
# --------------------------------------------------------------------------- #
@dataclass(frozen=True)
class DeviceGraph:
    """Abstract on-device adjacency in the stacked ``(ndev, ...)`` layout.

    Concrete formats are registered pytrees: array leaves travel through
    ``jax.jit``/``vmap``/``shard_map`` while the four metadata ints ride in
    the static aux data (a shape change re-traces the engine stages, exactly
    like the old ``GraphMeta``).  The device-side contract every format must
    honour, for any leading index shape ``li``:

    * ``rows_at(t, li)``  -> ``(..., max_degree)`` int32 adjacency windows —
      sorted neighbor ids then sentinel ``n`` padding, *byte-identical*
      across formats (the engine's exchange payloads are built from these);
    * ``deg_at(t, li)``   -> ``(...,)`` int32 degrees.
    """

    format: ClassVar[str] = "abstract"
    # back-edge candidate refinement: False routes through the membership
    # lowering (the seed path), True through the sorted-window intersect
    # kernel (Alg. 1 line 6).  A per-format property so new registered
    # formats pick their kernel without touching the engine.
    intersect_backedge: ClassVar[bool] = False

    ndev: int
    stride: int
    n: int            # sentinel == n
    max_degree: int

    def rows_at(self, t, li) -> jnp.ndarray:
        raise NotImplementedError

    def deg_at(self, t, li) -> jnp.ndarray:
        raise NotImplementedError

    @property
    def adj_bytes(self) -> int:
        """Resident device adjacency footprint (all array leaves)."""
        leaves = jax.tree_util.tree_leaves(self)
        return int(sum(x.size * x.dtype.itemsize for x in leaves))

    def shard(self, mesh, axis: str = "data") -> "DeviceGraph":
        """Every leaf sharded on its leading ``ndev`` axis — through
        :func:`repro.compat.global_shard`, so a process-spanning mesh
        (the ``dist`` backend) assembles global arrays from per-process
        blocks while a local mesh stays a plain ``device_put``."""
        from repro import compat

        return compat.global_shard(self, mesh, axis)


_DEVICE_FORMATS: dict[str, type[DeviceGraph]] = {}


def register_device_format(name: str):
    """Class decorator: make ``device_graph(pg, name)`` resolve to this."""
    def deco(cls: type[DeviceGraph]) -> type[DeviceGraph]:
        cls.format = name
        _DEVICE_FORMATS[name] = cls
        return cls
    return deco


def device_formats() -> tuple[str, ...]:
    """Registered on-device adjacency format names (sorted)."""
    return tuple(sorted(_DEVICE_FORMATS))


def device_graph(pg: PartitionedGraph, fmt: str = "dense") -> DeviceGraph:
    """Export ``pg`` in the registered on-device format ``fmt``."""
    try:
        cls = _DEVICE_FORMATS[fmt]
    except KeyError:
        raise ValueError(
            f"unknown storage format {fmt!r}; registered formats: "
            f"{list(device_formats())}") from None
    return cls.from_partitioned(pg)


@register_device_format("dense")
@jax.tree_util.register_pytree_node_class
@dataclass(frozen=True)
class DenseDeviceGraph(DeviceGraph):
    """The seed layout: ``adj[dev, local_v, :max_degree]`` (bit-exact
    reference — O(n_local × d_max) memory, one gather per row)."""

    adj: jnp.ndarray   # (ndev, stride, max_degree) int32, sentinel = n
    deg: jnp.ndarray   # (ndev, stride) int32

    @classmethod
    def from_partitioned(cls, pg: PartitionedGraph) -> "DenseDeviceGraph":
        return cls(ndev=pg.ndev, stride=pg.stride, n=pg.n,
                   max_degree=pg.max_degree,
                   adj=jnp.asarray(pg.adj), deg=jnp.asarray(pg.deg))

    def rows_at(self, t, li):
        return self.adj[t][li]

    def deg_at(self, t, li):
        return self.deg[t][li]

    def tree_flatten(self):
        return ((self.adj, self.deg),
                (self.ndev, self.stride, self.n, self.max_degree))

    @classmethod
    def tree_unflatten(cls, aux, children):
        adj, deg = children
        return cls(*aux, adj=adj, deg=deg)


@register_device_format("bucketed")
@jax.tree_util.register_pytree_node_class
@dataclass(frozen=True)
class BucketedDeviceGraph(DeviceGraph):
    """Degree-bucketed padded CSR slabs.

    Vertices with ``deg > 0`` are grouped into power-of-two degree buckets
    (cap 1, 2, 4, ... — the top cap is clamped to ``max_degree``); bucket
    ``b`` stores one slab ``(ndev, n_b_max, cap_b)`` padded only to its own
    cap, plus O(n) per-vertex ``bucket_of``/``slot_of`` maps.  Adjacency
    memory is therefore ~O(Σ_b n_b · cap_b) — on skewed graphs a fraction
    of the dense O(n · d_max) — while ``rows_at`` reassembles the dense
    sentinel-padded window (so results stay byte-identical to ``dense``).
    Degree-0 and padding vertices own no slab row: their window is produced
    entirely by the degree mask.
    """

    intersect_backedge: ClassVar[bool] = True

    bucket_caps: tuple  # static: padded row width per bucket, ascending
    deg: jnp.ndarray        # (ndev, stride) int32
    bucket_of: jnp.ndarray  # (ndev, stride) int32 (0 where deg == 0)
    slot_of: jnp.ndarray    # (ndev, stride) int32 (0 where deg == 0)
    slabs: tuple            # per bucket: (ndev, n_b_max, cap_b) int32

    @classmethod
    def from_partitioned(cls, pg: PartitionedGraph) -> "BucketedDeviceGraph":
        ndev, stride, n, D = pg.ndev, pg.stride, pg.n, pg.max_degree
        deg = np.asarray(pg.deg, dtype=np.int32)
        real_max = int(deg.max()) if deg.size else 0
        caps: list[int] = []
        c = 1
        while c < max(real_max, 1):
            caps.append(c)
            c *= 2
        caps.append(min(c, D) if real_max else 1)
        caps_arr = np.asarray(caps, dtype=np.int32)

        bucket_of = np.zeros((ndev, stride), dtype=np.int32)
        slot_of = np.zeros((ndev, stride), dtype=np.int32)
        has_row = deg > 0
        bucket_of[has_row] = np.searchsorted(caps_arr, deg[has_row])
        counts = np.zeros((ndev, len(caps)), dtype=np.int64)
        for t in range(ndev):
            for b in range(len(caps)):
                members = np.flatnonzero(has_row[t] & (bucket_of[t] == b))
                slot_of[t, members] = np.arange(len(members), dtype=np.int32)
                counts[t, b] = len(members)

        slabs = []
        for b, cap in enumerate(caps):
            nb_max = max(int(counts[:, b].max()), 1)
            slab = np.full((ndev, nb_max, cap), n, dtype=np.int32)
            for t in range(ndev):
                members = np.flatnonzero(has_row[t] & (bucket_of[t] == b))
                if len(members):
                    slab[t, :len(members)] = pg.adj[t, members, :cap]
            slabs.append(jnp.asarray(slab))
        return cls(ndev=ndev, stride=stride, n=n, max_degree=D,
                   bucket_caps=tuple(caps), deg=jnp.asarray(deg),
                   bucket_of=jnp.asarray(bucket_of),
                   slot_of=jnp.asarray(slot_of), slabs=tuple(slabs))

    def rows_at(self, t, li):
        b = self.bucket_of[t][li]
        s = self.slot_of[t][li]
        d = self.deg[t][li]
        D = self.max_degree
        out = jnp.full(jnp.shape(li) + (D,), self.n, dtype=jnp.int32)
        for bi, cap in enumerate(self.bucket_caps):
            slab_t = self.slabs[bi][t]                       # (n_b_max, cap)
            row = slab_t[jnp.clip(s, 0, slab_t.shape[0] - 1)]
            if cap < D:
                pad = [(0, 0)] * (row.ndim - 1) + [(0, D - cap)]
                row = jnp.pad(row, pad, constant_values=self.n)
            else:
                row = row[..., :D]
            out = jnp.where((b == bi)[..., None], row, out)
        # degree mask: deg-0 / padding vertices never touch a slab row
        return jnp.where(jnp.arange(D) < d[..., None], out, self.n)

    def deg_at(self, t, li):
        return self.deg[t][li]

    def tree_flatten(self):
        return ((self.deg, self.bucket_of, self.slot_of, self.slabs),
                (self.ndev, self.stride, self.n, self.max_degree,
                 self.bucket_caps))

    @classmethod
    def tree_unflatten(cls, aux, children):
        ndev, stride, n, max_degree, bucket_caps = aux
        deg, bucket_of, slot_of, slabs = children
        return cls(ndev=ndev, stride=stride, n=n, max_degree=max_degree,
                   bucket_caps=bucket_caps, deg=deg, bucket_of=bucket_of,
                   slot_of=slot_of, slabs=tuple(slabs))
