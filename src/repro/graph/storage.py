"""Graph storage: host-side CSR + device-partitioned padded CSR.

The data graph is undirected and unlabeled (paper §2). On host we keep a
numpy CSR with *sorted* adjacency rows (dedup'd, no self-loops). For the
distributed engine each device partition is exported as dense padded
adjacency (``adj[dev, local_v, :max_degree]`` with sentinel ``n``) plus the
ownership map the paper assumes every machine holds (§3.2 Expand: "each
machine has a record of the ownership information ... of all the vertices").
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class Graph:
    """Host-side undirected graph in CSR form (rows sorted ascending)."""

    n: int
    indptr: np.ndarray   # (n+1,) int64
    indices: np.ndarray  # (2E,) int32, row-sorted

    @property
    def n_edges(self) -> int:
        return int(self.indices.shape[0]) // 2

    @property
    def degrees(self) -> np.ndarray:
        return np.diff(self.indptr).astype(np.int32)

    @property
    def max_degree(self) -> int:
        return int(self.degrees.max()) if self.n else 0

    def neighbors(self, v: int) -> np.ndarray:
        return self.indices[self.indptr[v]:self.indptr[v + 1]]

    def has_edge(self, u: int, v: int) -> bool:
        row = self.neighbors(u)
        i = np.searchsorted(row, v)
        return bool(i < row.shape[0] and row[i] == v)

    def edge_array(self) -> np.ndarray:
        """(2E, 2) directed edge list (src, dst) — both directions present."""
        src = np.repeat(np.arange(self.n, dtype=np.int32), self.degrees)
        return np.stack([src, self.indices.astype(np.int32)], axis=1)

    @staticmethod
    def from_edges(n: int, edges: np.ndarray) -> "Graph":
        """Build from an (E, 2) array of undirected edges (any order/dups)."""
        edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
        # drop self loops, symmetrize, dedup
        edges = edges[edges[:, 0] != edges[:, 1]]
        both = np.concatenate([edges, edges[:, ::-1]], axis=0)
        key = both[:, 0] * n + both[:, 1]
        _, uniq = np.unique(key, return_index=True)
        both = both[uniq]
        order = np.lexsort((both[:, 1], both[:, 0]))
        both = both[order]
        indptr = np.zeros(n + 1, dtype=np.int64)
        np.add.at(indptr, both[:, 0] + 1, 1)
        indptr = np.cumsum(indptr)
        return Graph(n=n, indptr=indptr, indices=both[:, 1].astype(np.int32))


@dataclass
class PartitionedGraph:
    """Device-partitioned graph, padded for SPMD.

    All per-device arrays carry a leading ``ndev`` axis so they can be fed to
    ``shard_map`` sharded on that axis. Vertices are *globally renumbered* so
    that device t owns the contiguous id range [t*stride, t*stride + n_local[t])
    — the ownership map is then ``owner(v) = v // stride`` (one integer, even
    cheaper than the paper's one-byte-per-vertex map) and local index is
    ``v - t*stride``. ``old2new``/``new2old`` translate to original ids.
    """

    n: int                 # number of (renumbered) global vertices = ndev*stride
    n_real: int            # actual vertex count (n_real <= n; rest are padding)
    ndev: int
    stride: int            # owned id-range width per device
    max_degree: int
    adj: np.ndarray        # (ndev, stride, max_degree) int32, sentinel = n
    deg: np.ndarray        # (ndev, stride) int32
    n_local: np.ndarray    # (ndev,) int32 — real vertices per device
    border: np.ndarray     # (ndev, stride) bool — has a foreign neighbor
    border_dist: np.ndarray  # (ndev, stride) int32 — hops to nearest border vertex
    old2new: np.ndarray    # (n_real,) int32
    new2old: np.ndarray    # (n,) int32 (padding rows = -1)

    @property
    def sentinel(self) -> int:
        return self.n

    def owner(self, v: np.ndarray | int):
        return v // self.stride

    def global_deg(self) -> np.ndarray:
        return self.deg.reshape(-1)

    def neighbors(self, v: int) -> np.ndarray:
        t, i = divmod(int(v), self.stride)
        return self.adj[t, i, : self.deg[t, i]]

    def has_edge(self, u: int, v: int) -> bool:
        row = self.neighbors(u)
        j = np.searchsorted(row, v)
        return bool(j < row.shape[0] and row[j] == v)


def build_partitioned(graph: Graph, ndev: int, assignment: np.ndarray,
                      max_degree: int | None = None) -> PartitionedGraph:
    """Partition ``graph`` given a per-vertex device ``assignment`` (n,).

    Renumbers vertices device-contiguously, builds padded adjacency, border
    flags and the border-distance map (multi-source BFS inside each local
    subgraph — Definition 1).
    """
    n = graph.n
    assignment = np.asarray(assignment, dtype=np.int32)
    counts = np.bincount(assignment, minlength=ndev)
    stride = int(counts.max()) if n else 1
    stride = max(stride, 1)

    # renumber: vertices of device t -> [t*stride, t*stride+counts[t])
    order = np.argsort(assignment, kind="stable")
    old2new = np.empty(n, dtype=np.int32)
    offs = np.zeros(ndev + 1, dtype=np.int64)
    offs[1:] = np.cumsum(counts)
    for t in range(ndev):
        vs = order[offs[t]:offs[t + 1]]
        old2new[vs] = t * stride + np.arange(len(vs), dtype=np.int32)
    n_new = ndev * stride
    new2old = np.full(n_new, -1, dtype=np.int32)
    new2old[old2new] = np.arange(n, dtype=np.int32)

    md = max_degree if max_degree is not None else max(graph.max_degree, 1)
    adj = np.full((ndev, stride, md), n_new, dtype=np.int32)
    deg = np.zeros((ndev, stride), dtype=np.int32)
    border = np.zeros((ndev, stride), dtype=bool)

    for old_v in range(n):
        nv = int(old2new[old_v])
        t, i = divmod(nv, stride)
        nbrs = np.sort(old2new[graph.neighbors(old_v)]).astype(np.int32)
        d = len(nbrs)
        if d > md:
            raise ValueError(f"vertex degree {d} exceeds max_degree {md}")
        adj[t, i, :d] = nbrs
        deg[t, i] = d
        if d and (np.any(nbrs // stride != t)):
            border[t, i] = True

    border_dist = _border_distance(adj, deg, border, stride, n_new)
    return PartitionedGraph(
        n=n_new, n_real=n, ndev=ndev, stride=stride, max_degree=md,
        adj=adj, deg=deg, n_local=counts.astype(np.int32), border=border,
        border_dist=border_dist, old2new=old2new, new2old=new2old)


def _border_distance(adj: np.ndarray, deg: np.ndarray, border: np.ndarray,
                     stride: int, n_new: int) -> np.ndarray:
    """Multi-source BFS from border vertices over *local* edges (Def. 1).

    Non-border components with no border vertex get distance INF (2**30) —
    their seeds are always SM-E eligible.
    """
    ndev = adj.shape[0]
    INF = np.int32(1 << 30)
    out = np.full((ndev, stride), INF, dtype=np.int32)
    for t in range(ndev):
        dist = out[t]
        frontier = np.flatnonzero(border[t])
        dist[frontier] = 0
        d = 0
        while frontier.size:
            d += 1
            nxt = []
            for i in frontier:
                nbrs = adj[t, i, : deg[t, i]]
                local = nbrs[(nbrs // stride) == t] - t * stride
                fresh = local[dist[local] > d]
                dist[fresh] = d
                nxt.append(fresh)
            frontier = np.unique(np.concatenate(nxt)) if nxt else np.array([], np.int64)
    return out
