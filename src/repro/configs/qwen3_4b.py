"""Qwen3-4B [hf:Qwen/Qwen3 family] — 36L d=2560 32H (GQA kv=8) d_ff=9728, qk_norm."""
from repro.configs.base import ArchConfig, LM_SHAPES, TransformerConfig, scaled_transformer

CONFIG = ArchConfig(
    arch_id="qwen3-4b",
    model=TransformerConfig(
        name="qwen3-4b",
        n_layers=36, d_model=2560, n_heads=32, n_kv_heads=8,
        d_ff=9728, vocab=151936, qk_norm=True, d_head=128,
        rope_theta=1e6, tie_embeddings=True,
    ),
    shapes=LM_SHAPES,
    notes="dense; qk-norm; GQA 32q/8kv; tied embeddings.",
)


def reduced() -> TransformerConfig:
    return scaled_transformer(CONFIG.model, n_layers=2, d_model=64, n_heads=8,
                              n_kv_heads=2, d_ff=128, vocab=256, d_head=8)
