"""DeepSeek-V3-671B [arXiv:2412.19437] — 61L d=7168 128H MLA, 1 shared + 256 routed
top-8 (aux-loss-free), d_expert=2048, first 3 layers dense (d_ff=18432), MTP depth 1."""
from repro.configs.base import (ArchConfig, LM_SHAPES, MLAConfig, MoEConfig,
                                TransformerConfig, scaled_transformer)

CONFIG = ArchConfig(
    arch_id="deepseek-v3-671b",
    model=TransformerConfig(
        name="deepseek-v3-671b",
        n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128,
        d_ff=18432, vocab=129280,
        mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                      qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
        moe=MoEConfig(n_experts=256, top_k=8, d_expert=2048, n_shared=1,
                      router_aux_free=True, first_k_dense=3, d_ff_dense=18432),
        mtp_depth=1,
    ),
    shapes=LM_SHAPES,
    notes="MLA + DeepSeekMoE; KV cache holds only (kv_lora_rank + rope) per token.",
)


def reduced() -> TransformerConfig:
    import dataclasses
    m = CONFIG.model
    return scaled_transformer(
        m, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=256,
        mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=16,
                      qk_rope_head_dim=8, v_head_dim=16),
        moe=dataclasses.replace(m.moe, n_experts=4, top_k=2, d_expert=32,
                                first_k_dense=1, d_ff_dense=128),
        mtp_depth=1,
    )
