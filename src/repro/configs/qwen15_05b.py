"""Qwen1.5-0.5B [hf:Qwen/Qwen1.5-0.5B] — 24L d=1024 16H kv=16 d_ff=2816, QKV bias."""
from repro.configs.base import ArchConfig, LM_SHAPES, TransformerConfig, scaled_transformer

CONFIG = ArchConfig(
    arch_id="qwen1.5-0.5b",
    model=TransformerConfig(
        name="qwen1.5-0.5b",
        n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
        d_ff=2816, vocab=151936, qkv_bias=True, tie_embeddings=True,
        rope_theta=1e6,
    ),
    shapes=LM_SHAPES,
    notes="dense; QKV bias; tied embeddings.",
)


def reduced() -> TransformerConfig:
    return scaled_transformer(CONFIG.model, n_layers=2, d_model=64, n_heads=4,
                              n_kv_heads=4, d_ff=128, vocab=256)
