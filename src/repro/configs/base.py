"""Config dataclasses + registry for every selectable architecture.

Each assigned architecture lives in ``src/repro/configs/<id>.py`` exposing a
module-level ``CONFIG: ArchConfig`` with the exact published dims, plus a
``reduced()`` config used by CPU smoke tests. The full configs are only ever
exercised through the dry-run (ShapeDtypeStruct — no allocation).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any


# --------------------------------------------------------------------------- #
# Shapes
# --------------------------------------------------------------------------- #
@dataclass(frozen=True)
class ShapeSpec:
    """One input-shape cell. ``kind`` selects which step gets lowered."""

    name: str
    kind: str  # train | prefill | decode | long_decode | full_graph | minibatch | serve | retrieval
    dims: dict[str, int] = field(default_factory=dict)

    def __getitem__(self, k: str) -> int:
        return self.dims[k]


LM_SHAPES = (
    ShapeSpec("train_4k", "train", {"seq_len": 4096, "global_batch": 256}),
    ShapeSpec("prefill_32k", "prefill", {"seq_len": 32768, "global_batch": 32}),
    ShapeSpec("decode_32k", "decode", {"seq_len": 32768, "global_batch": 128}),
    ShapeSpec("long_500k", "long_decode", {"seq_len": 524288, "global_batch": 1}),
)

GNN_SHAPES = (
    ShapeSpec("full_graph_sm", "full_graph",
              {"n_nodes": 2708, "n_edges": 10556, "d_feat": 1433}),
    ShapeSpec("minibatch_lg", "minibatch",
              {"n_nodes": 232965, "n_edges": 114615892, "batch_nodes": 1024,
               "fanout0": 15, "fanout1": 10, "d_feat": 602}),
    ShapeSpec("ogb_products", "full_graph",
              {"n_nodes": 2449029, "n_edges": 61859140, "d_feat": 100}),
    ShapeSpec("molecule", "batched_graphs",
              {"n_nodes": 30, "n_edges": 64, "batch": 128, "d_feat": 16}),
)

RECSYS_SHAPES = (
    ShapeSpec("train_batch", "train", {"batch": 65536}),
    ShapeSpec("serve_p99", "serve", {"batch": 512}),
    ShapeSpec("serve_bulk", "serve", {"batch": 262144}),
    ShapeSpec("retrieval_cand", "retrieval", {"batch": 1, "n_candidates": 1000000}),
)


# --------------------------------------------------------------------------- #
# Model configs
# --------------------------------------------------------------------------- #
@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int                 # per-expert FFN hidden dim
    n_shared: int = 0             # shared (always-on) experts
    capacity_factor: float = 1.25
    router_aux_free: bool = False  # DeepSeek-V3 aux-loss-free bias routing
    first_k_dense: int = 0        # leading dense layers (DeepSeek-V3: 3)
    d_ff_dense: int = 0           # FFN dim of those dense layers


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek multi-head latent attention dims."""

    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0               # 0 -> d_model // n_heads
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    moe: MoEConfig | None = None
    mla: MLAConfig | None = None
    mtp_depth: int = 0            # multi-token-prediction extra heads (DeepSeek-V3)
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    family: str = "lm"

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks), used for roofline."""
        d, L, V = self.d_model, self.n_layers, self.vocab
        emb = V * d * (1 if self.tie_embeddings else 2)
        if self.mla is not None:
            m = self.mla
            qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
            attn = (d * m.q_lora_rank + m.q_lora_rank * self.n_heads * qk_head
                    + d * (m.kv_lora_rank + m.qk_rope_head_dim)
                    + m.kv_lora_rank * self.n_heads * (m.qk_nope_head_dim + m.v_head_dim)
                    + self.n_heads * m.v_head_dim * d)
        else:
            hd = self.head_dim
            attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) \
                + (self.n_heads * hd) * d
        if self.moe is not None:
            mo = self.moe
            moe_ffn = 3 * d * mo.d_expert * (mo.n_experts + mo.n_shared) \
                + d * mo.n_experts
            dense_ffn = 3 * d * (mo.d_ff_dense or self.d_ff)
            ffn_total = (mo.first_k_dense * dense_ffn
                         + (L - mo.first_k_dense) * moe_ffn)
        else:
            ffn_total = L * 3 * d * self.d_ff
        return emb + L * attn + ffn_total + L * 2 * d

    def active_param_count(self) -> int:
        """Per-token active params (MoE: only routed top-k + shared)."""
        if self.moe is None:
            return self.param_count()
        d, L = self.d_model, self.n_layers
        mo = self.moe
        full = self.param_count()
        all_experts = (L - mo.first_k_dense) * 3 * d * mo.d_expert * mo.n_experts
        active_experts = (L - mo.first_k_dense) * 3 * d * mo.d_expert * mo.top_k
        return full - all_experts + active_experts


@dataclass(frozen=True)
class GNNConfig:
    name: str
    kind: str                     # graphcast | schnet | pna | gat
    n_layers: int
    d_hidden: int
    aggregator: str = "sum"
    n_heads: int = 1
    # schnet
    n_rbf: int = 0
    cutoff: float = 0.0
    # graphcast
    mesh_refinement: int = 0
    n_vars: int = 0
    # pna
    aggregators: tuple[str, ...] = ()
    scalers: tuple[str, ...] = ()
    n_classes: int = 47           # ogbn-products has 47 classes
    dtype: str = "bfloat16"
    family: str = "gnn"


@dataclass(frozen=True)
class RecsysConfig:
    name: str
    kind: str = "din"
    embed_dim: int = 18
    seq_len: int = 100
    attn_mlp: tuple[int, ...] = (80, 40)
    mlp: tuple[int, ...] = (200, 80)
    n_items: int = 50_000_000     # production-scale sparse table (rows)
    n_cates: int = 1_000_000
    n_user_feats: int = 8_000_000
    dtype: str = "bfloat16"
    family: str = "recsys"


ModelConfig = Any  # TransformerConfig | GNNConfig | RecsysConfig


@dataclass(frozen=True)
class ArchConfig:
    arch_id: str
    model: ModelConfig
    shapes: tuple[ShapeSpec, ...]
    notes: str = ""

    def shape(self, name: str) -> ShapeSpec:
        for s in self.shapes:
            if s.name == name:
                return s
        raise KeyError(f"{self.arch_id}: unknown shape {name!r}; "
                       f"have {[s.name for s in self.shapes]}")


def scaled_transformer(cfg: TransformerConfig, **over) -> TransformerConfig:
    return dataclasses.replace(cfg, **over)
