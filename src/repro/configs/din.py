"""DIN [arXiv:1706.06978] — embed_dim=18, hist seq 100, attn MLP 80-40, MLP 200-80,
target attention feature interaction. Production-scale sparse tables."""
import dataclasses

from repro.configs.base import ArchConfig, RECSYS_SHAPES, RecsysConfig

CONFIG = ArchConfig(
    arch_id="din",
    model=RecsysConfig(
        name="din", kind="din",
        embed_dim=18, seq_len=100, attn_mlp=(80, 40), mlp=(200, 80),
        n_items=50_000_000, n_cates=1_000_000, n_user_feats=8_000_000,
    ),
    shapes=RECSYS_SHAPES,
    notes="EmbeddingBag = take + segment_sum (row-sharded tables); "
          "retrieval_cand scores 1M candidates with one batched dot.",
)


def reduced() -> RecsysConfig:
    return dataclasses.replace(CONFIG.model, embed_dim=8, seq_len=12,
                               attn_mlp=(16, 8), mlp=(32, 16),
                               n_items=1000, n_cates=100, n_user_feats=200)
