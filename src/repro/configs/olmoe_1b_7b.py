"""OLMoE-1B-7B [arXiv:2409.02060] — 16L d=2048 16H (kv=16) MoE 64e top-8, d_expert=1024."""
from repro.configs.base import (ArchConfig, LM_SHAPES, MoEConfig, TransformerConfig,
                                scaled_transformer)

CONFIG = ArchConfig(
    arch_id="olmoe-1b-7b",
    model=TransformerConfig(
        name="olmoe-1b-7b",
        n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=1024, vocab=50304, qk_norm=True,
        moe=MoEConfig(n_experts=64, top_k=8, d_expert=1024),
    ),
    shapes=LM_SHAPES,
    notes="64-expert top-8 MoE; every layer MoE; GQA kv=16 (== MHA).",
)


def reduced() -> TransformerConfig:
    import dataclasses
    return scaled_transformer(
        CONFIG.model, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=256,
        moe=dataclasses.replace(CONFIG.model.moe, n_experts=4, top_k=2, d_expert=32),
    )
