"""PNA [arXiv:2004.05718] — 4 layers, d=75, aggregators mean/max/min/std,
scalers identity/amplification/attenuation."""
import dataclasses

from repro.configs.base import ArchConfig, GNN_SHAPES, GNNConfig

CONFIG = ArchConfig(
    arch_id="pna",
    model=GNNConfig(
        name="pna", kind="pna",
        n_layers=4, d_hidden=75,
        aggregators=("mean", "max", "min", "std"),
        scalers=("identity", "amplification", "attenuation"),
    ),
    shapes=GNN_SHAPES,
    notes="4 aggregators x 3 degree-scalers -> 12x towers -> linear mix.",
)


def reduced() -> GNNConfig:
    return dataclasses.replace(CONFIG.model, n_layers=2, d_hidden=16)
