"""Architecture registry: ``get_config(arch_id)`` / ``get_reduced(arch_id)``.

Assigned archs (10) + the paper's own engine config live here.
"""
from __future__ import annotations

import importlib

from repro.configs.base import (ArchConfig, GNNConfig, MLAConfig, MoEConfig,
                                RecsysConfig, ShapeSpec, TransformerConfig)

_ARCH_MODULES: dict[str, str] = {
    "olmoe-1b-7b": "repro.configs.olmoe_1b_7b",
    "deepseek-v3-671b": "repro.configs.deepseek_v3_671b",
    "qwen1.5-0.5b": "repro.configs.qwen15_05b",
    "qwen3-14b": "repro.configs.qwen3_14b",
    "qwen3-4b": "repro.configs.qwen3_4b",
    "graphcast": "repro.configs.graphcast",
    "schnet": "repro.configs.schnet",
    "pna": "repro.configs.pna",
    "gat-cora": "repro.configs.gat_cora",
    "din": "repro.configs.din",
}

ARCH_IDS: tuple[str, ...] = tuple(_ARCH_MODULES)


def get_config(arch_id: str) -> ArchConfig:
    if arch_id not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; available: {list(_ARCH_MODULES)}")
    return importlib.import_module(_ARCH_MODULES[arch_id]).CONFIG


def get_reduced(arch_id: str):
    if arch_id not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; available: {list(_ARCH_MODULES)}")
    return importlib.import_module(_ARCH_MODULES[arch_id]).reduced()


def all_cells() -> list[tuple[str, str]]:
    """Every (arch_id, shape_name) cell — 40 total."""
    cells = []
    for a in ARCH_IDS:
        for s in get_config(a).shapes:
            cells.append((a, s.name))
    return cells


__all__ = [
    "ArchConfig", "TransformerConfig", "GNNConfig", "RecsysConfig",
    "MoEConfig", "MLAConfig", "ShapeSpec",
    "ARCH_IDS", "get_config", "get_reduced", "all_cells",
]
