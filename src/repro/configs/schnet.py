"""SchNet [arXiv:1706.08566] — 3 interactions, d=64, 300 RBF, cutoff 10A."""
import dataclasses

from repro.configs.base import ArchConfig, GNN_SHAPES, GNNConfig

CONFIG = ArchConfig(
    arch_id="schnet",
    model=GNNConfig(
        name="schnet", kind="schnet",
        n_layers=3, d_hidden=64, aggregator="sum",
        n_rbf=300, cutoff=10.0,
    ),
    shapes=GNN_SHAPES,
    notes="continuous-filter conv: RBF(dist) -> filter MLP -> elementwise * gathered "
          "features -> segment_sum; positions synthesized for non-molecular graphs.",
)


def reduced() -> GNNConfig:
    return dataclasses.replace(CONFIG.model, n_layers=2, d_hidden=16, n_rbf=20)
