"""GAT [arXiv:1710.10903] — 2 layers, d_hidden=8 per head, 8 heads, attention agg."""
import dataclasses

from repro.configs.base import ArchConfig, GNN_SHAPES, GNNConfig

CONFIG = ArchConfig(
    arch_id="gat-cora",
    model=GNNConfig(
        name="gat-cora", kind="gat",
        n_layers=2, d_hidden=8, n_heads=8, aggregator="attn",
        n_classes=7,
    ),
    shapes=GNN_SHAPES,
    notes="SDDMM edge scores -> segment softmax -> SpMM; ELU between layers.",
)


def reduced() -> GNNConfig:
    return dataclasses.replace(CONFIG.model, n_layers=2, d_hidden=4, n_heads=2)
