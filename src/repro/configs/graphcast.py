"""GraphCast [arXiv:2212.12794] — encoder-processor-decoder mesh GNN.
16 processor layers, d_hidden=512, mesh_refinement=6, sum aggregator, 227 vars.

For the assigned (arch x shape) cells the processor runs over the *given* graph
(cora / reddit-minibatch / ogb-products / molecule batches); the icosahedral
multi-mesh generator is used by the graphcast example driver."""
import dataclasses

from repro.configs.base import ArchConfig, GNN_SHAPES, GNNConfig

CONFIG = ArchConfig(
    arch_id="graphcast",
    model=GNNConfig(
        name="graphcast", kind="graphcast",
        n_layers=16, d_hidden=512, aggregator="sum",
        mesh_refinement=6, n_vars=227,
    ),
    shapes=GNN_SHAPES,
    notes="encoder-processor-decoder interaction network; edge+node MLPs, residual.",
)


def reduced() -> GNNConfig:
    return dataclasses.replace(CONFIG.model, n_layers=2, d_hidden=32,
                               mesh_refinement=1, n_vars=8)
