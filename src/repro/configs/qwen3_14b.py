"""Qwen3-14B [hf:Qwen/Qwen3 family] — 40L d=5120 40H (GQA kv=8) d_ff=17408, qk_norm."""
from repro.configs.base import ArchConfig, LM_SHAPES, TransformerConfig, scaled_transformer

CONFIG = ArchConfig(
    arch_id="qwen3-14b",
    model=TransformerConfig(
        name="qwen3-14b",
        n_layers=40, d_model=5120, n_heads=40, n_kv_heads=8,
        d_ff=17408, vocab=151936, qk_norm=True, d_head=128,
        rope_theta=1e6,
    ),
    shapes=LM_SHAPES,
    notes="dense; qk-norm; GQA 40q/8kv.",
)


def reduced() -> TransformerConfig:
    return scaled_transformer(CONFIG.model, n_layers=2, d_model=64, n_heads=8,
                              n_kv_heads=2, d_ff=128, vocab=256, d_head=8)
