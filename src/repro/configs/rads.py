"""Config for the paper's own workload: distributed subgraph enumeration.

Defines the engine knobs (capacities, region-group budget, caching) and the
synthetic stand-ins for the paper's four datasets (offline container — see
DESIGN.md §5) plus the q1..q8 / qc1..qc4 query sets.
"""
from __future__ import annotations

import os
from dataclasses import dataclass, field


@dataclass(frozen=True)
class EngineConfig:
    """RADS / R-Meef engine knobs (all static — JAX shapes)."""

    frontier_cap: int = 1 << 16        # max live partial embeddings per device
    max_degree: int = 64               # padded adjacency window for expansion
    fetch_cap: int = 1 << 12           # max foreign-vertex fetches per round/peer
    verify_cap: int = 1 << 14          # max undetermined-edge queries per round/peer
    region_group_budget: int = 1 << 14 # memory-control target: est. trie nodes/group
    enable_sme: bool = True            # SM-E local/distributed split (Prop. 1)
    # --- foreign-adjacency cache (core/cache.py AdjCache) ------------------- #
    enable_cache: bool = True          # device-resident fetchV row cache (§7)
    cache_slots: int = 1 << 12         # sets per device (must be a power of 2:
                                       # the set index is `v & (slots - 1)`)
    cache_ways: int = 2                # associativity (1 = direct-mapped)
    cache_decay: int = 0               # shared-benefit decay period: every
                                       # `cache_decay` update batches the live
                                       # benefit counters are halved (>> 1) so
                                       # stale hub lines stop pinning the cache
                                       # across phases (0 = no decay)
    enable_work_stealing: bool = True  # checkR/shareR analogue (seed rebalance)
    # --- exchange wire format (core/wire.py codecs) ------------------------- #
    wire_format: str = "raw"           # 'raw' (int32 slabs, the reference) |
                                       # 'varint' (delta+varint / Elias-Fano
                                       # coded u8 streams on the wire; results
                                       # are wire-format-invariant) |
                                       # 'auto' (measured per-run selection
                                       # from persisted wire trials — see
                                       # core/wire.py resolve_wire_format;
                                       # requires priors_path to learn)
    plan_rho: float = 1.0              # score-function exponent (paper uses 1)
    seed: int = 0
    # --- on-device adjacency storage (graph/storage.py DeviceGraph) --------- #
    storage_format: str = "dense"      # 'dense' (reference) | 'bucketed'
                                       # (degree-bucketed CSR slabs, decouples
                                       # adjacency memory from the worst hub)
    # --- async wave scheduler (core/scheduler.py) --------------------------- #
    pipeline_depth: int | str = 2      # max in-flight waves (1 = synchronous,
                                       # "auto" = adapt from per-wave timing)
    steal_from_longest: bool = True    # refill drained group queues (checkR/shareR)
    # --- cross-run priors (core/priors.py) ---------------------------------- #
    priors_path: str = ""              # JSON cache of per-(pattern, graph)
                                       # capacity/cost priors ("" = disabled)
    # --- pipelined group communication (core/exchange.py) ------------------- #
    comm_pipeline: bool = False        # split each wave's a2a into comm_chunks
                                       # back-to-back sub-exchanges so chunk
                                       # k's transfer overlaps chunk k+1's
                                       # encode/decode (arXiv:1804.09764-style
                                       # pipelined groups; bit-identical)
    comm_chunks: int = 4               # sub-exchanges per a2a when
                                       # comm_pipeline is on (power of two so
                                       # it divides the capacity-ladder axes)
    # --- persistent stage-executable cache (runtime/compile_cache.py) ------- #
    compile_cache_dir: str = ""        # per-host on-disk store of serialized
                                       # stage executables ("" = disabled);
                                       # with priors v2 a warm run performs
                                       # zero traces/compiles
    compile_cache_budget_bytes: int = 0  # LRU size budget for the store: on
                                       # every save, least-recently-used
                                       # .stagex envelopes (file mtime) are
                                       # evicted until the store fits
                                       # (0 = unbounded, the old behaviour)
    prewarm: bool = True               # resolve the stage ladder on a
                                       # background thread during group
                                       # formation (off the critical path)
    # --- accelerator kernels ------------------------------------------------ #
    use_pallas_kernels: bool = False   # Pallas membership in back-edge checks +
                                       # intersect in bucketed candidate gen
                                       # (off on CPU: jnp reference is the test path)

    def __post_init__(self):
        # RL002's runtime twin: every escalation doubles the caps, and the
        # priors cache warm-starts from persisted (doubled) values — caps on
        # the power-of-two ladder are the invariant that makes a warm start
        # land exactly on an already-jitted executable instead of re-tracing
        for name in ("frontier_cap", "fetch_cap", "verify_cap"):
            v = getattr(self, name)
            if v <= 0 or (v & (v - 1)):
                raise ValueError(
                    f"{name} must be a positive power of two (capacity "
                    f"escalation ladder / jit-cache warm starts), got {v}")
        if self.cache_slots <= 0 or (self.cache_slots
                                     & (self.cache_slots - 1)):
            raise ValueError(
                f"cache_slots must be a positive power of two (the set "
                f"index is a bitmask), got {self.cache_slots}")
        if self.cache_ways < 1:
            raise ValueError(f"cache_ways must be >= 1, got {self.cache_ways}")
        if self.cache_decay < 0:
            raise ValueError(
                f"cache_decay must be >= 0 (0 disables the benefit decay "
                f"schedule), got {self.cache_decay}")
        if self.wire_format not in ("raw", "varint", "auto"):
            raise ValueError(
                f"wire_format must be 'raw', 'varint' or 'auto', "
                f"got {self.wire_format!r}")
        if not isinstance(self.compile_cache_dir, str):
            raise ValueError(
                f"compile_cache_dir must be a directory path string "
                f"('' disables the executable store), "
                f"got {self.compile_cache_dir!r}")
        if self.compile_cache_dir and os.path.exists(self.compile_cache_dir) \
                and not os.path.isdir(self.compile_cache_dir):
            raise ValueError(
                f"compile_cache_dir exists but is not a directory: "
                f"{self.compile_cache_dir!r}")
        if not isinstance(self.prewarm, bool):
            raise ValueError(
                f"prewarm must be a bool (background stage pre-warm), "
                f"got {self.prewarm!r}")
        if not isinstance(self.comm_pipeline, bool):
            raise ValueError(
                f"comm_pipeline must be a bool (pipelined group "
                f"communication), got {self.comm_pipeline!r}")
        if (not isinstance(self.comm_chunks, int) or self.comm_chunks < 1
                or (self.comm_chunks & (self.comm_chunks - 1))):
            raise ValueError(
                f"comm_chunks must be a positive power of two (so chunks "
                f"divide the power-of-two capacity axes evenly), "
                f"got {self.comm_chunks!r}")
        if (not isinstance(self.compile_cache_budget_bytes, int)
                or isinstance(self.compile_cache_budget_bytes, bool)
                or self.compile_cache_budget_bytes < 0):
            raise ValueError(
                f"compile_cache_budget_bytes must be an int >= 0 "
                f"(0 = unbounded store), "
                f"got {self.compile_cache_budget_bytes!r}")


# dataset stand-ins: name -> generator kwargs (see graph/generators.py)
DATASETS: dict[str, dict] = {
    # sparse, huge diameter (RoadNet-like): 2-D lattice with perturbation
    "roadnet_synth": dict(kind="road", n=4096),
    # small, moderately dense, community structure (DBLP-like)
    "dblp_synth": dict(kind="powerlaw", n=2000, avg_deg=7, seed=1),
    # dense social graph (LiveJournal-like)
    "livejournal_synth": dict(kind="powerlaw", n=6000, avg_deg=18, seed=2),
    # densest web graph (UK2002-like)
    "uk2002_synth": dict(kind="powerlaw", n=8000, avg_deg=32, seed=3),
    # CPU-container benchmark sizes (same shape characteristics, small n —
    # the tee'd bench must finish in minutes on one CPU; the full-size
    # stand-ins above are exercised by tests/examples on demand)
    "dblp_bench": dict(kind="powerlaw", n=700, avg_deg=6, seed=1),
    "roadnet_bench": dict(kind="road", n=2304),
    "livejournal_bench": dict(kind="powerlaw", n=900, avg_deg=10, seed=2),
    "uk2002_bench": dict(kind="powerlaw", n=1100, avg_deg=14, seed=3),
}

# Query patterns, edge lists over vertices 0..k-1 (unlabeled, undirected,
# connected) — recreated at the paper's 3-6 vertex scale (Figure 7).
QUERIES: dict[str, list[tuple[int, int]]] = {
    "q1": [(0, 1), (1, 2), (0, 2)],                                   # triangle
    "q2": [(0, 1), (1, 2), (2, 3), (0, 3)],                           # square
    "q3": [(0, 1), (1, 2), (2, 3), (0, 3), (0, 2)],                   # diamond
    "q4": [(0, 1), (0, 2), (0, 3), (1, 2), (1, 3), (2, 3)],           # 4-clique
    "q5": [(0, 1), (1, 2), (2, 3), (0, 3), (0, 2), (3, 4)],           # diamond+tail
    "q6": [(0, 1), (1, 2), (2, 3), (3, 4), (0, 4), (0, 2)],           # house
    "q7": [(0, 1), (1, 2), (2, 3), (3, 4), (4, 5), (0, 5), (0, 3)],   # 6-cycle+chord
    "q8": [(0, 1), (0, 2), (0, 3), (1, 2), (3, 4), (3, 5)],           # tri + star
}

# clique-heavy set (Appendix C.4, Figure 14)
CLIQUE_QUERIES: dict[str, list[tuple[int, int]]] = {
    "qc1": [(0, 1), (0, 2), (1, 2), (2, 3), (3, 4), (2, 4)],          # two triangles
    "qc2": [(0, 1), (0, 2), (0, 3), (1, 2), (1, 3), (2, 3), (3, 4)],  # 4clique+tail
    "qc3": [(0, 1), (0, 2), (0, 3), (1, 2), (1, 3), (2, 3),
            (2, 4), (3, 4)],                                          # 4clique+tri
    "qc4": [(0, 1), (0, 2), (1, 2), (1, 3), (2, 3), (2, 4), (3, 4),
            (0, 4)],                                                  # dense 5v
}

DEFAULT_ENGINE = EngineConfig()
