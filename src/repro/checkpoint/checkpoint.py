"""Sharded checkpointing: npz-per-leaf + json manifest, async save thread,
elastic restore (a checkpoint written on one mesh restores onto any other —
arrays are saved unsharded and re-device_put against the new topology's
shardings, which is exactly what an elastic resize needs).
"""
from __future__ import annotations

import json
import os
import queue
import shutil
import threading

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save_checkpoint(path: str, step: int, tree, blocking: bool = True):
    """Write tree -> ``path/step_<N>/`` (atomic rename)."""
    tgt = os.path.join(path, f"step_{step:08d}")
    tmp = tgt + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    leaves, treedef = _flatten(tree)
    # npz can't serialize ml_dtypes (bfloat16 etc.) — upcast losslessly to
    # float32 on disk; load_checkpoint casts back to the tree's dtype.
    host = []
    for l in leaves:
        a = np.asarray(jax.device_get(l))
        if a.dtype not in (np.float32, np.float64, np.int32, np.int64,
                           np.int8, np.uint8, np.bool_, np.int16, np.uint16,
                           np.float16, np.uint32, np.uint64):
            a = a.astype(np.float32)
        host.append(a)

    def write():
        manifest = dict(step=step, n_leaves=len(host),
                        treedef=str(treedef),
                        shapes=[list(a.shape) for a in host],
                        dtypes=[str(a.dtype) for a in host])
        np.savez(os.path.join(tmp, "leaves.npz"),
                 **{f"leaf_{i}": a for i, a in enumerate(host)})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(tgt):
            shutil.rmtree(tgt)
        os.rename(tmp, tgt)

    if blocking:
        write()
        return None
    th = threading.Thread(target=write, daemon=True)
    th.start()
    return th


def latest_step(path: str) -> int | None:
    if not os.path.isdir(path):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(path)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def load_checkpoint(path: str, like_tree, step: int | None = None,
                    shardings=None):
    """Restore into the structure of ``like_tree``. ``shardings`` (same
    structure or a single sharding) re-places leaves for the current mesh —
    the elastic-resize path."""
    step = step if step is not None else latest_step(path)
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {path}")
    src = os.path.join(path, f"step_{step:08d}")
    data = np.load(os.path.join(src, "leaves.npz"))
    leaves, treedef = _flatten(like_tree)
    assert len(leaves) == len(data.files), \
        f"checkpoint has {len(data.files)} leaves, tree wants {len(leaves)}"
    restored = []
    sh_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                 if shardings is not None and not hasattr(shardings, "memory_kind")
                 else [shardings] * len(leaves))
    for i, (ref, sh) in enumerate(zip(leaves, sh_leaves)):
        arr = data[f"leaf_{i}"]
        assert tuple(arr.shape) == tuple(ref.shape), \
            f"leaf {i}: ckpt {arr.shape} vs tree {ref.shape}"
        a = jnp.asarray(arr, dtype=ref.dtype)
        if sh is not None:
            a = jax.device_put(a, sh)
        restored.append(a)
    return jax.tree_util.tree_unflatten(treedef, restored), step
