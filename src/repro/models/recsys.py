"""DIN (Deep Interest Network) + the EmbeddingBag substrate.

JAX has no ``nn.EmbeddingBag``; ``embedding_bag`` below builds it from
``jnp.take`` + ``jax.ops.segment_sum`` — this is part of the system (see
kernel_taxonomy §RecSys). Tables are production-scale (50M items) and
row-sharded over the mesh in the dry-run; the EVI-style request dedup from
the paper's engine reappears here as ``unique``-before-gather (optional).

DIN: target attention over the user behavior sequence (attn MLP 80-40),
then MLP 200-80 -> CTR logit. ``retrieval_scores`` scores one user against
1M candidates with a single batched dot (no loop).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.configs.base import RecsysConfig
from repro.models.layers import _init

DTYPES = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}


# --------------------------------------------------------------------------- #
# EmbeddingBag = take + segment_sum
# --------------------------------------------------------------------------- #
def embedding_bag(table: jnp.ndarray, ids: jnp.ndarray,
                  segment_ids: jnp.ndarray, n_segments: int,
                  weights: jnp.ndarray | None = None,
                  mode: str = "sum") -> jnp.ndarray:
    """table (V, d); ids (K,) flat indices; segment_ids (K,) bag assignment.
    Returns (n_segments, d). ``mean`` divides by bag sizes."""
    rows = jnp.take(table, jnp.clip(ids, 0, table.shape[0] - 1), axis=0)
    if weights is not None:
        rows = rows * weights[:, None].astype(rows.dtype)
    out = jax.ops.segment_sum(rows, segment_ids, num_segments=n_segments)
    if mode == "mean":
        cnt = jax.ops.segment_sum(jnp.ones_like(ids, dtype=rows.dtype),
                                  segment_ids, num_segments=n_segments)
        out = out / jnp.maximum(cnt[:, None], 1)
    return out


@jax.tree_util.register_dataclass
@dataclass
class DINBatch:
    user_feats: jnp.ndarray    # (B, n_uf) multi-hot user profile ids
    target_item: jnp.ndarray   # (B,)
    target_cate: jnp.ndarray   # (B,)
    hist_items: jnp.ndarray    # (B, T)
    hist_cates: jnp.ndarray    # (B, T)
    hist_mask: jnp.ndarray     # (B, T) bool
    labels: jnp.ndarray        # (B,) float 0/1


def _mlp_init(key, dims, dtype):
    ks = jax.random.split(key, len(dims) - 1)
    return [dict(w=_init(ks[i], (dims[i], dims[i + 1]), dtype=dtype),
                 b=jnp.zeros((dims[i + 1],), dtype))
            for i in range(len(dims) - 1)]


def _mlp(params, x, act=jax.nn.sigmoid):
    # DIN uses PReLU/Dice; sigmoid-gated linear here keeps it dependency-free
    for i, lyr in enumerate(params):
        x = x @ lyr["w"] + lyr["b"]
        if i < len(params) - 1:
            x = jax.nn.silu(x)
    return x


def init_din(key, cfg: RecsysConfig):
    dt = DTYPES[cfg.dtype]
    d = cfg.embed_dim
    ks = jax.random.split(key, 5)
    de = 2 * d                         # item+cate concat
    return dict(
        item_table=_init(ks[0], (cfg.n_items, d), scale=0.01, dtype=dt),
        cate_table=_init(ks[1], (cfg.n_cates, d), scale=0.01, dtype=dt),
        user_table=_init(ks[2], (cfg.n_user_feats, d), scale=0.01, dtype=dt),
        attn=_mlp_init(ks[3], (4 * de, *cfg.attn_mlp, 1), dt),
        mlp=_mlp_init(ks[4], (d + 3 * de, *cfg.mlp, 1), dt),
    )


def _hist_embed(params, items, cates):
    return jnp.concatenate([jnp.take(params["item_table"], items, axis=0),
                            jnp.take(params["cate_table"], cates, axis=0)], -1)


def din_user_state(params, cfg: RecsysConfig, batch: DINBatch):
    """Everything before the target interaction — reusable for retrieval."""
    B = batch.target_item.shape[0]
    # user profile: EmbeddingBag (sum) over multi-hot ids
    nuf = batch.user_feats.shape[1]
    seg = jnp.repeat(jnp.arange(B), nuf)
    u = embedding_bag(params["user_table"], batch.user_feats.reshape(-1),
                      seg, B, mode="sum")
    hist = _hist_embed(params, batch.hist_items, batch.hist_cates)  # (B,T,2d)
    return u, hist


def din_logits(params, cfg: RecsysConfig, batch: DINBatch):
    B, T = batch.hist_items.shape
    u, hist = din_user_state(params, cfg, batch)
    tgt = _hist_embed(params, batch.target_item[:, None],
                      batch.target_cate[:, None])[:, 0]             # (B, 2d)
    # target attention (DIN): MLP on [h, t, h-t, h*t], NOT softmax-normalized
    t_b = jnp.broadcast_to(tgt[:, None, :], hist.shape)
    att_in = jnp.concatenate([hist, t_b, hist - t_b, hist * t_b], -1)
    w = _mlp(params["attn"], att_in)[..., 0]                        # (B, T)
    w = jnp.where(batch.hist_mask, w, 0.0)
    summary = (w[..., None] * hist).sum(axis=1)                     # (B, 2d)
    hist_sum = (batch.hist_mask[..., None] * hist).sum(axis=1)
    feats = jnp.concatenate([u, tgt, summary, hist_sum], -1)
    return _mlp(params["mlp"], feats)[:, 0]


def din_loss(params, cfg: RecsysConfig, batch: DINBatch):
    logit = din_logits(params, cfg, batch).astype(jnp.float32)
    y = batch.labels.astype(jnp.float32)
    return jnp.mean(jnp.maximum(logit, 0) - logit * y
                    + jnp.log1p(jnp.exp(-jnp.abs(logit))))


def retrieval_scores(params, cfg: RecsysConfig, batch: DINBatch,
                     cand_items: jnp.ndarray, cand_cates: jnp.ndarray):
    """Score batch.user (typically B=1) against N candidates in one batched
    dot: user tower = attention-free summary; item tower = embed concat."""
    u, hist = din_user_state(params, cfg, batch)
    hist_sum = (batch.hist_mask[..., None] * hist).sum(axis=1)      # (B, 2d)
    user_vec = jnp.concatenate([u, hist_sum], -1)                   # (B, 3d)
    cand = _hist_embed(params, cand_items[None], cand_cates[None])[0]  # (N, 2d)
    proj = user_vec[:, :cand.shape[-1]]                             # (B, 2d)
    return proj @ cand.T                                            # (B, N)
