"""LM transformer: dense GQA (qwen family), MoE (olmoe), MLA+MoE (deepseek-v3).

Design points for the 512-chip dry-run:
* ``jax.lax.scan`` over stacked per-layer weights — HLO size independent of
  depth (61-layer DSv3 compiles as one block).
* optional ``jax.checkpoint`` (remat) around the block — activation-memory
  lever for the perf loop.
* MoE layers run in a second scan (DeepSeek's ``first_k_dense`` prefix runs
  dense); MTP head (depth-1) supported.
* decode: per-layer KV cache, GQA (k, v) or MLA latent (c_kv, k_rope —
  the paper-exact cache shrink), updated functionally.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import TransformerConfig
from repro.models.layers import (apply_rope, decode_attention, flash_attention,
                                 gqa_qkv, init_gqa_params, init_mla_params,
                                 init_moe_params, mla_absorbed_decode,
                                 mla_compress, mla_expand_kv, mla_queries,
                                 moe_block, rms_norm, rope_angles, swiglu,
                                 _init)

DTYPES = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}


def _dt(cfg):
    return DTYPES[cfg.dtype]


# --------------------------------------------------------------------------- #
# init
# --------------------------------------------------------------------------- #
def init_ffn_params(key, cfg: TransformerConfig, d_ff: int, dtype):
    ks = jax.random.split(key, 3)
    return dict(wg=_init(ks[0], (cfg.d_model, d_ff), dtype=dtype),
                wu=_init(ks[1], (cfg.d_model, d_ff), dtype=dtype),
                wd=_init(ks[2], (d_ff, cfg.d_model), dtype=dtype))


def _init_block(key, cfg: TransformerConfig, moe: bool, dtype):
    ks = jax.random.split(key, 3)
    attn = (init_mla_params(ks[0], cfg, dtype) if cfg.mla is not None
            else init_gqa_params(ks[0], cfg, dtype))
    if moe:
        ffn = init_moe_params(ks[1], cfg, dtype)
    else:
        d_ff = (cfg.moe.d_ff_dense if (cfg.moe and cfg.moe.first_k_dense)
                else cfg.d_ff)
        ffn = init_ffn_params(ks[1], cfg, d_ff, dtype)
    return dict(attn=attn, ffn=ffn,
                ln1=jnp.ones((cfg.d_model,), dtype),
                ln2=jnp.ones((cfg.d_model,), dtype))


def init_lm_params(key, cfg: TransformerConfig):
    """Returns a pytree with per-layer weights stacked on axis 0 (two stacks
    if the model mixes dense + MoE layers)."""
    dtype = _dt(cfg)
    n_dense = cfg.moe.first_k_dense if cfg.moe else cfg.n_layers
    n_moe = cfg.n_layers - n_dense
    keys = jax.random.split(key, 4)

    def stack(key, n, moe):
        if n == 0:
            return None
        ks = jax.random.split(key, n)
        return jax.tree.map(lambda *xs: jnp.stack(xs),
                            *[_init_block(k, cfg, moe, dtype) for k in ks])

    params = dict(
        embed=_init(keys[0], (cfg.vocab, cfg.d_model), scale=0.02, dtype=dtype),
        dense_stack=stack(keys[1], n_dense, moe=False),
        moe_stack=stack(keys[2], n_moe, moe=True),
        final_norm=jnp.ones((cfg.d_model,), dtype),
    )
    if not cfg.tie_embeddings:
        params["lm_head"] = _init(keys[3], (cfg.d_model, cfg.vocab),
                                  scale=0.02, dtype=dtype)
    if cfg.mtp_depth:
        km = jax.random.split(keys[3], 3)
        params["mtp"] = dict(block=_init_block(km[0], cfg, moe=False, dtype=dtype),
                             proj=_init(km[1], (2 * cfg.d_model, cfg.d_model),
                                        dtype=dtype),
                             norm=jnp.ones((cfg.d_model,), dtype))
    return params


# --------------------------------------------------------------------------- #
# forward
# --------------------------------------------------------------------------- #
def _attn_full(blk, cfg: TransformerConfig, x, positions, remat_chunks):
    """Full-sequence (train/prefill) attention for one block."""
    if cfg.mla is not None:
        c_kv, k_r = mla_compress(blk["attn"], cfg, x, positions)
        q_nope, q_rope = mla_queries(blk["attn"], cfg, x, positions)
        k_nope, v = mla_expand_kv(blk["attn"], cfg, c_kv)
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_r, k_nope.shape[:-1] + (k_r.shape[-1],))],
            axis=-1)
        o = flash_attention(q, k, v, causal=True)
        B, S = x.shape[:2]
        return o.reshape(B, S, -1) @ blk["attn"]["wo"]
    q, k, v = gqa_qkv(blk["attn"], cfg, x, positions)
    o = flash_attention(q, k, v, causal=True)
    B, S = x.shape[:2]
    return o.reshape(B, S, -1) @ blk["attn"]["wo"]


def _block_fwd(blk, cfg: TransformerConfig, x, positions, moe: bool):
    h = x + _attn_full(blk, cfg, rms_norm(x, blk["ln1"], cfg.norm_eps),
                       positions, None)
    hn = rms_norm(h, blk["ln2"], cfg.norm_eps)
    if moe:
        y, aux = moe_block(blk["ffn"], cfg, hn)
    else:
        y, aux = swiglu(hn, **blk["ffn"]), jnp.zeros((), jnp.float32)
    return h + y, aux


def lm_forward(params, cfg: TransformerConfig, tokens,
               remat: bool = True):
    """tokens (B, S) -> (logits (B, S, V), aux_loss)."""
    B, S = tokens.shape
    x = params["embed"][tokens]
    positions = jnp.arange(S)[None, :]
    aux_total = jnp.zeros((), jnp.float32)

    def run_stack(x, stack, moe, aux):
        if stack is None:
            return x, aux

        def body(carry, blk):
            xx, aa = carry
            fwd = partial(_block_fwd, cfg=cfg, positions=positions, moe=moe)
            if remat:
                fwd = jax.checkpoint(
                    lambda b, v: _block_fwd(b, cfg, v, positions, moe))
                out, aux_l = fwd(blk, xx)
            else:
                out, aux_l = _block_fwd(blk, cfg, xx, positions, moe)
            return (out, aa + aux_l), None

        (x, aux), _ = jax.lax.scan(body, (x, aux), stack)
        return x, aux

    x, aux_total = run_stack(x, params.get("dense_stack"), False, aux_total)
    x, aux_total = run_stack(x, params.get("moe_stack"), True, aux_total)
    hidden = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = hidden @ head
    return logits, aux_total, hidden


def lm_forward_hidden(params, cfg: TransformerConfig, tokens,
                      remat: bool = True):
    """Like lm_forward but never materializes logits (loss is chunked)."""
    B, S = tokens.shape
    x = params["embed"][tokens]
    positions = jnp.arange(S)[None, :]
    aux_total = jnp.zeros((), jnp.float32)

    def run_stack(x, stack, moe, aux):
        if stack is None:
            return x, aux

        def body(carry, blk):
            xx, aa = carry
            if remat:
                out, aux_l = jax.checkpoint(
                    lambda b, v: _block_fwd(b, cfg, v, positions, moe))(blk, xx)
            else:
                out, aux_l = _block_fwd(blk, cfg, xx, positions, moe)
            return (out, aa + aux_l), None

        (x, aux), _ = jax.lax.scan(body, (x, aux), stack)
        return x, aux

    x, aux_total = run_stack(x, params.get("dense_stack"), False, aux_total)
    x, aux_total = run_stack(x, params.get("moe_stack"), True, aux_total)
    hidden = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return None, aux_total, hidden


def _ce(logits, labels, mask=None):
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    ce = lse - picked
    if mask is not None:
        return (ce * mask).sum() / jnp.maximum(mask.sum(), 1)
    return ce.mean()


def chunked_xent(hidden, head, labels, mask=None, chunk: int = 8192):
    """Vocab-chunked cross entropy: never materializes the full (B, S, V)
    f32 logits — an online-logsumexp scan over vocab chunks (the flash trick
    applied to the LM head). Cuts the train-step temp memory by the vocab /
    chunk factor; the head matmul stays TP-sharded over 'model'."""
    B, S, d = hidden.shape
    V = head.shape[1]
    chunk = min(chunk, V)
    nc = -(-V // chunk)
    Vp = nc * chunk
    headp = jnp.pad(head, ((0, 0), (0, Vp - V)))

    def body(carry, ci):
        m, s, picked = carry
        hc = jax.lax.dynamic_slice(headp, (0, ci * chunk), (d, chunk))
        lg = (hidden @ hc).astype(jnp.float32)           # (B, S, chunk)
        col = jax.lax.broadcasted_iota(jnp.int32, (1, 1, chunk), 2) \
            + ci * chunk
        lg = jnp.where(col < V, lg, -jnp.inf)
        m_new = jnp.maximum(m, lg.max(-1))
        s = s * jnp.exp(m - m_new) + jnp.exp(
            lg - m_new[..., None]).sum(-1)
        in_chunk = (labels >= ci * chunk) & (labels < (ci + 1) * chunk)
        idx = jnp.clip(labels - ci * chunk, 0, chunk - 1)
        pick_c = jnp.take_along_axis(lg, idx[..., None], axis=-1)[..., 0]
        picked = jnp.where(in_chunk, pick_c, picked)
        return (m_new, s, picked), None

    m0 = jnp.full((B, S), -jnp.inf, jnp.float32)
    s0 = jnp.zeros((B, S), jnp.float32)
    p0 = jnp.zeros((B, S), jnp.float32)
    (m, s, picked), _ = jax.lax.scan(body, (m0, s0, p0), jnp.arange(nc))
    ce = m + jnp.log(jnp.maximum(s, 1e-30)) - picked
    if mask is not None:
        return (ce * mask).sum() / jnp.maximum(mask.sum(), 1)
    return ce.mean()


def sharded_xent(hidden, head, labels, mask=None, logits_sharding=None,
                 hidden_sharding=None):
    """CE that stays vocab-sharded end to end: bf16 logits (batch x vocab
    2-D sharded), f32 reductions, and the label pick via an iota-compare
    masked sum (no cross-shard gather). The explicit constraints matter:
    without them GSPMD contracts the model-sharded hidden dim / all-gathers
    the batch — 37 GiB f32 collectives per step (measured; EXPERIMENTS.md
    §Perf)."""
    if hidden_sharding is not None:
        hidden = jax.lax.with_sharding_constraint(hidden, hidden_sharding)
    logits = hidden @ head                               # (B, S, V) bf16
    if logits_sharding is not None:
        logits = jax.lax.with_sharding_constraint(logits, logits_sharding)
    lg32 = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lg32, axis=-1)
    V = head.shape[-1]
    col = jax.lax.broadcasted_iota(jnp.int32, (1, 1, V), 2)
    eq = col == labels[..., None]
    picked = jnp.sum(jnp.where(eq, lg32, 0.0), axis=-1)
    ce = lse - picked
    if mask is not None:
        return (ce * mask).sum() / jnp.maximum(mask.sum(), 1)
    return ce.mean()


def lm_loss(params, cfg: TransformerConfig, tokens, labels,
            aux_weight: float = 0.01, mtp_weight: float = 0.3,
            remat: bool = True, xent: str = "sharded",
            xent_chunk: int = 8192, logits_sharding=None,
            hidden_sharding=None):
    """Next-token CE (+ MoE aux loss + depth-1 MTP loss, DeepSeek-V3 style).
    ``xent='sharded'`` keeps logits bf16 + vocab-sharded; ``'chunked'``
    streams vocab chunks (never resident) — perf-loop option."""
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    _, aux, hidden = lm_forward_hidden(params, cfg, tokens, remat=remat)
    if xent == "chunked":
        loss = chunked_xent(hidden, head, labels, chunk=xent_chunk)
    else:
        loss = sharded_xent(hidden, head, labels,
                            logits_sharding=logits_sharding,
                            hidden_sharding=hidden_sharding)
    if cfg.moe is not None and not cfg.moe.router_aux_free:
        loss = loss + aux_weight * aux / max(cfg.n_layers, 1)
    if cfg.mtp_depth and "mtp" in params:
        # depth-1 MTP: h'_t = Block(Proj[norm(h_t) ; norm(emb(tok_{t+1}))]);
        # logits'_t predicts labels_{t+1} (i.e., token t+2). Tail masked.
        mtp = params["mtp"]
        B, S = tokens.shape
        nxt_emb = params["embed"][jnp.roll(tokens, -1, axis=1)]
        cat = jnp.concatenate(
            [rms_norm(hidden, mtp["norm"], cfg.norm_eps), nxt_emb], axis=-1)
        h2 = cat @ mtp["proj"]
        positions = jnp.arange(S)[None, :]
        h2, _ = _block_fwd(mtp["block"], cfg, h2, positions, moe=False)
        h2 = rms_norm(h2, params["final_norm"], cfg.norm_eps)
        labels2 = jnp.roll(labels, -1, axis=1)
        mask = (jnp.arange(S) < S - 1).astype(jnp.float32)[None, :]
        if xent == "chunked":
            loss = loss + mtp_weight * chunked_xent(h2, head, labels2, mask,
                                                    chunk=xent_chunk)
        else:
            loss = loss + mtp_weight * sharded_xent(
                h2, head, labels2, mask, logits_sharding=logits_sharding,
                hidden_sharding=hidden_sharding)
    return loss


# --------------------------------------------------------------------------- #
# KV-cache serving
# --------------------------------------------------------------------------- #
@dataclass
class CacheSpec:
    """Shapes of the per-layer decode cache."""
    kind: str          # "gqa" | "mla"
    shapes: dict


def cache_spec(cfg: TransformerConfig, batch: int, max_len: int) -> CacheSpec:
    L = cfg.n_layers
    dt = _dt(cfg)
    if cfg.mla is not None:
        m = cfg.mla
        return CacheSpec("mla", dict(
            c_kv=((L, batch, max_len, m.kv_lora_rank), dt),
            k_rope=((L, batch, max_len, m.qk_rope_head_dim), dt)))
    return CacheSpec("gqa", dict(
        k=((L, batch, max_len, cfg.n_kv_heads, cfg.head_dim), dt),
        v=((L, batch, max_len, cfg.n_kv_heads, cfg.head_dim), dt)))


def init_cache(cfg: TransformerConfig, batch: int, max_len: int):
    spec = cache_spec(cfg, batch, max_len)
    return {k: jnp.zeros(s, d) for k, (s, d) in spec.shapes.items()}


def _stack_blocks(params, cfg):
    """Concatenate dense+moe stacks into per-layer python list views is not
    scan-able; instead yield (stack, moe?, n_layers) segments."""
    segs = []
    if params.get("dense_stack") is not None:
        n = cfg.moe.first_k_dense if cfg.moe else cfg.n_layers
        segs.append((params["dense_stack"], False, n))
    if params.get("moe_stack") is not None:
        segs.append((params["moe_stack"], True,
                     cfg.n_layers - (cfg.moe.first_k_dense if cfg.moe else 0)))
    return segs


def decode_step(params, cfg: TransformerConfig, cache, tokens, length,
                absorbed: bool = False):
    """One decode step. tokens (B,) int32; length = current cache fill
    (scalar int32). Returns (logits (B, V), new_cache)."""
    B = tokens.shape[0]
    x = params["embed"][tokens][:, None, :]           # (B, 1, d)
    positions = jnp.full((B, 1), length, jnp.int32)
    layer_off = 0
    new_cache = dict(cache)

    for stack, moe, n in _stack_blocks(params, cfg):
        def body(carry, inp):
            xx, lidx = carry
            blk, cache_sl = inp
            xn = rms_norm(xx, blk["ln1"], cfg.norm_eps)
            if cfg.mla is not None:
                c_kv, k_r = mla_compress(blk["attn"], cfg, xn, positions)
                ck = jax.lax.dynamic_update_slice(
                    cache_sl["c_kv"], c_kv.astype(cache_sl["c_kv"].dtype),
                    (0, length, 0))
                kr = jax.lax.dynamic_update_slice(
                    cache_sl["k_rope"], k_r[:, :, 0].astype(
                        cache_sl["k_rope"].dtype), (0, length, 0))
                if absorbed:
                    o = mla_absorbed_decode(blk["attn"], cfg, xn, ck, kr[:, :, None],
                                            length + 1, positions)
                else:
                    k_nope, v = mla_expand_kv(blk["attn"], cfg, ck)
                    q_nope, q_rope = mla_queries(blk["attn"], cfg, xn, positions)
                    q = jnp.concatenate([q_nope, q_rope], -1)
                    k = jnp.concatenate(
                        [k_nope, jnp.broadcast_to(
                            kr[:, :, None, :],
                            k_nope.shape[:-1] + (kr.shape[-1],))], -1)
                    o = decode_attention(q, k, v, length + 1)
                    o = o.reshape(B, 1, -1) @ blk["attn"]["wo"]
                new_sl = dict(c_kv=ck, k_rope=kr)
            else:
                q, k, v = gqa_qkv(blk["attn"], cfg, xn, positions)
                ck = jax.lax.dynamic_update_slice(
                    cache_sl["k"], k.astype(cache_sl["k"].dtype),
                    (0, length, 0, 0))
                cv = jax.lax.dynamic_update_slice(
                    cache_sl["v"], v.astype(cache_sl["v"].dtype),
                    (0, length, 0, 0))
                o = decode_attention(q, ck, cv, length + 1)
                o = o.reshape(B, 1, -1) @ blk["attn"]["wo"]
                new_sl = dict(k=ck, v=cv)
            h = xx + o
            hn = rms_norm(h, blk["ln2"], cfg.norm_eps)
            if moe:
                y, _ = moe_block(blk["ffn"], cfg, hn)
            else:
                y = swiglu(hn, **blk["ffn"])
            return (h + y, lidx + 1), new_sl

        cache_seg = {k: jax.lax.dynamic_slice_in_dim(v, layer_off, n, 0)
                     for k, v in cache.items()}
        # move layer axis first for scan
        (x, _), upd = jax.lax.scan(
            body, (x, 0), (stack, jax.tree.map(lambda v: v, cache_seg)))
        for k in new_cache:
            new_cache[k] = jax.lax.dynamic_update_slice_in_dim(
                new_cache[k], upd[k], layer_off, 0)
        layer_off += n

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = (x @ head)[:, 0]
    return logits, new_cache


def prefill(params, cfg: TransformerConfig, tokens, max_len: int | None = None,
            cache_shardings=None, last_only: bool = False):
    """Prefill: run the full sequence, return (logits, cache filled to S).

    ``cache_shardings`` (dict matching the cache pytree) constrains both the
    zero-init and every per-layer update — without it the cache is born
    replicated and GSPMD all-gathers each layer's K/V into it (measured 74
    GiB/device temp at 32k prefill, §Perf). ``last_only`` computes logits
    for the final position only (decode handoff needs nothing else)."""
    B, S = tokens.shape
    max_len = max_len or S
    cache = init_cache(cfg, B, max_len)
    if cache_shardings is not None:
        cache = {k: jax.lax.with_sharding_constraint(v, cache_shardings[k])
                 for k, v in cache.items()}
    x = params["embed"][tokens]
    positions = jnp.arange(S)[None, :]
    layer_off = 0

    for stack, moe, n in _stack_blocks(params, cfg):
        def body(xx, blk):
            xn = rms_norm(xx, blk["ln1"], cfg.norm_eps)
            if cfg.mla is not None:
                c_kv, k_r = mla_compress(blk["attn"], cfg, xn, positions)
                k_nope, v = mla_expand_kv(blk["attn"], cfg, c_kv)
                q_nope, q_rope = mla_queries(blk["attn"], cfg, xn, positions)
                q = jnp.concatenate([q_nope, q_rope], -1)
                k = jnp.concatenate(
                    [k_nope, jnp.broadcast_to(
                        k_r, k_nope.shape[:-1] + (k_r.shape[-1],))], -1)
                o = flash_attention(q, k, v, causal=True)
                o = o.reshape(B, S, -1) @ blk["attn"]["wo"]
                kv_out = dict(c_kv=c_kv, k_rope=k_r[:, :, 0])
            else:
                q, k, v = gqa_qkv(blk["attn"], cfg, xn, positions)
                o = flash_attention(q, k, v, causal=True)
                o = o.reshape(B, S, -1) @ blk["attn"]["wo"]
                kv_out = dict(k=k, v=v)
            h = xx + o
            hn = rms_norm(h, blk["ln2"], cfg.norm_eps)
            y = moe_block(blk["ffn"], cfg, hn)[0] if moe \
                else swiglu(hn, **blk["ffn"])
            return h + y, kv_out

        x, kvs = jax.lax.scan(body, x, stack)
        for k, v in kvs.items():
            pad = max_len - S
            vv = jnp.pad(v.astype(cache[k].dtype),
                         ((0, 0), (0, 0), (0, pad)) + ((0, 0),) * (v.ndim - 3))
            if cache_shardings is not None:
                vv = jax.lax.with_sharding_constraint(
                    vv, cache_shardings[k])
            cache[k] = jax.lax.dynamic_update_slice_in_dim(
                cache[k], vv, layer_off, 0)
            if cache_shardings is not None:
                cache[k] = jax.lax.with_sharding_constraint(
                    cache[k], cache_shardings[k])
        layer_off += n

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    if last_only:
        return x[:, -1:] @ head, cache
    return x @ head, cache
