"""Transformer building blocks — raw JAX, explicit param pytrees.

Everything here is shape-polymorphic over a leading batch axis and written
so that ``jax.lax.scan`` over stacked per-layer weights compiles one block
regardless of depth (critical for the 61-layer DeepSeek-V3 dry-run).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import MLAConfig, MoEConfig, TransformerConfig


def _init(key, shape, scale=None, dtype=jnp.float32):
    scale = scale if scale is not None else (1.0 / max(shape[0], 1)) ** 0.5
    return (jax.random.normal(key, shape, dtype=jnp.float32) * scale).astype(dtype)


# --------------------------------------------------------------------------- #
# norms / rope / mlp
# --------------------------------------------------------------------------- #
def rms_norm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w


def rope_angles(positions: jnp.ndarray, dim: int, theta: float) -> tuple:
    """positions (...,) -> (cos, sin) of shape (..., dim//2)."""
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions[..., None].astype(jnp.float32) * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x (..., S, H, D); cos/sin (..., S, D//2) broadcast over heads."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    c, s = cos[..., None, :], sin[..., None, :]
    out = jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)
    return out.astype(x.dtype)


def swiglu(x: jnp.ndarray, wg: jnp.ndarray, wu: jnp.ndarray,
           wd: jnp.ndarray) -> jnp.ndarray:
    return (jax.nn.silu(x @ wg) * (x @ wu)) @ wd


# --------------------------------------------------------------------------- #
# attention (chunked online-softmax — pure-JAX flash; ref for the Pallas kernel)
# --------------------------------------------------------------------------- #
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool = True, q_chunk: int = 1024,
                    kv_chunk: int = 1024, q_offset=0) -> jnp.ndarray:
    """q (B, Sq, H, D), k/v (B, Skv, Hk, D) with H % Hk == 0 (GQA).

    Online-softmax over kv chunks; O(S) memory. ``q_offset`` is the absolute
    position of q[0] (for causal masking during chunked prefill/decode)."""
    B, Sq, H, D = q.shape
    _, Skv, Hk, _ = k.shape
    Dv = v.shape[-1]          # may differ from D (MLA: v_head_dim != qk dim)
    rep = H // Hk
    scale = D ** -0.5
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Skv)
    nq = -(-Sq // q_chunk)
    nk = -(-Skv // kv_chunk)
    q_pad = nq * q_chunk - Sq
    k_pad = nk * kv_chunk - Skv
    qq = jnp.pad(q, ((0, 0), (0, q_pad), (0, 0), (0, 0)))
    kk = jnp.pad(k, ((0, 0), (0, k_pad), (0, 0), (0, 0)))
    vv = jnp.pad(v, ((0, 0), (0, k_pad), (0, 0), (0, 0)))
    qq = qq.reshape(B, nq, q_chunk, H, D)
    kk = kk.reshape(B, nk, kv_chunk, Hk, D)
    vv = vv.reshape(B, nk, kv_chunk, Hk, Dv)

    q_pos = q_offset + jnp.arange(nq * q_chunk).reshape(nq, q_chunk)
    k_pos = jnp.arange(nk * kv_chunk).reshape(nk, kv_chunk)
    k_valid = (jnp.arange(nk * kv_chunk) < Skv).reshape(nk, kv_chunk)

    def per_q_chunk(qc, qp):
        # qc (B, qch, H, D); scan over kv chunks
        def body(carry, inp):
            m, l, o = carry
            kc, vc, kp, kval = inp
            kr = jnp.repeat(kc, rep, axis=2)      # (B, kch, H, D)
            vr = jnp.repeat(vc, rep, axis=2)
            s = jnp.einsum("bqhd,bkhd->bhqk", qc, kr,
                           preferred_element_type=jnp.float32) * scale
            mask = kval[None, None, None, :]
            if causal:
                mask = mask & (qp[None, None, :, None] >= kp[None, None, None, :])
            s = jnp.where(mask, s, -jnp.inf)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            o_new = o * corr[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p, vr.astype(jnp.float32))
            return (m_new, l_new, o_new), None

        m0 = jnp.full((B, H, qc.shape[1]), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, H, qc.shape[1]), jnp.float32)
        o0 = jnp.zeros((B, H, qc.shape[1], Dv), jnp.float32)
        (m, l, o), _ = jax.lax.scan(
            body, (m0, l0, o0),
            (jnp.moveaxis(kk, 1, 0), jnp.moveaxis(vv, 1, 0), k_pos, k_valid))
        out = o / jnp.maximum(l[..., None], 1e-30)
        return jnp.einsum("bhqd->bqhd", out)

    out = jax.lax.map(lambda args: per_q_chunk(*args),
                      (jnp.moveaxis(qq, 1, 0), q_pos))
    out = jnp.moveaxis(out, 0, 1).reshape(B, nq * q_chunk, H, Dv)
    return out[:, :Sq].astype(q.dtype)


def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                     length) -> jnp.ndarray:
    """Single-token decode: q (B, 1, H, D) against cache (B, S, Hk, D).
    ``length`` masks positions >= current length (scalar or (B,))."""
    B, _, H, D = q.shape
    _, S, Hk, _ = k_cache.shape
    rep = H // Hk
    kr = jnp.repeat(k_cache, rep, axis=2)
    vr = jnp.repeat(v_cache, rep, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kr,
                   preferred_element_type=jnp.float32) * D ** -0.5
    pos = jnp.arange(S)
    ln = jnp.asarray(length)
    mask = pos[None, :] < (ln[:, None] if ln.ndim else ln)
    s = jnp.where(mask[:, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vr.astype(jnp.float32))
    return out.astype(q.dtype)


# --------------------------------------------------------------------------- #
# GQA attention block
# --------------------------------------------------------------------------- #
def init_gqa_params(key, cfg: TransformerConfig, dtype):
    d, H, Hk, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = dict(
        wq=_init(ks[0], (d, H * Dh), dtype=dtype),
        wk=_init(ks[1], (d, Hk * Dh), dtype=dtype),
        wv=_init(ks[2], (d, Hk * Dh), dtype=dtype),
        wo=_init(ks[3], (H * Dh, d), dtype=dtype),
    )
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * Dh,), dtype)
        p["bk"] = jnp.zeros((Hk * Dh,), dtype)
        p["bv"] = jnp.zeros((Hk * Dh,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((Dh,), dtype)
        p["k_norm"] = jnp.ones((Dh,), dtype)
    return p


def gqa_qkv(p, cfg: TransformerConfig, x, positions):
    """x (B, S, d) -> q (B,S,H,Dh), k/v (B,S,Hk,Dh) with rope (+qk_norm)."""
    B, S, _ = x.shape
    H, Hk, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, H, Dh)
    k = k.reshape(B, S, Hk, Dh)
    v = v.reshape(B, S, Hk, Dh)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    cos, sin = rope_angles(positions, Dh, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    return q, k, v


# --------------------------------------------------------------------------- #
# MLA (DeepSeek multi-head latent attention)
# --------------------------------------------------------------------------- #
def init_mla_params(key, cfg: TransformerConfig, dtype):
    m = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 8)
    return dict(
        w_dq=_init(ks[0], (d, m.q_lora_rank), dtype=dtype),
        q_norm=jnp.ones((m.q_lora_rank,), dtype),
        w_uq=_init(ks[1], (m.q_lora_rank, H * qk_head), dtype=dtype),
        w_dkv=_init(ks[2], (d, m.kv_lora_rank), dtype=dtype),
        kv_norm=jnp.ones((m.kv_lora_rank,), dtype),
        w_kr=_init(ks[3], (d, m.qk_rope_head_dim), dtype=dtype),
        w_uk=_init(ks[4], (m.kv_lora_rank, H * m.qk_nope_head_dim), dtype=dtype),
        w_uv=_init(ks[5], (m.kv_lora_rank, H * m.v_head_dim), dtype=dtype),
        wo=_init(ks[6], (H * m.v_head_dim, d), dtype=dtype),
    )


def mla_compress(p, cfg: TransformerConfig, x, positions):
    """x (B,S,d) -> (c_kv (B,S,r), k_rope (B,S,1,Dr)) — what the KV cache
    stores (the MLA memory saving)."""
    m = cfg.mla
    c_kv = rms_norm(x @ p["w_dkv"], p["kv_norm"], cfg.norm_eps)
    k_r = (x @ p["w_kr"]).reshape(*x.shape[:-1], 1, m.qk_rope_head_dim)
    cos, sin = rope_angles(positions, m.qk_rope_head_dim, cfg.rope_theta)
    k_r = apply_rope(k_r, cos, sin)
    return c_kv, k_r


def mla_queries(p, cfg: TransformerConfig, x, positions):
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    cq = rms_norm(x @ p["w_dq"], p["q_norm"], cfg.norm_eps)
    q = (cq @ p["w_uq"]).reshape(B, S, H, m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    cos, sin = rope_angles(positions, m.qk_rope_head_dim, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    return q_nope, q_rope


def mla_expand_kv(p, cfg: TransformerConfig, c_kv):
    """Naive execution: materialize per-head k_nope / v from the latent."""
    m = cfg.mla
    B, S, _ = c_kv.shape
    H = cfg.n_heads
    k_nope = (c_kv @ p["w_uk"]).reshape(B, S, H, m.qk_nope_head_dim)
    v = (c_kv @ p["w_uv"]).reshape(B, S, H, m.v_head_dim)
    return k_nope, v


def mla_absorbed_decode(p, cfg: TransformerConfig, x, c_kv_cache, kr_cache,
                        length, positions):
    """Weight-absorbed MLA decode: attention runs in the *latent* space —
    no per-head K/V materialization over the 500k cache (DeepSeek-V2 §
    "absorb W_UK into W_UQ"). q_nope @ W_uk -> latent queries against c_kv;
    output combines with W_uv afterwards."""
    m = cfg.mla
    B, S, r = c_kv_cache.shape
    H = cfg.n_heads
    q_nope, q_rope = mla_queries(p, cfg, x, positions)       # (B,1,H,*)
    w_uk = p["w_uk"].reshape(r, H, m.qk_nope_head_dim)
    q_lat = jnp.einsum("bqhd,rhd->bqhr", q_nope, w_uk)       # (B,1,H,r)
    s_lat = jnp.einsum("bqhr,bkr->bhqk", q_lat,
                       c_kv_cache.astype(q_lat.dtype),
                       preferred_element_type=jnp.float32)
    s_rope = jnp.einsum("bqhd,bkd->bhqk", q_rope,
                        kr_cache[:, :, 0].astype(q_rope.dtype),
                        preferred_element_type=jnp.float32)
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    s = (s_lat + s_rope) * scale
    ln = jnp.asarray(length)
    mask = jnp.arange(S)[None, :] < (ln[:, None] if ln.ndim else ln)
    s = jnp.where(mask[:, None, None, :], s, -jnp.inf)
    pattn = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhqk,bkr->bqhr", pattn,
                       c_kv_cache.astype(jnp.float32))       # (B,1,H,r)
    w_uv = p["w_uv"].reshape(r, H, m.v_head_dim)
    o = jnp.einsum("bqhr,rhd->bqhd", o_lat.astype(x.dtype), w_uv)
    return o.reshape(B, 1, H * m.v_head_dim) @ p["wo"]


# --------------------------------------------------------------------------- #
# MoE
# --------------------------------------------------------------------------- #
def init_moe_params(key, cfg: TransformerConfig, dtype):
    mo = cfg.moe
    d, E, f = cfg.d_model, mo.n_experts, mo.d_expert
    ks = jax.random.split(key, 5)
    p = dict(
        router=_init(ks[0], (d, E), dtype=jnp.float32),
        wg=_init(ks[1], (E, d, f), dtype=dtype),
        wu=_init(ks[2], (E, d, f), dtype=dtype),
        wd=_init(ks[3], (E, f, d), dtype=dtype),
    )
    if mo.router_aux_free:
        p["router_bias"] = jnp.zeros((E,), jnp.float32)
    if mo.n_shared:
        fs = f * mo.n_shared
        k2 = jax.random.split(ks[4], 3)
        p["shared_wg"] = _init(k2[0], (d, fs), dtype=dtype)
        p["shared_wu"] = _init(k2[1], (d, fs), dtype=dtype)
        p["shared_wd"] = _init(k2[2], (fs, d), dtype=dtype)
    return p


def moe_block(p, cfg: TransformerConfig, x):
    """Capacity-based top-k dispatch (sort-free scatter). x (B, S, d) ->
    (y, aux_loss). Dropped tokens (over capacity) fall back to 0 (plus the
    shared expert, if any) — standard capacity semantics.

    With ``ctx.CURRENT.moe_ep_constrain`` the dispatch buffers carry
    explicit EP shardings (experts over 'model', tokens over dp axes) so
    GSPMD emits all-to-alls instead of gathering the token buffer across
    the expert axis (§Perf iteration 1 on deepseek-v3 x train_4k)."""
    from repro.distributed import ctx as _ctx
    fl = _ctx.CURRENT
    mo = cfg.moe
    B, S, d = x.shape
    T = B * S
    E, k = mo.n_experts, mo.top_k
    xt = x.reshape(T, d)
    if fl.moe_ep_constrain:
        xt = _ctx.constrain(xt, fl.dp_axes, None)
    logits = xt.astype(jnp.float32) @ p["router"]
    if mo.router_aux_free:
        scores = jax.nn.sigmoid(logits)
        sel_score, sel = jax.lax.top_k(scores + p["router_bias"], k)
        gsel = jnp.take_along_axis(scores, sel, axis=-1)
        gates = gsel / (gsel.sum(-1, keepdims=True) + 1e-9)
        probs_mean = scores.mean(axis=0)
    else:
        probs = jax.nn.softmax(logits, axis=-1)
        gsel, sel = jax.lax.top_k(probs, k)
        gates = gsel / (gsel.sum(-1, keepdims=True) + 1e-9)
        probs_mean = probs.mean(axis=0)

    cf = fl.moe_capacity_factor or mo.capacity_factor
    C = max(int(T * k / E * cf), 1)
    flat_e = sel.reshape(-1)                                 # (T*k,)
    flat_g = gates.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(T), k)
    # position of each (token, expert) pair within its expert via stable
    # sort-by-expert (O(Tk log Tk) instead of a (Tk, E) one-hot cumsum);
    # deterministic priority = token order
    sort_idx = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[sort_idx]
    first = jax.vmap(lambda v: jnp.searchsorted(sorted_e, v))(sorted_e)
    pos_sorted = jnp.arange(T * k, dtype=jnp.int32) - first.astype(jnp.int32)
    pos_in_e = jnp.zeros((T * k,), jnp.int32).at[sort_idx].set(pos_sorted)
    keep = pos_in_e < C
    slot_e = jnp.where(keep, flat_e, 0)
    slot_c = jnp.where(keep, pos_in_e, C - 1)
    buf = jnp.zeros((E, C, d), x.dtype)
    buf = buf.at[slot_e, slot_c].add(jnp.where(keep[:, None], xt[flat_t], 0))
    if fl.moe_tp:
        # TP-MoE: buf replicated over 'model' (dispatch is model-local);
        # the expert GEMM is TP over f, reduced back at y
        buf = _ctx.constrain(buf, None, None, None)
    elif fl.moe_ep_constrain:
        buf = _ctx.constrain(buf, "model", None, None)       # EP layout
    h = jnp.einsum("ecd,edf->ecf", buf, p["wg"])
    u = jnp.einsum("ecd,edf->ecf", buf, p["wu"])
    y_e = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * u, p["wd"])
    if fl.moe_ep_constrain and not fl.moe_tp:
        y_e = _ctx.constrain(y_e, "model", None, None)
    y_tok = y_e[slot_e, slot_c]                              # (T*k, d)
    y_tok = jnp.where(keep[:, None], y_tok, 0) * flat_g[:, None].astype(x.dtype)
    y = jnp.zeros((T, d), x.dtype).at[flat_t].add(y_tok)
    if fl.moe_ep_constrain:
        y = _ctx.constrain(y, fl.dp_axes, None)
    # load-balance aux (Switch-style); for aux-free routing it is only
    # *reported* (router_bias is updated outside the gradient path)
    frac_tok = jnp.mean(jax.nn.one_hot(sel, E, dtype=jnp.float32), axis=(0, 1))
    aux = E * jnp.sum(frac_tok * probs_mean)
    if mo.n_shared:
        y = y + swiglu(xt, p["shared_wg"], p["shared_wu"], p["shared_wd"])
    return y.reshape(B, S, d), aux
