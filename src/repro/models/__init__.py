from repro.models.transformer import (init_lm_params, lm_forward, lm_loss,
                                      prefill, decode_step, init_cache,
                                      cache_spec)
from repro.models.gnn import GraphBatch, init_gnn, gnn_forward, gnn_loss
from repro.models.recsys import (DINBatch, init_din, din_logits, din_loss,
                                 retrieval_scores, embedding_bag)
from repro.models.layers import flash_attention, moe_block, rms_norm

__all__ = [
    "init_lm_params", "lm_forward", "lm_loss", "prefill", "decode_step",
    "init_cache", "cache_spec", "GraphBatch", "init_gnn", "gnn_forward",
    "gnn_loss", "DINBatch", "init_din", "din_logits", "din_loss",
    "retrieval_scores", "embedding_bag", "flash_attention", "moe_block",
    "rms_norm",
]
