"""GNN model zoo: graphcast (encode-process-decode interaction net), schnet
(continuous-filter conv), pna (multi-aggregator), gat (attention).

Message passing is built on ``jax.ops.segment_sum`` / ``segment_max`` over an
edge index — JAX has no sparse SpMM; the scatter/gather IS the system (see
kernel_taxonomy §GNN). One uniform batch format serves all four:

    GraphBatch: node_feats (N, F), edge_src/edge_dst (E,), edge_mask (E,),
                positions (N, 3) [schnet], graph_id (N,) [molecule readout],
                labels / label_mask.

The distributed story (full-batch ogb_products on 256 chips) shards the edge
arrays over 'data'; ``segment_sum`` over sharded edges lowers to a
reduce-scatter/all-reduce of partial node aggregates — exactly the paper's
fetch/aggregate pattern mapped onto GSPMD (DESIGN.md §4).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import GNNConfig
from repro.models.layers import _init

DTYPES = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}


@jax.tree_util.register_dataclass
@dataclass
class GraphBatch:
    node_feats: jnp.ndarray          # (N, F)
    edge_src: jnp.ndarray            # (E,) int32
    edge_dst: jnp.ndarray            # (E,) int32
    edge_mask: jnp.ndarray           # (E,) bool
    labels: jnp.ndarray              # (N,) int32 or (N, n_vars) float
    label_mask: jnp.ndarray          # (N,) bool
    positions: jnp.ndarray | None = None   # (N, 3) for schnet
    graph_id: jnp.ndarray | None = None    # (N,) for batched molecules


def _mlp_init(key, dims, dtype):
    ks = jax.random.split(key, len(dims) - 1)
    return [dict(w=_init(ks[i], (dims[i], dims[i + 1]), dtype=dtype),
                 b=jnp.zeros((dims[i + 1],), dtype)) for i in range(len(dims) - 1)]


def _mlp(params, x, act=jax.nn.silu, final_act=False):
    for i, lyr in enumerate(params):
        x = x @ lyr["w"] + lyr["b"]
        if i < len(params) - 1 or final_act:
            x = act(x)
    return x


def _seg_sum(x, idx, n):
    return jax.ops.segment_sum(x, idx, num_segments=n)


def _seg_mean(x, idx, n, mask):
    s = _seg_sum(x, idx, n)
    c = _seg_sum(mask.astype(x.dtype)[:, None], idx, n)
    return s / jnp.maximum(c, 1)


def _seg_max(x, idx, n):
    return jax.ops.segment_max(x, idx, num_segments=n, indices_are_sorted=False)


# =========================================================================== #
# GraphCast-style encode-process-decode interaction network
# =========================================================================== #
def init_graphcast(key, cfg: GNNConfig, d_feat: int):
    dt = DTYPES[cfg.dtype]
    d = cfg.d_hidden
    ks = jax.random.split(key, 4 + cfg.n_layers)
    enc_node = _mlp_init(ks[0], (d_feat, d, d), dt)
    enc_edge = _mlp_init(ks[1], (2 * d, d, d), dt)
    layers = []
    for i in range(cfg.n_layers):
        k1, k2 = jax.random.split(ks[3 + i])
        layers.append(dict(edge_mlp=_mlp_init(k1, (3 * d, d, d), dt),
                           node_mlp=_mlp_init(k2, (2 * d, d, d), dt)))
    layers = jax.tree.map(lambda *xs: jnp.stack(xs), *layers)
    dec = _mlp_init(ks[2], (d, d, cfg.n_vars), dt)
    return dict(enc_node=enc_node, enc_edge=enc_edge, layers=layers, dec=dec)


def graphcast_forward(params, cfg: GNNConfig, gb: GraphBatch):
    N = gb.node_feats.shape[0]
    dt = DTYPES[cfg.dtype]
    h = _mlp(params["enc_node"], gb.node_feats.astype(dt))
    e = _mlp(params["enc_edge"],
             jnp.concatenate([h[gb.edge_src], h[gb.edge_dst]], -1))
    m = gb.edge_mask[:, None].astype(dt)

    def body(carry, lyr):
        h, e = carry
        e_in = jnp.concatenate([e, h[gb.edge_src], h[gb.edge_dst]], -1)
        e = e + _mlp(lyr["edge_mlp"], e_in) * m
        agg = _seg_sum(e * m, gb.edge_dst, N)
        h = h + _mlp(lyr["node_mlp"], jnp.concatenate([h, agg], -1))
        return (h, e), None

    (h, e), _ = jax.lax.scan(body, (h, e), params["layers"])
    return _mlp(params["dec"], h)                       # (N, n_vars)


# =========================================================================== #
# SchNet
# =========================================================================== #
def init_schnet(key, cfg: GNNConfig, d_feat: int):
    dt = DTYPES[cfg.dtype]
    d, R = cfg.d_hidden, cfg.n_rbf
    ks = jax.random.split(key, 2 + 3 * cfg.n_layers)
    emb = _mlp_init(ks[0], (d_feat, d), dt)
    layers = []
    for i in range(cfg.n_layers):
        k1, k2, k3 = jax.random.split(ks[1 + i], 3)
        layers.append(dict(filt=_mlp_init(k1, (R, d, d), dt),
                           w_in=_init(k2, (d, d), dtype=dt),
                           out=_mlp_init(k3, (d, d, d), dt)))
    layers = jax.tree.map(lambda *xs: jnp.stack(xs), *layers)
    head = _mlp_init(ks[-1], (d, d // 2, 1), dt)
    return dict(emb=emb, layers=layers, head=head)


def _rbf(dist, n_rbf: int, cutoff: float):
    centers = jnp.linspace(0.0, cutoff, n_rbf)
    gamma = n_rbf / cutoff
    return jnp.exp(-gamma * (dist[:, None] - centers) ** 2)


def schnet_forward(params, cfg: GNNConfig, gb: GraphBatch):
    """Continuous-filter convolution; returns per-node scalar (summed into a
    per-graph energy when graph_id is present)."""
    N = gb.node_feats.shape[0]
    dt = DTYPES[cfg.dtype]
    pos = gb.positions
    assert pos is not None, "schnet needs positions"
    h = _mlp(params["emb"], gb.node_feats.astype(dt))
    dvec = pos[gb.edge_src] - pos[gb.edge_dst]
    dist = jnp.sqrt((dvec * dvec).sum(-1) + 1e-12)
    rbf = _rbf(dist, cfg.n_rbf, cfg.cutoff).astype(dt)
    # cosine cutoff envelope
    env = 0.5 * (jnp.cos(jnp.pi * jnp.clip(dist / cfg.cutoff, 0, 1)) + 1)
    m = (gb.edge_mask * (dist < cfg.cutoff)).astype(dt)[:, None] * env[:, None].astype(dt)

    def body(h, lyr):
        W = _mlp(lyr["filt"], rbf)                      # (E, d)
        msg = (h @ lyr["w_in"])[gb.edge_src] * W * m
        agg = _seg_sum(msg, gb.edge_dst, N)
        return h + _mlp(lyr["out"], agg), None

    h, _ = jax.lax.scan(body, h, params["layers"])
    atom_out = _mlp(params["head"], h)[:, 0]            # (N,)
    return atom_out


# =========================================================================== #
# PNA
# =========================================================================== #
def init_pna(key, cfg: GNNConfig, d_feat: int, n_out: int):
    dt = DTYPES[cfg.dtype]
    d = cfg.d_hidden
    n_tow = len(cfg.aggregators) * len(cfg.scalers)
    ks = jax.random.split(key, 3 + cfg.n_layers)
    enc = _mlp_init(ks[0], (d_feat, d), dt)
    layers = []
    for i in range(cfg.n_layers):
        k1, k2 = jax.random.split(ks[1 + i])
        layers.append(dict(pre=_mlp_init(k1, (2 * d, d), dt),
                           post=_mlp_init(k2, (n_tow * d + d, d), dt)))
    layers = jax.tree.map(lambda *xs: jnp.stack(xs), *layers)
    dec = _mlp_init(ks[-1], (d, n_out), dt)
    return dict(enc=enc, layers=layers, dec=dec)


def pna_forward(params, cfg: GNNConfig, gb: GraphBatch, avg_log_deg: float = 2.0):
    N = gb.node_feats.shape[0]
    dt = DTYPES[cfg.dtype]
    h = _mlp(params["enc"], gb.node_feats.astype(dt))
    mask = gb.edge_mask
    deg = _seg_sum(mask.astype(jnp.float32)[:, None], gb.edge_dst, N)[:, 0]
    log_deg = jnp.log1p(deg)[:, None].astype(dt)

    def body(h, lyr):
        msg = _mlp(lyr["pre"], jnp.concatenate([h[gb.edge_src], h[gb.edge_dst]], -1))
        msg = msg * mask[:, None].astype(dt)
        aggs = []
        mean = _seg_mean(msg, gb.edge_dst, N, mask)
        for a in cfg.aggregators:
            if a == "mean":
                aggs.append(mean)
            elif a == "max":
                mx = _seg_max(jnp.where(mask[:, None], msg, -1e9).astype(
                    jnp.float32), gb.edge_dst, N)
                aggs.append(jnp.where(deg[:, None] > 0, mx, 0).astype(dt))
            elif a == "min":
                mn = -_seg_max(jnp.where(mask[:, None], -msg, -1e9).astype(
                    jnp.float32), gb.edge_dst, N)
                aggs.append(jnp.where(deg[:, None] > 0, mn, 0).astype(dt))
            elif a == "std":
                sq = _seg_mean(msg * msg, gb.edge_dst, N, mask)
                var = jnp.maximum((sq - mean * mean).astype(jnp.float32), 0)
                aggs.append(jnp.sqrt(var + 1e-5).astype(dt))  # eps: finite grad
        towers = []
        for agg in aggs:
            for s in cfg.scalers:
                if s == "identity":
                    towers.append(agg)
                elif s == "amplification":
                    towers.append(agg * log_deg / avg_log_deg)
                elif s == "attenuation":
                    towers.append(agg * avg_log_deg / jnp.maximum(log_deg, 1e-3))
        cat = jnp.concatenate(towers + [h], -1)
        return h + _mlp(lyr["post"], cat), None

    h, _ = jax.lax.scan(body, h, params["layers"])
    return _mlp(params["dec"], h)


# =========================================================================== #
# GAT
# =========================================================================== #
def init_gat(key, cfg: GNNConfig, d_feat: int, n_out: int):
    dt = DTYPES[cfg.dtype]
    d, H = cfg.d_hidden, cfg.n_heads
    ks = jax.random.split(key, cfg.n_layers)
    layers = []
    dims_in = [d_feat] + [d * H] * (cfg.n_layers - 1)
    dims_out = [d] * (cfg.n_layers - 1) + [n_out]
    heads = [H] * (cfg.n_layers - 1) + [H]
    for i in range(cfg.n_layers):
        k1, k2, k3 = jax.random.split(ks[i], 3)
        layers.append(dict(
            w=_init(k1, (dims_in[i], heads[i] * dims_out[i]), dtype=dt),
            a_src=_init(k2, (heads[i], dims_out[i]), dtype=dt),
            a_dst=_init(k3, (heads[i], dims_out[i]), dtype=dt)))
    return dict(layers=layers)


def gat_forward(params, cfg: GNNConfig, gb: GraphBatch):
    """SDDMM edge scores -> segment softmax -> SpMM. Last layer averages
    heads (classification head), earlier layers concat + ELU.

    ``ctx.CURRENT.gnn_bf16_msgs`` keeps the segment-softmax partials and
    messages in bf16 — on an edge-sharded full-batch graph every segment op
    all-reduces an (N, H)/(N, H, d) partial across the data axis, so the
    payload dtype directly scales the collective term (§Perf iteration on
    gat-cora x ogb_products)."""
    from repro.distributed import ctx as _ctx
    bf16_msgs = _ctx.CURRENT.gnn_bf16_msgs
    acc_dt = jnp.bfloat16 if bf16_msgs else jnp.float32
    N = gb.node_feats.shape[0]
    dt = DTYPES[cfg.dtype]
    h = gb.node_feats.astype(dt)
    mask = gb.edge_mask
    n_layers = len(params["layers"])
    for i, lyr in enumerate(params["layers"]):
        H, dout = lyr["a_src"].shape           # static from weight shapes
        hw = (h @ lyr["w"]).reshape(N, H, dout)
        s_src = (hw * lyr["a_src"]).sum(-1)             # (N, H)
        s_dst = (hw * lyr["a_dst"]).sum(-1)
        score = jax.nn.leaky_relu(
            s_src[gb.edge_src] + s_dst[gb.edge_dst], 0.2).astype(jnp.float32)
        score = jnp.where(mask[:, None], score, -jnp.inf)
        smax = _seg_max(score, gb.edge_dst, N)          # (N, H) f32 (exactness)
        ex = jnp.exp(score - smax[gb.edge_dst]).astype(acc_dt)
        ex = jnp.where(mask[:, None], ex, 0)
        den = _seg_sum(ex, gb.edge_dst, N)
        alpha = (ex.astype(jnp.float32)
                 / jnp.maximum(den.astype(jnp.float32)[gb.edge_dst], 1e-9)
                 ).astype(dt)
        out = _seg_sum((alpha[..., None] * hw[gb.edge_src]).astype(acc_dt),
                       gb.edge_dst, N)
        if i < n_layers - 1:
            h = jax.nn.elu(out.astype(jnp.float32)).astype(dt).reshape(
                N, H * dout)
        else:
            h = out.astype(jnp.float32).mean(axis=1)    # (N, n_out)
    return h


# =========================================================================== #
# uniform entry points
# =========================================================================== #
def init_gnn(key, cfg: GNNConfig, d_feat: int, n_out: int):
    if cfg.kind == "graphcast":
        return init_graphcast(key, cfg, d_feat)
    if cfg.kind == "schnet":
        return init_schnet(key, cfg, d_feat)
    if cfg.kind == "pna":
        return init_pna(key, cfg, d_feat, n_out)
    if cfg.kind == "gat":
        return init_gat(key, cfg, d_feat, n_out)
    raise KeyError(cfg.kind)


def gnn_forward(params, cfg: GNNConfig, gb: GraphBatch):
    if cfg.kind == "graphcast":
        return graphcast_forward(params, cfg, gb)
    if cfg.kind == "schnet":
        return schnet_forward(params, cfg, gb)
    if cfg.kind == "pna":
        return pna_forward(params, cfg, gb)
    if cfg.kind == "gat":
        return gat_forward(params, cfg, gb)
    raise KeyError(cfg.kind)


def gnn_loss(params, cfg: GNNConfig, gb: GraphBatch):
    out = gnn_forward(params, cfg, gb)
    mask = gb.label_mask.astype(jnp.float32)
    if cfg.kind == "schnet":
        # per-graph energy regression (sum-pool over graph_id when present)
        if gb.graph_id is not None:
            n_graphs = int(gb.labels.shape[0])
            energy = jax.ops.segment_sum(out, gb.graph_id, num_segments=n_graphs)
            err = (energy - gb.labels.astype(jnp.float32)) ** 2
            return err.mean()
        err = (out - gb.labels.astype(jnp.float32)) ** 2
        return (err * mask).sum() / jnp.maximum(mask.sum(), 1)
    if cfg.kind == "graphcast":
        err = (out.astype(jnp.float32) - gb.labels.astype(jnp.float32)) ** 2
        return (err.mean(-1) * mask).sum() / jnp.maximum(mask.sum(), 1)
    # classification (gat, pna)
    logits = out.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(
        logits, gb.labels[:, None].astype(jnp.int32), axis=-1)[:, 0]
    ce = lse - picked
    return (ce * mask).sum() / jnp.maximum(mask.sum(), 1)
