"""Per-process worker + local launcher for the ``dist`` exchange backend.

One OS process per graph partition, joined into a single JAX computation
by ``jax.distributed``:

``python -m repro.launch.dist_worker --coordinator HOST:PORT \
    --num-processes N --process-id I --dataset dblp_bench --query q1``

The bootstrap order is load-bearing and lives in :mod:`repro.compat`
(:func:`~repro.compat.enable_cpu_collectives` MUST run before the CPU
backend client exists, :func:`~repro.compat.distributed_initialize`
before any device use) — this module only sequences the calls before the
heavy imports.  Exit code ``3`` means "multi-process bootstrap
unavailable on this build": callers (tests, the scalability harness)
treat it as a clean skip, never a failure.

Every process loads the same deterministic dataset, computes the same
partition, and runs :func:`repro.core.driver.rads_enumerate` with
``mode="dist"`` over a mesh spanning all processes — per-process results
are byte-identical by construction (the replicated finalize), so each
worker writes its full stats JSON to ``--out`` and the launcher merges
them with :func:`repro.core.driver.merge_process_stats`, which *asserts*
that identity.

:func:`launch_local` spawns N single-device worker subprocesses against a
coordinator on a free localhost port — the container stand-in for real
multi-host launches (same flags, one host).
"""
from __future__ import annotations

import json
import os
import socket
import subprocess
import sys
import tempfile
import time

# exit code contract with launch_local / tests: clean "cannot run here"
EXIT_BOOTSTRAP_UNAVAILABLE = 3


def dist_available() -> bool:
    """Cheap probe: does this jaxlib ship gloo CPU collectives at all?"""
    from repro import compat

    return compat.HAS_MULTIPROCESS_CPU


def build_argparser():
    import argparse

    ap = argparse.ArgumentParser(
        description="one process of a multi-process dist enumeration run")
    ap.add_argument("--coordinator", default="127.0.0.1:0",
                    help="jax.distributed coordinator HOST:PORT (process 0 "
                         "binds it; all processes dial it)")
    ap.add_argument("--num-processes", type=int, default=1)
    ap.add_argument("--process-id", type=int, default=0)
    ap.add_argument("--dataset", default="dblp_bench")
    ap.add_argument("--query", default="q1")
    ap.add_argument("--partition", default="bfs",
                    choices=["bfs", "block", "hash"])
    ap.add_argument("--wire", default="raw",
                    choices=["raw", "varint", "auto"])
    ap.add_argument("--no-cache", action="store_true",
                    help="disable the foreign-adjacency cache")
    ap.add_argument("--comm-pipeline", action="store_true",
                    help="chunked back-to-back sub-exchanges per a2a")
    ap.add_argument("--comm-chunks", type=int, default=4)
    # engine capacities (power-of-two ladder; defaults = EngineConfig's) —
    # the scalability harness passes these so its in-process sim parity
    # runs share the exact configuration, making stats byte-comparable
    ap.add_argument("--frontier-cap", type=int, default=0,
                    help="0 = EngineConfig default")
    ap.add_argument("--fetch-cap", type=int, default=0)
    ap.add_argument("--verify-cap", type=int, default=0)
    ap.add_argument("--region-budget", type=int, default=0)
    ap.add_argument("--out", default="",
                    help="write {count, wall_s, stats} JSON here")
    ap.add_argument("--trace", default="",
                    help="write this process's Chrome trace-event JSON "
                         "(with >1 process the process id is inserted "
                         "before the extension: out.json -> out.p0.json; "
                         "merge lanes with `python -m tools.merge_traces`)")
    ap.add_argument("--metrics-out", default="",
                    help="export this process's metrics registry (*.prom = "
                         "Prometheus textfile, else JSON; per-process path "
                         "derivation as for --trace)")
    return ap


def _per_process_path(path: str, process_id: int, nproc: int) -> str:
    """launch_local hands every worker identical args, so per-process
    artifact paths derive from the shared one: ``t.json -> t.p2.json``."""
    if nproc <= 1:
        return path
    root, ext = os.path.splitext(path)
    return f"{root}.p{process_id}{ext or '.json'}"


def worker_config(args):
    """The EngineConfig a worker invocation resolves to — shared with the
    scalability harness's in-process ``sim`` parity runs so both sides
    compare byte-for-byte."""
    import dataclasses

    from repro.configs.rads import DEFAULT_ENGINE

    cfg = dataclasses.replace(DEFAULT_ENGINE,
                              wire_format=args.wire,
                              enable_cache=not args.no_cache,
                              comm_pipeline=args.comm_pipeline,
                              comm_chunks=args.comm_chunks)
    caps = dict(frontier_cap=args.frontier_cap, fetch_cap=args.fetch_cap,
                verify_cap=args.verify_cap,
                region_group_budget=args.region_budget)
    return dataclasses.replace(
        cfg, **{k: v for k, v in caps.items() if v})


def main(argv=None) -> int:
    args = build_argparser().parse_args(argv)

    # ---- bootstrap (before any jax device use — see module docstring) ----- #
    from repro import compat

    if args.num_processes > 1:
        if not compat.enable_cpu_collectives():
            return EXIT_BOOTSTRAP_UNAVAILABLE
        if not compat.distributed_initialize(args.coordinator,
                                             args.num_processes,
                                             args.process_id):
            return EXIT_BOOTSTRAP_UNAVAILABLE

    import jax

    if jax.device_count() != args.num_processes:
        # one device per process is the launch contract (the engine mesh
        # axis is the process axis); a mismatched topology would silently
        # change the partition count, so refuse as "unavailable"
        print(f"[dist] device/process topology mismatch: "
              f"{jax.device_count()} global devices for "
              f"{args.num_processes} processes", file=sys.stderr)
        return EXIT_BOOTSTRAP_UNAVAILABLE

    from repro.configs.rads import CLIQUE_QUERIES, QUERIES
    from repro.core import Pattern, rads_enumerate
    from repro.graph import load_dataset, partition
    from repro.launch.mesh import make_engine_mesh

    pattern = Pattern.from_edges({**QUERIES, **CLIQUE_QUERIES}[args.query])
    g = load_dataset(args.dataset)          # deterministic: identical on
    pg = partition(g, args.num_processes,   # every process by construction
                   method=args.partition)
    cfg = worker_config(args)
    mesh = make_engine_mesh(args.num_processes)
    tracer = None
    if args.trace:
        from repro.obs import TraceRecorder

        # the Chrome pid lane IS the process index — merged traces keep
        # one lane group per process (see repro.obs dist merge contract)
        tracer = TraceRecorder(pid=args.process_id)
    t0 = time.perf_counter()
    res = rads_enumerate(pg, pattern, cfg, mode="dist", mesh=mesh,
                         return_embeddings=False, tracer=tracer)
    wall_s = time.perf_counter() - t0
    payload = dict(count=int(res.count), wall_s=wall_s,
                   process_id=args.process_id,
                   num_processes=args.num_processes,
                   dataset=args.dataset, query=args.query,
                   stats=res.stats)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(payload, f, default=float)
    if tracer is not None:
        tracer.save(_per_process_path(args.trace, args.process_id,
                                      args.num_processes))
    if args.metrics_out:
        mpath = _per_process_path(args.metrics_out, args.process_id,
                                  args.num_processes)
        if mpath.endswith(".prom"):
            res.registry.export_prometheus(mpath)
        else:
            res.registry.export_json(mpath)
    print(f"[dist] p{args.process_id}/{args.num_processes} "
          f"{args.dataset}/{args.query}: count={res.count} "
          f"wall={wall_s:.2f}s wire="
          f"{res.stats['bytes_wire_fetch'] + res.stats['bytes_wire_verify']:.0f}B | "
          + res.registry.summary(("wall_us", "compiles", "comm_pipeline")))
    return 0


# --------------------------------------------------------------------------- #
# Local multi-process launcher (container stand-in for multi-host)
# --------------------------------------------------------------------------- #
def _free_port() -> int:
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def _src_dir() -> str:
    import repro

    # repro is a namespace package (no __init__.py): __file__ is None, so
    # resolve the source root from __path__ instead
    return os.path.dirname(os.path.abspath(next(iter(repro.__path__))))


def launch_local(nproc: int, worker_args: list[str],
                 timeout_s: float = 1200.0) -> list[dict] | None:
    """Run one ``dist`` enumeration across ``nproc`` local subprocesses.

    Each worker gets exactly one CPU device
    (``--xla_force_host_platform_device_count=1``) so the process axis IS
    the device axis — the same flags drive a real multi-host launch with
    one command per host.  Returns the per-process result payloads
    ordered by process id, or ``None`` when the bootstrap is unavailable
    (any worker exited ``EXIT_BOOTSTRAP_UNAVAILABLE``); any other failure
    raises with the worker's output attached."""
    port = _free_port()
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=1"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = _src_dir() + os.pathsep + env.get("PYTHONPATH", "")
    outs = [tempfile.NamedTemporaryFile(suffix=f".dist{i}.json",
                                        delete=False).name
            for i in range(nproc)]
    procs = []
    try:
        for i in range(nproc):
            cmd = [sys.executable, "-m", "repro.launch.dist_worker",
                   "--coordinator", f"127.0.0.1:{port}",
                   "--num-processes", str(nproc), "--process-id", str(i),
                   *worker_args, "--out", outs[i]]
            procs.append(subprocess.Popen(
                cmd, env=env, stdout=subprocess.PIPE,
                stderr=subprocess.STDOUT, text=True))
        deadline = time.monotonic() + timeout_s
        logs = []
        for p in procs:
            remaining = max(1.0, deadline - time.monotonic())
            try:
                out, _ = p.communicate(timeout=remaining)
            except subprocess.TimeoutExpired:
                for q in procs:
                    q.kill()
                raise RuntimeError(
                    f"dist worker timed out after {timeout_s:.0f}s")
            logs.append(out or "")
        codes = [p.returncode for p in procs]
        if any(c == EXIT_BOOTSTRAP_UNAVAILABLE for c in codes):
            return None
        if any(c != 0 for c in codes):
            detail = "\n".join(
                f"--- worker {i} (exit {codes[i]}) ---\n{logs[i][-2000:]}"
                for i in range(nproc) if codes[i] != 0)
            raise RuntimeError(f"dist workers failed:\n{detail}")
        results = []
        for i, path in enumerate(outs):
            with open(path) as f:
                results.append(json.load(f))
        return results
    finally:
        for p in procs:
            if p.poll() is None:
                p.kill()
        for path in outs:
            try:
                os.remove(path)
            except OSError:
                pass


if __name__ == "__main__":
    sys.exit(main())
