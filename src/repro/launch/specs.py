"""Per-(arch x shape) step functions + ShapeDtypeStruct input specs +
shardings — the single source of truth the dry-run, roofline and perf loop
all consume.

``build_cell(arch_id, shape_name, mesh, opt)`` returns a ``Cell`` with:
  fn           — the function to lower (train_step / prefill / serve_step)
  arg_specs    — pytree of jax.ShapeDtypeStruct (weak-type-correct, no
                 device allocation)
  in_shardings — matching pytree of NamedSharding
  meta         — model-flops estimates etc. for §Roofline
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.configs.base import GNNConfig, RecsysConfig, TransformerConfig
from repro.distributed.sharding import dp_axes, param_shardings
from repro.graph.sampler import sample_capacities
from repro.models.gnn import GraphBatch, gnn_loss, init_gnn
from repro.models.recsys import DINBatch, din_loss, init_din, retrieval_scores
from repro.models.transformer import (cache_spec, decode_step, init_lm_params,
                                      lm_loss, prefill)
from repro.optim.adamw import AdamWConfig, adamw_update, init_opt_state

F32, BF16, I32, BOOL = jnp.float32, jnp.bfloat16, jnp.int32, jnp.bool_


@dataclass
class Cell:
    arch: str
    shape: str
    fn: Callable
    arg_specs: tuple
    in_shardings: tuple
    meta: dict = field(default_factory=dict)


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _rep(mesh):
    return NamedSharding(mesh, P())


def _eval_params(init_fn, *args):
    return jax.eval_shape(lambda k: init_fn(k, *args), jax.random.PRNGKey(0))


# --------------------------------------------------------------------------- #
# LM cells
# --------------------------------------------------------------------------- #
def _lm_train_cell(arch, shape, cfg: TransformerConfig, mesh, opt: AdamWConfig,
                   remat: bool = True):
    p_spec = _eval_params(init_lm_params, cfg)
    p_sh = param_shardings(p_spec, "lm", mesh)
    o_spec = jax.eval_shape(lambda p: init_opt_state(p, opt), p_spec)
    o_sh = dict(mu=p_sh, nu=p_sh, step=_rep(mesh))
    B, S = shape["global_batch"], shape["seq_len"]
    dp = dp_axes(mesh)
    dpa = dp if len(dp) > 1 else dp[0]
    tok_sh = NamedSharding(mesh, P(dpa, None))
    lg_sh = NamedSharding(mesh, P(dpa, None, "model"))
    hid_sh = NamedSharding(mesh, P(dpa, None, None))

    def train_step(params, opt_state, tokens, labels):
        loss, grads = jax.value_and_grad(
            lambda p: lm_loss(p, cfg, tokens, labels, remat=remat,
                              logits_sharding=lg_sh,
                              hidden_sharding=hid_sh))(params)
        params, opt_state, info = adamw_update(params, grads, opt_state, opt)
        return params, opt_state, loss, info["grad_norm"]

    toks = _sds((B, S), I32)
    D = 2 * B * S  # tokens * 2 (fwd тokens incl labels irrelevant)
    model_flops = 6 * cfg.active_param_count() * B * S * (3 if remat else 3)
    # 6ND fwd+bwd; remat adds ~1 extra fwd -> noted separately
    meta = dict(model_flops=6 * cfg.active_param_count() * B * S,
                model_flops_remat=8 * cfg.active_param_count() * B * S,
                tokens=B * S, scan_trip=cfg.n_layers)
    return Cell(arch, shape.name, train_step,
                (p_spec, o_spec, toks, toks),
                (p_sh, o_sh, tok_sh, tok_sh), meta)


def _lm_prefill_cell(arch, shape, cfg, mesh, variant: str = "baseline"):
    p_spec = _eval_params(init_lm_params, cfg)
    p_sh = param_shardings(p_spec, "lm", mesh)
    B, S = shape["global_batch"], shape["seq_len"]
    dp = dp_axes(mesh)
    dpa = dp if len(dp) > 1 else dp[0]
    tok_sh = NamedSharding(mesh, P(dpa, None))
    if variant == "baseline":
        def prefill_step(params, tokens):
            logits, cache = prefill(params, cfg, tokens)
            return logits[:, -1], cache
    else:
        # opt: sharded cache init/updates + last-token-only logits (§Perf)
        cs = cache_spec(cfg, B, S)
        c_sh = {k: NamedSharding(mesh, P(None, dpa, "model",
                                         *([None] * (len(s) - 3))))
                for k, (s, d) in cs.shapes.items()}

        def prefill_step(params, tokens):
            logits, cache = prefill(params, cfg, tokens,
                                    cache_shardings=c_sh, last_only=True)
            return logits[:, -1], cache

    meta = dict(model_flops=2 * cfg.active_param_count() * B * S
                + _attn_flops(cfg, B, S), tokens=B * S,
                scan_trip=cfg.n_layers)
    return Cell(arch, shape.name, prefill_step,
                (p_spec, _sds((B, S), I32)), (p_sh, tok_sh), meta)


def _attn_flops(cfg: TransformerConfig, B, S, causal=True):
    hd = cfg.head_dim if cfg.mla is None else (
        cfg.mla.qk_nope_head_dim + cfg.mla.qk_rope_head_dim
        + cfg.mla.v_head_dim) // 2
    f = 2 * B * cfg.n_heads * S * S * hd * 2  # qk + pv
    return f // 2 if causal else f


def _lm_decode_cell(arch, shape, cfg, mesh, long: bool = False):
    p_spec = _eval_params(init_lm_params, cfg)
    p_sh = param_shardings(p_spec, "lm", mesh)
    B, S = shape["global_batch"], shape["seq_len"]
    dp = dp_axes(mesh)
    dpa = dp if len(dp) > 1 else dp[0]
    cs = cache_spec(cfg, B, S)
    c_spec = {k: _sds(s, d) for k, (s, d) in cs.shapes.items()}
    if long:
        # batch=1: shard the *sequence* axis of the cache (data axis), model
        # axis left for attention-head/TP sharding of the weights
        c_sh = {k: NamedSharding(mesh, P(None, None, dpa,
                                         *([None] * (len(s) - 3))))
                for k, (s, d) in cs.shapes.items()}
        tok_sh = _rep(mesh)
    else:
        # batch over data axes, sequence over model axis
        c_sh = {k: NamedSharding(mesh, P(None, dpa, "model",
                                         *([None] * (len(s) - 3))))
                for k, (s, d) in cs.shapes.items()}
        tok_sh = NamedSharding(mesh, P(dpa))

    absorbed = cfg.mla is not None

    def serve_step(params, cache, tokens, length):
        return decode_step(params, cfg, cache, tokens, length,
                           absorbed=absorbed)

    kv_bytes = sum(int(np.prod(s)) * 2 for s, _ in cs.shapes.values())
    meta = dict(model_flops=2 * cfg.active_param_count() * B
                + 2 * B * kv_bytes,   # decode reads the whole cache
                kv_cache_bytes=kv_bytes, tokens=B, scan_trip=cfg.n_layers)
    return Cell(arch, shape.name, serve_step,
                (p_spec, c_spec, _sds((B,), I32), _sds((), I32)),
                (p_sh, c_sh, tok_sh, _rep(mesh)), meta)


# --------------------------------------------------------------------------- #
# GNN cells
# --------------------------------------------------------------------------- #
def _gnn_batch_specs(cfg: GNNConfig, N, E, d_feat, mesh, n_out):
    from repro.distributed import ctx
    dp = dp_axes(mesh)
    dpa = dp if len(dp) > 1 else dp[0]
    e_sh = NamedSharding(mesh, P(dpa))
    if ctx.CURRENT.gnn_replicate_nodes:
        # §Perf gat iter 2: node arrays replicated -> src-feature gathers
        # become local; only the (N, H)-sized aggregation partials reduce
        n_sh = NamedSharding(mesh, P())
        dpa = None
    else:
        n_sh = NamedSharding(mesh, P(dpa, None))
    if cfg.kind == "graphcast":
        labels = _sds((N, cfg.n_vars), F32)
    elif cfg.kind == "schnet":
        labels = _sds((N,), F32)
    else:
        labels = _sds((N,), I32)
    gb = GraphBatch(
        node_feats=_sds((N, d_feat), BF16),
        edge_src=_sds((E,), I32), edge_dst=_sds((E,), I32),
        edge_mask=_sds((E,), BOOL), labels=labels,
        label_mask=_sds((N,), BOOL),
        positions=_sds((N, 3), F32) if cfg.kind == "schnet" else None,
        graph_id=None)
    gb_sh = GraphBatch(
        node_feats=n_sh, edge_src=e_sh, edge_dst=e_sh, edge_mask=e_sh,
        labels=NamedSharding(mesh, P(dpa, None)) if cfg.kind == "graphcast"
        else NamedSharding(mesh, P(dpa)),
        label_mask=NamedSharding(mesh, P(dpa)),
        positions=n_sh if cfg.kind == "schnet" else None,
        graph_id=None)
    return gb, gb_sh


def _dp_total(mesh):
    t = 1
    for a in dp_axes(mesh):
        t *= mesh.shape[a]
    return t


def _gnn_cell(arch, shape, cfg: GNNConfig, mesh, opt: AdamWConfig):
    n_out = cfg.n_classes
    if shape.kind == "minibatch":
        N, E = sample_capacities(shape["batch_nodes"],
                                 (shape["fanout0"], shape["fanout1"]))
    elif shape.kind == "batched_graphs":
        N = shape["n_nodes"] * shape["batch"]
        E = shape["n_edges"] * 2 * shape["batch"]
    else:
        N, E = shape["n_nodes"], shape["n_edges"]
    # pad node/edge counts to the DP width (masked padding is already part
    # of the GraphBatch contract — the loaders pad the same way)
    m = _dp_total(mesh)
    N = -(-N // m) * m
    E = -(-E // m) * m
    d_feat = shape.dims.get("d_feat", 16)
    p_spec = _eval_params(partial(init_gnn, cfg=cfg, d_feat=d_feat,
                                  n_out=n_out)
                          if False else lambda k: init_gnn(k, cfg, d_feat, n_out))
    p_sh = param_shardings(p_spec, "gnn", mesh)
    o_spec = jax.eval_shape(lambda p: init_opt_state(p, opt), p_spec)
    o_sh = jax.tree.map(lambda _: _rep(mesh), o_spec)
    gb, gb_sh = _gnn_batch_specs(cfg, N, E, d_feat, mesh, n_out)

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: gnn_loss(p, cfg, batch))(params)
        params, opt_state, info = adamw_update(params, grads, opt_state, opt)
        return params, opt_state, loss, info["grad_norm"]

    d = cfg.d_hidden
    meta = dict(model_flops=int(cfg.n_layers * (4 * E * d * d + 8 * N * d * d)),
                n_nodes=N, n_edges=E, scan_trip=cfg.n_layers)
    return Cell(arch, shape.name, train_step,
                (p_spec, o_spec, gb), (p_sh, o_sh, gb_sh), meta)


# --------------------------------------------------------------------------- #
# RecSys cells
# --------------------------------------------------------------------------- #
def _din_batch_specs(cfg: RecsysConfig, B, mesh):
    dp = dp_axes(mesh)
    dpa = dp if len(dp) > 1 else dp[0]
    if B % _dp_total(mesh) == 0:
        b1 = NamedSharding(mesh, P(dpa))
        b2 = NamedSharding(mesh, P(dpa, None))
    else:  # tiny batches (retrieval B=1): replicate
        b1 = b2 = NamedSharding(mesh, P())
    T = cfg.seq_len
    batch = DINBatch(
        user_feats=_sds((B, 4), I32), target_item=_sds((B,), I32),
        target_cate=_sds((B,), I32), hist_items=_sds((B, T), I32),
        hist_cates=_sds((B, T), I32), hist_mask=_sds((B, T), BOOL),
        labels=_sds((B,), F32))
    sh = DINBatch(user_feats=b2, target_item=b1, target_cate=b1,
                  hist_items=b2, hist_cates=b2, hist_mask=b2, labels=b1)
    return batch, sh


def _din_cell(arch, shape, cfg: RecsysConfig, mesh, opt: AdamWConfig):
    p_spec = _eval_params(lambda k: init_din(k, cfg))
    p_sh = param_shardings(p_spec, "recsys", mesh)
    kind = shape.kind
    d = cfg.embed_dim
    if kind == "retrieval":
        B, NC = shape["batch"], shape["n_candidates"]
        all_ax = 1
        for a in mesh.axis_names:
            all_ax *= mesh.shape[a]
        NC = -(-NC // all_ax) * all_ax     # pad candidate set to mesh width
        batch, b_sh = _din_batch_specs(cfg, B, mesh)
        cand_sh = NamedSharding(mesh, P(tuple(mesh.axis_names)))

        def retrieval_step(params, batch, cand_items, cand_cates):
            return retrieval_scores(params, cfg, batch, cand_items, cand_cates)

        meta = dict(model_flops=2 * B * NC * 2 * d, candidates=NC)
        return Cell(arch, shape.name, retrieval_step,
                    (p_spec, batch, _sds((NC,), I32), _sds((NC,), I32)),
                    (p_sh, b_sh, cand_sh, cand_sh), meta)
    B = shape["batch"]
    batch, b_sh = _din_batch_specs(cfg, B, mesh)
    if kind == "train":
        o_spec = jax.eval_shape(lambda p: init_opt_state(p, opt), p_spec)
        o_sh = param_shardings(o_spec["mu"], "recsys", mesh)
        o_shard = dict(mu=o_sh, nu=o_sh, step=_rep(mesh))

        def train_step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(
                lambda p: din_loss(p, cfg, batch))(params)
            params, opt_state, info = adamw_update(params, grads, opt_state, opt)
            return params, opt_state, loss, info["grad_norm"]

        mlp_f = (4 * 2 * d) * cfg.attn_mlp[0] + cfg.attn_mlp[0] * cfg.attn_mlp[1]
        meta = dict(model_flops=6 * B * (cfg.seq_len * mlp_f
                                         + (7 * d) * cfg.mlp[0]
                                         + cfg.mlp[0] * cfg.mlp[1]))
        return Cell(arch, shape.name, train_step,
                    (p_spec, o_spec, batch), (p_sh, o_shard, b_sh), meta)

    def serve_step(params, batch):
        from repro.models.recsys import din_logits
        return jax.nn.sigmoid(din_logits(params, cfg, batch))

    mlp_f = (4 * 2 * d) * cfg.attn_mlp[0] + cfg.attn_mlp[0] * cfg.attn_mlp[1]
    meta = dict(model_flops=2 * B * (cfg.seq_len * mlp_f
                                     + (7 * d) * cfg.mlp[0]
                                     + cfg.mlp[0] * cfg.mlp[1]))
    return Cell(arch, shape.name, serve_step, (p_spec, batch),
                (p_sh, b_sh), meta)


# --------------------------------------------------------------------------- #
# entry
# --------------------------------------------------------------------------- #
def build_cell(arch_id: str, shape_name: str, mesh: Mesh,
               opt: AdamWConfig | None = None, remat: bool = True,
               variant: str = "baseline") -> Cell:
    """variant='baseline' is the paper-faithful configuration; 'opt' turns
    on the hillclimbed optimizations (EXPERIMENTS.md §Perf) via ctx flags +
    spec-level changes. Baseline artifacts stay reproducible."""
    from repro.distributed import ctx
    ctx.reset()
    dp = dp_axes(mesh)
    dpa = dp if len(dp) > 1 else dp[0]
    if variant == "opt":
        ctx.set_flags(dp_axes=dpa, moe_ep_constrain=True, gnn_bf16_msgs=True)
    elif variant == "opt2":
        ctx.set_flags(dp_axes=dpa, moe_tp=True, gnn_bf16_msgs=True,
                      gnn_replicate_nodes=True)
    elif variant == "opt3":
        # deepseek iter 3: baseline EP sharding, tighter dispatch capacity
        ctx.set_flags(dp_axes=dpa, moe_capacity_factor=1.0,
                      gnn_replicate_nodes=True, gnn_bf16_msgs=True)
    opt = opt or AdamWConfig()
    ac = get_config(arch_id)
    cfg = ac.model
    shape = ac.shape(shape_name)
    if cfg.family == "lm":
        if cfg.name == "deepseek-v3-671b":
            opt = dataclasses.replace(opt, moment_dtype="bfloat16")
        if shape.kind == "train":
            return _lm_train_cell(arch_id, shape, cfg, mesh, opt, remat)
        if shape.kind == "prefill":
            return _lm_prefill_cell(arch_id, shape, cfg, mesh, variant)
        if shape.kind == "decode":
            return _lm_decode_cell(arch_id, shape, cfg, mesh, long=False)
        if shape.kind == "long_decode":
            return _lm_decode_cell(arch_id, shape, cfg, mesh, long=True)
    if cfg.family == "gnn":
        return _gnn_cell(arch_id, shape, cfg, mesh, opt)
    if cfg.family == "recsys":
        return _din_cell(arch_id, shape, cfg, mesh, opt)
    raise KeyError((arch_id, shape_name))
