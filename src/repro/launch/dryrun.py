import os
os.environ["XLA_FLAGS"] = os.environ.get("DRYRUN_XLA_FLAGS",
                                         "--xla_force_host_platform_device_count=512")
"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes, record memory/cost/collective analysis.

MUST be run as a module entry (``python -m repro.launch.dryrun``) so the
XLA_FLAGS above land before jax initializes its backends.

Usage:
  python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both
  python -m repro.launch.dryrun --list
Artifacts: experiments/artifacts/dryrun_<arch>_<shape>_<mesh>.json
"""
import argparse
import json
import re
import sys
import time
import traceback

import jax

from repro import compat
from repro.configs import ARCH_IDS, all_cells, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import build_cell
from repro.utils import dump_json, human_bytes

ARTIFACT_DIR = os.environ.get("DRYRUN_ARTIFACTS",
                              os.path.join(os.path.dirname(__file__),
                                           "../../../experiments/artifacts"))

_COLL_RE = re.compile(
    r"=\s*(.*?)\s+(all-gather|all-reduce|reduce-scatter|"
    r"all-to-all|collective-permute)(?:-start|-done)?\(", re.I)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
                "s64": 8, "u64": 8, "s8": 1, "u8": 1, "pred": 1, "s16": 2,
                "u16": 2, "f8e4m3fn": 1, "f8e5m2": 1}


def parse_collective_bytes(hlo: str) -> dict:
    """Sum output-buffer bytes of every collective op in (optimized) HLO.

    XLA's cost model (and a naive text sum) counts while-loop bodies ONCE,
    but a scanned transformer executes them n_layers times — so collectives
    are attributed to entry vs region (loop-body/branch) computations, and
    the roofline applies the static trip count to ``in_regions`` (see
    benchmarks/roofline.py; calibrated in EXPERIMENTS.md §Roofline notes)."""
    out = {"all-gather": 0, "all-reduce": 0, "reduce-scatter": 0,
           "all-to-all": 0, "collective-permute": 0, "ops": 0,
           "in_regions": 0}
    in_entry = False
    for line in hlo.splitlines():
        ls = line.lstrip()
        if ls.startswith("ENTRY "):
            in_entry = True
        elif (not line.startswith(" ")) and ls.startswith("%") \
                and ls.rstrip().endswith("{"):
            in_entry = False
        if "-done(" in line:      # -start already counted
            continue
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind = m.group(2).lower()
        nbytes = 0
        for dt, dims in _SHAPE_RE.findall(m.group(1)):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        out[kind] += nbytes
        out["ops"] += 1
        if not in_entry:
            out["in_regions"] += nbytes
    out["total"] = sum(out[k] for k in
                       ("all-gather", "all-reduce", "reduce-scatter",
                        "all-to-all", "collective-permute"))
    return out


def run_cell(arch: str, shape: str, mesh_kind: str, verbose: bool = True,
             variant: str = "baseline"):
    multi = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    t0 = time.time()
    with mesh:
        cell = build_cell(arch, shape, mesh, variant=variant)
        jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings)
        lowered = jitted.lower(*cell.arg_specs)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    mem = compat.memory_analysis(compiled)
    cost = compat.cost_analysis(compiled)
    hlo = compiled.as_text()
    coll = parse_collective_bytes(hlo)
    mem_d = {}
    if mem is not None:
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "host_argument_size_in_bytes"):
            mem_d[k] = int(getattr(mem, k, 0) or 0)
    cost_d = {}
    if cost:
        for k in ("flops", "bytes accessed", "transcendentals",
                  "utilization operand 0 {}", "bytes accessed output {}"):
            if k in cost:
                cost_d[k.replace(" ", "_").replace("{}", "").strip("_")] = \
                    float(cost[k])
        for k, v in cost.items():
            if k in ("flops", "bytes accessed"):
                cost_d[k.replace(" ", "_")] = float(v)
    rec = dict(arch=arch, shape=shape, mesh=mesh_kind, variant=variant,
               n_devices=mesh.devices.size,
               lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
               memory=mem_d, cost=cost_d, collectives=coll,
               meta={k: (int(v) if isinstance(v, (int, float)) else v)
                     for k, v in cell.meta.items()},
               ok=True)
    suffix = "" if variant == "baseline" else f"_{variant}"
    path = os.path.join(ARTIFACT_DIR,
                        f"dryrun_{arch}_{shape}_{mesh_kind}{suffix}.json")
    dump_json(path, rec)
    if verbose:
        tot = mem_d.get("temp_size_in_bytes", 0) + \
            mem_d.get("argument_size_in_bytes", 0)
        print(f"[dryrun] {arch} x {shape} x {mesh_kind} [{variant}]: OK "
              f"lower {t_lower:.1f}s compile {t_compile:.1f}s "
              f"flops/dev {cost_d.get('flops', 0):.3e} "
              f"mem/dev {human_bytes(tot)} "
              f"coll {human_bytes(coll['total'])} ({coll['ops']} ops)")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi",
                                                         "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--variant", default="baseline",
                    choices=["baseline", "opt", "opt2", "opt3"])
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()

    if args.list:
        for a, s in all_cells():
            print(f"{a:20s} {s}")
        return

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    cells = all_cells() if args.all else [(args.arch, args.shape)]
    failures = []
    for arch, shape in cells:
        for mk in meshes:
            try:
                run_cell(arch, shape, mk, variant=args.variant)
            except Exception as e:  # noqa: BLE001 — record and continue
                traceback.print_exc()
                failures.append((arch, shape, mk, str(e)))
                dump_json(os.path.join(
                    ARTIFACT_DIR, f"dryrun_{arch}_{shape}_{mk}.json"),
                    dict(arch=arch, shape=shape, mesh=mk, ok=False,
                         error=str(e)))
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", f)
        sys.exit(1)
    print("\nall dry-runs passed")


if __name__ == "__main__":
    main()
