"""Production mesh builders.

Defined as FUNCTIONS so importing this module never touches jax device
state; ``dryrun.py`` sets ``--xla_force_host_platform_device_count=512``
before any jax import and then calls these.
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) = 256 chips (data, model).
    Multi-pod: (2, 16, 16) = 512 chips (pod, data, model) — the 'pod' axis
    joins the FSDP/data-parallel group and carries the compressed gradient
    all-reduce on the slow inter-pod links."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_engine_mesh(ndev: int | None = None):
    """1-D mesh for the enumeration engine (paper workload): every chip is a
    'machine' M_t holding one graph partition."""
    ndev = ndev or len(jax.devices())
    return jax.make_mesh((ndev,), ("data",),
                         axis_types=(AxisType.Auto,))
