"""Production mesh builders.

Defined as FUNCTIONS so importing this module never touches jax device
state; ``dryrun.py`` sets ``--xla_force_host_platform_device_count=512``
before any jax import and then calls these.

All construction goes through :mod:`repro.compat` so the version-drifting
mesh-construction surface (axis-type kwargs and friends) lives in one file.
"""
from __future__ import annotations

import jax

from repro.compat import default_axis_types
from repro.compat import make_mesh as _compat_make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) = 256 chips (data, model).
    Multi-pod: (2, 16, 16) = 512 chips (pod, data, model) — the 'pod' axis
    joins the FSDP/data-parallel group and carries the compressed gradient
    all-reduce on the slow inter-pod links."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _compat_make_mesh(shape, axes,
                             axis_types=default_axis_types(len(axes)))


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    return _compat_make_mesh(shape, axes,
                             axis_types=default_axis_types(len(axes)))


def make_engine_mesh(ndev: int | None = None):
    """1-D mesh for the enumeration engine (paper workload): every chip is a
    'machine' M_t holding one graph partition."""
    ndev = ndev or len(jax.devices())
    return _compat_make_mesh((ndev,), ("data",),
                             axis_types=default_axis_types(1))
