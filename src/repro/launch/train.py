"""Training launcher: ``python -m repro.launch.train --arch qwen1.5-0.5b
--scale 0.1 --steps 200``.

On this CPU container it trains a width/depth-scaled variant of the chosen
arch with the full production stack (sharded params if >1 device, AdamW,
async checkpoints, fault-tolerant run loop). On a real pod the same entry
point runs the full config (``--scale 1``).
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_reduced
from repro.data import Prefetcher, lm_token_stream
from repro.models.transformer import init_lm_params, lm_loss
from repro.optim import AdamWConfig
from repro.runtime import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--reduced", action="store_true", default=True,
                    help="use the smoke-test reduced config (CPU-sized)")
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--grad-compression", default="none",
                    choices=["none", "int8_ef"])
    ap.add_argument("--d-model", type=int, default=0,
                    help="override width (e.g. ~100M model: 768)")
    ap.add_argument("--n-layers", type=int, default=0)
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch).model
    assert cfg.family == "lm", "train.py drives LM archs; see examples/ for others"
    over = {}
    if args.d_model:
        over["d_model"] = args.d_model
    if args.n_layers:
        over["n_layers"] = args.n_layers
    if over:
        cfg = dataclasses.replace(cfg, **over)
    print(f"[train] arch={args.arch} layers={cfg.n_layers} d={cfg.d_model} "
          f"params~{cfg.param_count()/1e6:.1f}M devices={len(jax.devices())}")

    params = init_lm_params(jax.random.PRNGKey(0), cfg)
    loss_fn = lambda p, b: lm_loss(p, cfg, jnp.asarray(b["tokens"]),
                                   jnp.asarray(b["labels"]))
    opt = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 5),
                      total_steps=args.steps)
    tcfg = TrainerConfig(ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                         grad_compression=args.grad_compression)
    tr = Trainer(loss_fn, params, opt, tcfg)
    if args.resume and tr.restore():
        print(f"[train] resumed from step {tr.step}")
    data = Prefetcher(lm_token_stream(cfg.vocab, args.batch, args.seq, seed=1))
    hist = tr.run(data, args.steps)
    print(f"[train] done: loss {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f} "
          f"median step {1e3 * sorted(h['secs'] for h in hist)[len(hist)//2]:.0f}ms")
    tr.save(blocking=True)


if __name__ == "__main__":
    main()
