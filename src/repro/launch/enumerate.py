"""Enumeration launcher (the paper's workload):
``python -m repro.launch.enumerate --dataset dblp_synth --query q3 --ndev 4``
"""
from __future__ import annotations

import argparse
import time

from repro.configs.rads import CLIQUE_QUERIES, DEFAULT_ENGINE, QUERIES, EngineConfig
from repro.core import Pattern, best_plan, rads_enumerate
from repro.graph import load_dataset, partition


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="dblp_synth")
    ap.add_argument("--query", default="q1")
    ap.add_argument("--ndev", type=int, default=4)
    ap.add_argument("--partition", default="bfs", choices=["bfs", "block", "hash"])
    ap.add_argument("--no-sme", action="store_true")
    ap.add_argument("--no-steal", action="store_true")
    ap.add_argument("--mode", default="sim", choices=["sim", "gather", "spmd"])
    ap.add_argument("--storage", default="dense",
                    help="on-device adjacency format (see "
                         "repro.graph.device_formats(): dense | bucketed)")
    ap.add_argument("--pipeline-depth", default="2",
                    help="max in-flight waves (1 = synchronous driver, "
                         "'auto' = adapt from per-wave timing)")
    ap.add_argument("--no-steal-groups", action="store_true",
                    help="disable steal-from-longest group-queue refill")
    ap.add_argument("--pallas", action="store_true",
                    help="Pallas kernels: membership in back-edge checks, "
                         "intersect in bucketed candidate generation")
    ap.add_argument("--wire", default="raw",
                    choices=["raw", "varint", "auto"],
                    help="exchange wire format: raw int32 slabs, "
                         "delta+varint / Elias-Fano coded u8 streams, or "
                         "measured per-run auto-selection from persisted "
                         "wire trials (needs --priors; core/wire.py; "
                         "results are identical)")
    ap.add_argument("--compile-cache", default="",
                    help="per-host directory for the persistent stage-"
                         "executable store (runtime/compile_cache.py); "
                         "warm runs deserialize executables instead of "
                         "tracing ('' = disabled)")
    ap.add_argument("--no-prewarm", action="store_true",
                    help="disable background stage pre-warm (resolve the "
                         "jit ladder off the critical path)")
    ap.add_argument("--cache-decay", type=int, default=None,
                    help="halve cache benefit counters every N update "
                         "batches (0 = never; default "
                         f"{DEFAULT_ENGINE.cache_decay})")
    ap.add_argument("--no-cache", action="store_true",
                    help="disable the device-resident foreign-adjacency "
                         "cache (core/cache.py)")
    ap.add_argument("--cache-slots", type=int, default=None,
                    help="cache sets per device (power of two; default "
                         f"{DEFAULT_ENGINE.cache_slots})")
    ap.add_argument("--cache-ways", type=int, default=None,
                    help="cache associativity (1 = direct-mapped; default "
                         f"{DEFAULT_ENGINE.cache_ways})")
    ap.add_argument("--priors", default="",
                    help="JSON cache of per-(pattern, graph) capacity/cost "
                         "priors; preloaded before and updated after the run")
    ap.add_argument("--trace", default="",
                    help="write a Chrome trace-event JSON of the run "
                         "(wave lanes, stage spans, dispatch->retire flow "
                         "arrows; load in Perfetto / chrome://tracing)")
    ap.add_argument("--metrics-out", default="",
                    help="export the typed metrics registry after the run: "
                         "*.prom = Prometheus textfile format, anything "
                         "else = JSON document with kind/unit/desc")
    args = ap.parse_args()
    depth = args.pipeline_depth if args.pipeline_depth == "auto" \
        else int(args.pipeline_depth)

    pattern = Pattern.from_edges({**QUERIES, **CLIQUE_QUERIES}[args.query])
    g = load_dataset(args.dataset)
    print(f"[enum] {args.dataset}: n={g.n} m={g.n_edges} | query {args.query} "
          f"(|V|={pattern.n})")
    pg = partition(g, args.ndev, method=args.partition)
    plan = best_plan(pattern)
    print(f"[enum] plan: {[(u.piv, u.leaves) for u in plan.units]} "
          f"rounds={plan.n_rounds} order={plan.matching_order}")
    import dataclasses
    cfg = dataclasses.replace(DEFAULT_ENGINE,
                              enable_sme=not args.no_sme,
                              enable_work_stealing=not args.no_steal,
                              pipeline_depth=depth,
                              steal_from_longest=not args.no_steal_groups,
                              use_pallas_kernels=args.pallas,
                              storage_format=args.storage,
                              enable_cache=not args.no_cache,
                              cache_slots=(args.cache_slots
                                           if args.cache_slots is not None
                                           else DEFAULT_ENGINE.cache_slots),
                              cache_ways=(args.cache_ways
                                          if args.cache_ways is not None
                                          else DEFAULT_ENGINE.cache_ways),
                              cache_decay=(args.cache_decay
                                           if args.cache_decay is not None
                                           else DEFAULT_ENGINE.cache_decay),
                              wire_format=args.wire,
                              priors_path=args.priors,
                              compile_cache_dir=args.compile_cache,
                              prewarm=not args.no_prewarm)
    mesh = None
    if args.mode == "spmd":
        from repro.launch.mesh import make_engine_mesh
        mesh = make_engine_mesh(args.ndev)
    tracer = None
    if args.trace:
        from repro.obs import TraceRecorder
        tracer = TraceRecorder(jax_bridge=True)
    t0 = time.perf_counter()
    res = rads_enumerate(pg, pattern, cfg, mode=args.mode, mesh=mesh,
                         return_embeddings=False, tracer=tracer)
    dt = time.perf_counter() - t0
    st = res.stats
    if tracer is not None:
        print(f"[enum] trace: {tracer.save(args.trace)} "
              f"({tracer.n_recorded} events, {tracer.n_dropped} dropped)")
    if args.metrics_out:
        if args.metrics_out.endswith(".prom"):
            res.registry.export_prometheus(args.metrics_out)
        else:
            res.registry.export_json(args.metrics_out)
        print(f"[enum] metrics: {args.metrics_out}")
    print(f"[enum] {res.count} embeddings in {dt:.2f}s | "
          f"SM-E seeds {st['n_sme_seeds']} dist seeds {st['n_dist_seeds']} | "
          f"fetchV {st['bytes_fetch']/1e6:.2f}MB verifyE "
          f"{st['bytes_verify']/1e6:.2f}MB | groups {st['n_groups']} "
          f"retries {st['overflow_retries']} escalations {st['cap_escalations']}")
    print(f"[enum] storage {st['storage_format']}: "
          f"adj {st['peak_adj_bytes'] / 1e6:.2f}MB on device | "
          f"priors preloaded {st['priors_preloaded']}")
    # the compile/store line is rendered by the registry itself (typed
    # units) instead of hand formatting each key
    print("[enum] " + res.registry.summary(
        ("compiles", "compile_s", "compile_cache_hits",
         "exec_cache_enabled", "exec_cache", "wall_us"))
        + f" | prewarm {'on' if cfg.prewarm else 'off'}")
    print(f"[enum] wire {st['wire_format']}"
          + (f" (requested {st['wire_format_requested']}, "
             f"{st['wire_auto_reason']})"
             if st["wire_format_requested"] == "auto" else "")
          + ": actual fetch "
          f"{st['bytes_wire_fetch']/1e6:.3f}MB verify "
          f"{st['bytes_wire_verify']/1e6:.3f}MB "
          f"(raw-equivalent {(st['bytes_fetch'] + st['bytes_verify'])/1e6:.3f}MB)")
    if st["cache_enabled"]:
        print(f"[enum] cache {cfg.cache_slots}x{cfg.cache_ways}: "
              f"hit-rate {st['cache_hit_rate']:.3f} "
              f"({st['cache_hits']:.0f}/{st['cache_probes']:.0f} probes) | "
              f"saved {st['bytes_saved_cache']/1e6:.2f}MB | "
              f"varint fetch {st['bytes_fetch_compressed']/1e6:.2f}MB | "
              f"resident {st['cache_bytes']/1e6:.2f}MB")
    else:
        print("[enum] cache disabled")
    print(f"[enum] pipeline: depth {st['pipeline_depth']}"
          f"{' (auto->%d)' % st['auto_depth'] if 'auto_depth' in st else ''} | "
          f"{st['n_waves']} waves, max {st['max_inflight_waves']} in flight | "
          f"steals {st['steal_events']} | "
          f"wave-time {st['wave_s_total']:.2f}s over "
          f"{st.get('dist_pipeline_s', 0.0) + st.get('sme_pipeline_s', 0.0):.2f}s wall")


if __name__ == "__main__":
    main()
