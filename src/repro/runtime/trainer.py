"""Trainer: jit'd train step with sharded params/opt-state, periodic async
checkpoints, crash-restart recovery, straggler monitoring, optional int8+EF
gradient compression and gradient accumulation.

Fault-tolerance contract (exercised by tests/test_runtime.py):
* every ``ckpt_every`` steps the full (params, opt_state, step) is saved
  asynchronously and atomically;
* ``Trainer.restore()`` resumes from the latest checkpoint onto the
  *current* mesh (elastic: the mesh may differ from the writer's);
* a ``FaultInjector`` can kill any step; the driver loop catches, restores,
  and replays — losses after recovery match the uninterrupted run bit-for-
  bit (same data keyed by step).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpoint import (latest_step, load_checkpoint,
                                         save_checkpoint)
from repro.compat import tree_map
from repro.distributed.compression import (compress_roundtrip,
                                           init_error_feedback)
from repro.optim.adamw import AdamWConfig, adamw_update, init_opt_state


class FaultInjector:
    """Deterministic fault schedule for tests: raises at given steps."""

    def __init__(self, fail_at: set[int] | None = None):
        self.fail_at = set(fail_at or ())
        self.tripped: set[int] = set()

    def check(self, step: int):
        if step in self.fail_at and step not in self.tripped:
            self.tripped.add(step)
            raise RuntimeError(f"injected fault at step {step}")


@dataclass
class TrainerConfig:
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    grad_accum: int = 1
    grad_compression: str = "none"       # none | int8_ef
    straggler_threshold: float = 2.0     # x median step time
    log_every: int = 10


class Trainer:
    def __init__(self, loss_fn: Callable, params, opt_cfg: AdamWConfig,
                 tcfg: TrainerConfig, param_shardings=None, donate: bool = True):
        self.loss_fn = loss_fn
        self.params = params
        self.opt_cfg = opt_cfg
        self.tcfg = tcfg
        self.opt_state = init_opt_state(params, opt_cfg)
        self.err_fb = (init_error_feedback(params)
                       if tcfg.grad_compression == "int8_ef" else None)
        self.step = 0
        self.step_times: list[float] = []
        self._ckpt_thread = None
        self._last_ckpt_step = 0
        if param_shardings is not None:
            self.params = tree_map(
                lambda p, s: jax.device_put(p, s), self.params, param_shardings)

        def _one_step(params, opt_state, err_fb, batch):
            def microbatch_loss(p, mb):
                return self.loss_fn(p, mb)

            if tcfg.grad_accum > 1:
                def acc_body(carry, mb):
                    lsum, gsum = carry
                    l, g = jax.value_and_grad(microbatch_loss)(params, mb)
                    gsum = tree_map(jnp.add, gsum, g)
                    return (lsum + l, gsum), None
                zeros = tree_map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                (lsum, gsum), _ = jax.lax.scan(
                    acc_body, (jnp.zeros(()), zeros), batch)
                loss = lsum / tcfg.grad_accum
                grads = tree_map(lambda g: g / tcfg.grad_accum, gsum)
            else:
                loss, grads = jax.value_and_grad(microbatch_loss)(params, batch)
            if err_fb is not None:
                grads, err_fb = compress_roundtrip(grads, err_fb)
            params, opt_state, info = adamw_update(
                params, grads, opt_state, opt_cfg)
            return params, opt_state, err_fb, loss, info

        donate_args = (0, 1, 2) if donate else ()
        self._step_fn = jax.jit(_one_step, donate_argnums=donate_args)

    # ------------------------------------------------------------------ #
    def train_step(self, batch, fault: FaultInjector | None = None) -> dict:
        t0 = time.perf_counter()
        if fault is not None:
            fault.check(self.step)
        (self.params, self.opt_state, self.err_fb, loss, info
         ) = self._step_fn(self.params, self.opt_state, self.err_fb, batch)
        loss = float(loss)
        self.step += 1
        dt = time.perf_counter() - t0
        self.step_times.append(dt)
        out = dict(step=self.step, loss=loss, secs=dt,
                   grad_norm=float(info["grad_norm"]), lr=float(info["lr"]),
                   straggler=self.is_straggler(dt))
        if self.step % self.tcfg.ckpt_every == 0:
            self.save()
            self._last_ckpt_step = self.step
        return out

    def is_straggler(self, dt: float) -> bool:
        """Step-time watchdog: on a real pod this triggers work re-balance /
        hot-spare swap; here it is surfaced to the driver."""
        if len(self.step_times) < 5:
            return False
        med = float(np.median(self.step_times[-50:]))
        return dt > self.tcfg.straggler_threshold * med

    # ------------------------------------------------------------------ #
    def save(self, blocking: bool = False):
        if self._ckpt_thread is not None:
            self._ckpt_thread.join()
        tree = dict(params=self.params, opt_state=self.opt_state,
                    err_fb=self.err_fb)
        self._ckpt_thread = save_checkpoint(
            self.tcfg.ckpt_dir, self.step, tree, blocking=blocking)

    def restore(self, shardings=None) -> bool:
        """Resume from the newest checkpoint; True if one was found."""
        if self._ckpt_thread is not None:
            self._ckpt_thread.join()
            self._ckpt_thread = None
        if latest_step(self.tcfg.ckpt_dir) is None:
            return False
        like = dict(params=self.params, opt_state=self.opt_state,
                    err_fb=self.err_fb)
        tree, step = load_checkpoint(self.tcfg.ckpt_dir, like,
                                     shardings=shardings)
        self.params = tree["params"]
        self.opt_state = tree["opt_state"]
        self.err_fb = tree["err_fb"]
        self.step = step
        self._last_ckpt_step = step
        return True

    # ------------------------------------------------------------------ #
    def run(self, data_iter, n_steps: int, fault: FaultInjector | None = None,
            max_restarts: int = 3, log: Callable = print) -> list[dict]:
        """Fault-tolerant driver loop: crash -> restore -> replay."""
        history: list[dict] = []
        restarts = 0
        data_by_step: dict[int, Any] = {}
        it = iter(data_iter)
        if latest_step(self.tcfg.ckpt_dir) is None:
            self.save(blocking=True)      # step-0 anchor for crash-before-ckpt
        while self.step < n_steps:
            s = self.step
            if s not in data_by_step:
                data_by_step[s] = next(it)
            try:
                out = self.train_step(data_by_step[s], fault)
            except RuntimeError as e:
                restarts += 1
                if restarts > max_restarts:
                    raise
                log(f"[trainer] fault at step {s}: {e}; restoring...")
                if not self.restore():
                    # no checkpoint yet: restart from step 0 params is not
                    # possible (donated) — checkpoint at step 0 guards this
                    raise
                continue
            history.append(out)
            if out["step"] % self.tcfg.log_every == 0:
                log(f"[trainer] step {out['step']} loss {out['loss']:.4f} "
                    f"lr {out['lr']:.2e} {out['secs']*1e3:.0f}ms"
                    + (" STRAGGLER" if out["straggler"] else ""))
            # free data older than the restore horizon (last checkpoint):
            # a crash can rewind at most to _last_ckpt_step, so batches for
            # steps >= that must stay replayable
            for k in [k for k in data_by_step if k < self._last_ckpt_step]:
                del data_by_step[k]
        return history
