"""Persistent AOT stage-executable cache — the serving latency floor killer.

The enumeration engine jits ~3 stages x units x 2 phases per workload and
``compile_us`` (seconds per stage on CPU XLA) dominates every benchmark
cell, 2-8x steady-state ``wall_us``.  All of that work is a pure function
of *static* inputs — the query plan, the engine capacities, the graph
geometry, the wire/storage/cache configuration, and the jax build — so a
warm server should never trace or compile anything.  This module is the
per-host on-disk store that makes that true:
:class:`~repro.core.scheduler.StageRunner` resolves every stage through
:class:`StageExecCache` before falling back to ``jax.jit`` tracing, and
a populated store turns a whole run into pure executable dispatch
(``stats["compiles"] == 0``).

Key schema
----------
An entry's digest is ``sha256`` over four independent layers, any of which
changing MUST invalidate the entry:

1. **environment stamp** (:func:`repro.compat.version_stamp`): exact
   jax/jaxlib versions, backend platform, visible device count — pickled
   XLA executables are only valid on the build that produced them;
2. **code fingerprint** (:func:`code_fingerprint`): sha256 over the source
   bytes of every module whose Python code is baked into a stage trace
   (engine, wire codecs, adjacency cache, exchange backends, storage
   formats, kernel ops, compat) — editing engine code invalidates the
   store wholesale, the bluntest and only safe granularity;
3. **stage context** (:func:`stage_context`): the stage key (kind, unit,
   local-only), the canonical plan/pattern repr, the exchange mode, and
   the *stage-relevant* ``EngineConfig`` fields.  Relevance is per stage
   kind so cells that genuinely share a trace share an entry: an
   ``expand`` executable does not depend on ``wire_format``, so the
   raw / varint / auto benchmark cells reuse one expand entry and pay
   only their marginal fetch/verify compiles;
4. **argument signature** (:func:`arg_signature`): the flattened treedef
   repr plus every leaf's ``(shape, dtype)``.  Custom pytree nodes
   (``DeviceGraph``, ``AdjCache``, ``WaveState``) carry their static
   geometry in treedef aux data, so graph size, storage format, cache
   geometry, seed capacity, and row width are all captured here without
   being re-listed in layer 3.

Invalidation rules
------------------
There is no in-place invalidation: every variation lands on a different
digest and stale digests simply stop being read (an external tool may
garbage-collect by mtime).  Two defensive layers turn *corruption* into a
cache miss instead of a crash: the pickled envelope stores the full key
material and :meth:`StageExecCache.load` rejects an envelope whose
recorded material mismatches the digest's (hash collision / truncated
write), and any unpickling or executable-load error is caught, warned
about once per file, and treated as a miss — the runner then falls back
to tracing and overwrites the bad entry with a fresh one.

Version stamping
----------------
Layers 1+2 are the version stamp.  They are *inside* the digest (stale
builds miss rather than load-and-crash) and *inside* the envelope (a
digest collision across builds is still refused at load time).

Pre-warm protocol
-----------------
``StageRunner.prewarm`` walks the stage ladder **abstractly** — the wave
state shapes for a seed capacity are derived with ``jax.eval_shape``, no
device work — and resolves each stage through this store from a
background thread while host-side group formation runs.  A warm store
makes pre-warming pure deserialization; a cold one moves the XLA compile
off the critical path, which is what finally lets the async pipeline win:
stage dispatch never stalls on a compile the scheduler could have paid
for during Algorithm-3 grouping.  Loaded executables are additionally
memoized in-process (keyed by absolute path + digest) so the warm
benchmark cells do not even re-read the files.

The store layout is flat: ``<dir>/<digest>.stagex``, written via
``tempfile + os.replace`` so concurrent runs on one host never observe a
torn file and duplicate writers are idempotent.

Known limitation: a ``Compiled`` executable also bakes its input
*shardings*, which the signature does not capture — the spmd backend
therefore disables both the store and the abstract pre-warm
(:class:`~repro.core.scheduler.StageRunner` forces ``exec_cache=None``)
and resolves stages from the live sharded arrays; see ROADMAP open
item 2 residuals.
"""
from __future__ import annotations

import hashlib
import os
import pickle
import tempfile
import warnings
from dataclasses import dataclass, field

import jax

from repro import compat
from repro.obs.metrics import COUNTER, Instrument, MetricsRegistry

__all__ = ["StageExecCache", "arg_signature", "code_fingerprint",
           "stage_context", "build_exec_cache"]


def _store_stats_registry() -> MetricsRegistry:
    """The store's typed counter set.  Declared here — not in
    :mod:`repro.obs.schema` — because these are registry-internal to the
    executable store and surface upward only as deltas through the single
    top-level ``exec_cache`` instrument; values start at 0 (not UNSET) so
    ``dict(cache.stats)`` and counter-delta arithmetic see every key."""
    reg = MetricsRegistry(Instrument(n, COUNTER, "", d) for n, d in (
        ("hits", "entries loaded (memo or disk)"),
        ("misses", "lookups with no entry"),
        ("stores", "fresh executables persisted"),
        ("errors", "corrupt/stale/unserializable entries degraded"),
        ("evictions", "LRU garbage-collected envelopes")))
    for ins in reg.instruments():
        reg[ins.name] = 0
    return reg

_ENVELOPE_VERSION = 1
_SUFFIX = ".stagex"

# in-process memo of loaded executables: (store path, digest) -> callable.
# Deserialized executables are stateless, so sharing them across
# StageRunner instances (the benchmark sweep builds many) is safe and
# makes warm resolution free of even the disk read.
_LOADED_MEMO: dict[tuple[str, str], object] = {}


def arg_signature(args: tuple) -> tuple:
    """Hashable abstract signature of a stage call's arguments.

    Works identically for concrete arrays and ``jax.ShapeDtypeStruct``
    placeholders (the pre-warm path), so an abstract pre-warm resolves to
    the same slot a concrete dispatch hits.  Treedef reprs include each
    custom node's aux data — graph/cache geometry rides along for free.
    """
    leaves, treedef = jax.tree_util.tree_flatten(args)
    return (str(treedef),
            tuple((tuple(getattr(leaf, "shape", ())),
                   str(getattr(leaf, "dtype", type(leaf).__name__)))
                  for leaf in leaves))


# modules whose source is baked into stage traces (layer 2 of the key)
_TRACED_MODULES = (
    "repro.core.engine", "repro.core.wire", "repro.core.cache",
    "repro.core.exchange", "repro.graph.storage", "repro.compat",
    "repro.kernels.membership.ops", "repro.kernels.membership.kernel",
    "repro.kernels.membership.ref", "repro.kernels.intersect.ops",
    "repro.kernels.intersect.kernel", "repro.kernels.intersect.ref",
    "repro.kernels.varint.ops", "repro.kernels.varint.kernel",
    "repro.kernels.varint.ref",
)
_CODE_FP: str | None = None


def code_fingerprint() -> str:
    """sha256 over the source bytes of every trace-relevant module.

    Memoized per process (sources cannot change under a running
    interpreter in any way the jit caches would notice either)."""
    global _CODE_FP
    if _CODE_FP is None:
        import importlib

        h = hashlib.sha256()
        for name in _TRACED_MODULES:
            mod = importlib.import_module(name)
            src = getattr(mod, "__file__", None)
            h.update(name.encode())
            if src and os.path.exists(src):
                with open(src, "rb") as f:
                    h.update(f.read())
        _CODE_FP = h.hexdigest()
    return _CODE_FP


def stage_context(stage_key, cfg, exch_mode: str, plan_repr: str) -> tuple:
    """Layer 3 of the key: everything a stage's *trace* reads that is not
    already visible in the argument signature.

    ``stage_key`` is the StageRunner jit-cache key (``"init"``,
    ``("fetch", ui)``, ``("expand", ui, local_only)``, ...).  Config
    relevance is per stage kind — see the module docstring; when in doubt
    a field belongs here (a spurious miss costs one compile, a spurious
    hit costs correctness)."""
    kind = stage_key if isinstance(stage_key, str) else stage_key[0]
    # the comm-pipelining knobs change the exchange program structure
    # (chunked vs single-shot a2a), so every stage with a collective
    # keys on them; getattr guards configs predating the knobs
    comm = (bool(getattr(cfg, "comm_pipeline", False)),
            int(getattr(cfg, "comm_chunks", 1)))
    if kind == "fetch":
        knobs = (cfg.fetch_cap, cfg.wire_format, cfg.use_pallas_kernels,
                 cfg.enable_cache, cfg.cache_slots, cfg.cache_ways,
                 cfg.cache_decay) + comm
    elif kind == "expand":
        knobs = (cfg.frontier_cap, cfg.use_pallas_kernels)
    elif kind == "verify":
        knobs = (cfg.verify_cap, cfg.wire_format,
                 cfg.use_pallas_kernels) + comm
    else:                      # init / finalize: pure shape transformers
        knobs = ()
    return (repr(stage_key), plan_repr, exch_mode, kind, knobs)


@dataclass
class StageExecCache:
    """Per-host on-disk store of serialized stage executables.

    ``stats`` counts ``hits`` (entry loaded — memo or disk), ``misses``
    (no entry), ``stores`` (fresh executables persisted), ``errors``
    (corrupt/stale/unserializable entries that degraded to a miss or a
    skipped store), and ``evictions`` (LRU garbage collection).  The
    store is inert — ``enabled`` False — when the JAX build cannot
    serialize executables; callers need no special casing, every ``load``
    just misses and every ``store`` no-ops.

    ``budget_bytes > 0`` bounds the on-disk size: after every store the
    least-recently-used ``.stagex`` envelopes (file mtime — refreshed on
    every disk *load* too, so a hot entry never looks cold) are evicted
    until the directory fits the budget.  Entries otherwise accrete per
    (pattern, caps, format) forever.  ``0`` keeps the store unbounded.
    """

    path: str
    budget_bytes: int = 0
    stats: MetricsRegistry = field(default_factory=_store_stats_registry)

    def __post_init__(self):
        self.path = os.path.abspath(self.path)
        self.enabled = compat.HAS_EXECUTABLE_SERIALIZATION
        if self.enabled:
            os.makedirs(self.path, exist_ok=True)

    # -- keying ------------------------------------------------------------- #
    def digest(self, stage_key, sig: tuple, context: tuple) -> str:
        """sha256 of the four key layers (see module docstring)."""
        material = self._material(sig, context)
        return hashlib.sha256(material.encode()).hexdigest()

    def _material(self, sig: tuple, context: tuple) -> str:
        return repr((_ENVELOPE_VERSION, compat.version_stamp(),
                     code_fingerprint(), context, sig))

    def _file(self, digest: str) -> str:
        return os.path.join(self.path, digest + _SUFFIX)

    # -- load / store ------------------------------------------------------- #
    def load(self, digest: str, sig: tuple, context: tuple):
        """Loaded executable for ``digest`` or ``None`` (miss).

        Corrupt, truncated, stale, or cross-build files are demoted to a
        miss with a warning — the engine must keep running on a damaged
        cache directory, just slower."""
        if not self.enabled:
            self.stats["misses"] += 1
            return None
        memo_key = (self.path, digest)
        fn = _LOADED_MEMO.get(memo_key)
        if fn is not None:
            self.stats["hits"] += 1
            return fn
        fname = self._file(digest)
        if not os.path.exists(fname):
            self.stats["misses"] += 1
            return None
        try:
            with open(fname, "rb") as f:
                env = pickle.load(f)
            if (not isinstance(env, dict)
                    or env.get("version") != _ENVELOPE_VERSION
                    or env.get("material") != self._material(sig, context)):
                raise ValueError("stale or mismatched cache envelope")
            fn = compat.deserialize_compiled(env["payload"])
        except Exception as e:   # corrupt pickle, stale build, bad envelope
            self.stats["errors"] += 1
            self.stats["misses"] += 1
            warnings.warn(
                f"compile cache: dropping unusable entry {fname}: {e!r} "
                f"(falling back to jit tracing)", RuntimeWarning,
                stacklevel=2)
            try:
                os.remove(fname)
            except OSError:
                pass
            return None
        try:
            os.utime(fname, None)   # LRU touch: a disk hit is recent use
        except OSError:
            pass
        _LOADED_MEMO[memo_key] = fn
        self.stats["hits"] += 1
        return fn

    def store(self, digest: str, sig: tuple, context: tuple,
              compiled) -> bool:
        """Persist a freshly compiled stage executable (atomic replace)."""
        if not self.enabled:
            return False
        try:
            payload = compat.serialize_compiled(compiled)
            env = dict(version=_ENVELOPE_VERSION,
                       material=self._material(sig, context),
                       payload=payload)
            blob = pickle.dumps(env)
        except Exception as e:   # unpicklable executable: cache-skip, run on
            self.stats["errors"] += 1
            warnings.warn(
                f"compile cache: could not serialize stage executable: "
                f"{e!r} (entry skipped)", RuntimeWarning, stacklevel=2)
            return False
        fd, tmp = tempfile.mkstemp(dir=self.path, suffix=".tmp")
        try:
            with os.fdopen(fd, "wb") as f:
                f.write(blob)
            os.replace(tmp, self._file(digest))
        except OSError:
            self.stats["errors"] += 1
            try:
                os.remove(tmp)
            except OSError:
                pass
            return False
        self.stats["stores"] += 1
        self._gc()
        return True

    # -- maintenance -------------------------------------------------------- #
    def _gc(self) -> int:
        """Evict least-recently-used envelopes until the store fits
        ``budget_bytes``.  The just-stored entry has the freshest mtime,
        so it is evicted last — a budget smaller than one envelope
        degrades to "keep only the newest".  Concurrent runs may race on
        removals; a vanished file is simply already-evicted."""
        if not self.enabled or self.budget_bytes <= 0:
            return 0
        try:
            files = [os.path.join(self.path, f)
                     for f in os.listdir(self.path) if f.endswith(_SUFFIX)]
            stats = []
            for f in files:
                try:
                    st = os.stat(f)
                    stats.append((st.st_mtime, st.st_size, f))
                except OSError:
                    continue
        except OSError:
            return 0
        total = sum(s for _, s, _ in stats)
        evicted = 0
        for mtime, size, fname in sorted(stats):   # oldest first
            if total <= self.budget_bytes:
                break
            try:
                os.remove(fname)
            except OSError:
                continue
            total -= size
            evicted += 1
        self.stats["evictions"] += evicted
        return evicted

    @staticmethod
    def clear_memory_memo() -> None:
        """Drop the in-process loaded-executable memo (tests use this to
        force the on-disk deserialization path)."""
        _LOADED_MEMO.clear()

    def entries(self) -> list[str]:
        """Digests currently stored on disk (sorted; diagnostics/tests)."""
        if not self.enabled or not os.path.isdir(self.path):
            return []
        return sorted(f[:-len(_SUFFIX)] for f in os.listdir(self.path)
                      if f.endswith(_SUFFIX))


def build_exec_cache(cfg) -> StageExecCache | None:
    """The store ``EngineConfig`` asks for (``None`` = disabled)."""
    if not getattr(cfg, "compile_cache_dir", ""):
        return None
    return StageExecCache(
        cfg.compile_cache_dir,
        budget_bytes=int(getattr(cfg, "compile_cache_budget_bytes", 0)))
