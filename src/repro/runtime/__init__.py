from repro.runtime.compile_cache import (StageExecCache, arg_signature,
                                         build_exec_cache, code_fingerprint,
                                         stage_context)
from repro.runtime.trainer import Trainer, TrainerConfig, FaultInjector
