from repro.runtime.trainer import Trainer, TrainerConfig, FaultInjector
