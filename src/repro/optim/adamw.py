"""AdamW + schedules — dependency-free (no optax in the container).

Moments live in f32 by default; ``moment_dtype='bfloat16'`` halves optimizer
memory (the DeepSeek-V3 configuration for 671B on 16GB-HBM chips). Optimizer
state inherits the parameter shardings (ZeRO: FSDP'd params => FSDP'd
moments for free).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1


def schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def init_opt_state(params, cfg: AdamWConfig):
    dt = jnp.bfloat16 if cfg.moment_dtype == "bfloat16" else jnp.float32
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return dict(mu=jax.tree.map(zeros, params),
                nu=jax.tree.map(zeros, params),
                step=jnp.zeros((), jnp.int32))


def global_norm(tree):
    sq = jax.tree.map(lambda g: jnp.sum(
        jnp.square(g.astype(jnp.float32))), tree)
    return jnp.sqrt(jax.tree.reduce(jnp.add, sq))


def adamw_update(params, grads, state, cfg: AdamWConfig):
    step = state["step"] + 1
    lr = schedule(cfg, step)
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gn, 1e-9))

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu32 = mu.astype(jnp.float32) * cfg.b1 + (1 - cfg.b1) * g
        nu32 = nu.astype(jnp.float32) * cfg.b2 + (1 - cfg.b2) * g * g
        mu_hat = mu32 / (1 - cfg.b1 ** step)
        nu_hat = nu32 / (1 - cfg.b2 ** step)
        delta = mu_hat / (jnp.sqrt(nu_hat) + cfg.eps)
        decay = cfg.weight_decay * p.astype(jnp.float32) if p.ndim >= 2 else 0.0
        new_p = p.astype(jnp.float32) - lr * (delta + decay)
        return (new_p.astype(p.dtype), mu32.astype(mu.dtype),
                nu32.astype(nu.dtype))

    out = jax.tree.map(upd, params, grads, state["mu"], state["nu"])
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    return new_params, dict(mu=new_mu, nu=new_nu, step=step), dict(
        grad_norm=gn, lr=lr)
