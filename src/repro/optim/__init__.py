from repro.optim.adamw import (AdamWConfig, adamw_update, init_opt_state,
                               schedule, global_norm)
