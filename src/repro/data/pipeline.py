"""Data pipelines: deterministic synthetic streams (LM tokens / graph
batches / DIN batches), host-sharded by (step, shard) so every data-parallel
rank draws disjoint data without coordination, with a background prefetch
thread (double buffering) — the standard input-bound mitigation.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass

import numpy as np


class Prefetcher:
    """Wrap an iterator with a daemon prefetch thread (depth-2 buffer)."""

    def __init__(self, it, depth: int = 2):
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._it = it
        self._done = object()
        self._th = threading.Thread(target=self._run, daemon=True)
        self._th.start()

    def _run(self):
        for x in self._it:
            self.q.put(x)
        self.q.put(self._done)

    def __iter__(self):
        return self

    def __next__(self):
        x = self.q.get()
        if x is self._done:
            raise StopIteration
        return x


def lm_token_stream(vocab: int, batch: int, seq_len: int, seed: int = 0,
                    n_steps: int | None = None):
    """Synthetic-but-learnable stream: Zipf unigrams + a deterministic
    bigram rule (token t+1 = (a*t + c) % V with prob 0.5) so training loss
    actually falls — validates the end-to-end optimizer path."""
    step = 0
    zipf_p = 1.0 / (np.arange(1, vocab + 1) ** 1.1)
    zipf_p /= zipf_p.sum()
    while n_steps is None or step < n_steps:
        rng = np.random.default_rng(seed * 1_000_003 + step)
        toks = rng.choice(vocab, size=(batch, seq_len + 1), p=zipf_p)
        follow = (toks[:, :-1] * 31 + 17) % vocab
        coin = rng.random((batch, seq_len)) < 0.5
        toks[:, 1:] = np.where(coin, follow, toks[:, 1:])
        yield dict(tokens=toks[:, :-1].astype(np.int32),
                   labels=toks[:, 1:].astype(np.int32))
        step += 1


def din_batch_stream(n_items: int, n_cates: int, n_user: int, batch: int,
                     seq_len: int, n_user_multihot: int = 4, seed: int = 0,
                     n_steps: int | None = None):
    """CTR stream with planted signal: label = 1 iff target cate appears in
    the history cates (plus noise)."""
    step = 0
    while n_steps is None or step < n_steps:
        rng = np.random.default_rng(seed * 7_000_003 + step)
        hist_items = rng.integers(0, n_items, (batch, seq_len))
        hist_cates = hist_items % n_cates
        hist_len = rng.integers(seq_len // 4, seq_len + 1, (batch,))
        mask = np.arange(seq_len)[None, :] < hist_len[:, None]
        tgt_item = rng.integers(0, n_items, (batch,))
        tgt_cate = tgt_item % n_cates
        match = ((hist_cates == tgt_cate[:, None]) & mask).any(1)
        noise = rng.random(batch) < 0.1
        labels = np.where(noise, ~match, match).astype(np.float32)
        yield dict(user_feats=rng.integers(0, n_user, (batch, n_user_multihot)).astype(np.int32),
                   target_item=tgt_item.astype(np.int32),
                   target_cate=tgt_cate.astype(np.int32),
                   hist_items=hist_items.astype(np.int32),
                   hist_cates=hist_cates.astype(np.int32),
                   hist_mask=mask,
                   labels=labels)
        step += 1


def gnn_epoch_stream(graph, feats: np.ndarray, labels: np.ndarray,
                     batch_nodes: int, fanout: tuple[int, ...], seed: int = 0,
                     n_steps: int | None = None):
    """Sampled-training stream over a big graph (minibatch_lg shape)."""
    from repro.graph.sampler import sample_neighbors
    rng = np.random.default_rng(seed)
    step = 0
    while n_steps is None or step < n_steps:
        seeds = rng.choice(graph.n, size=batch_nodes, replace=False)
        sub = sample_neighbors(graph, seeds, fanout, rng)
        node_ids = np.clip(sub.nodes, 0, graph.n - 1)
        yield dict(node_feats=feats[node_ids],
                   edge_src=sub.edge_src, edge_dst=sub.edge_dst,
                   edge_mask=sub.edge_mask,
                   labels=labels[node_ids],
                   label_mask=sub.seed_mask & (sub.nodes >= 0))
        step += 1
