from repro.data.pipeline import (Prefetcher, lm_token_stream,
                                 din_batch_stream, gnn_epoch_stream)
