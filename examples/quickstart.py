"""Quickstart: enumerate triangles (and a 5-vertex pattern) on a synthetic
DBLP-like graph partitioned over 4 'machines', with the full RADS pipeline:
plan computation, SM-E split, region groups, fetchV/verifyE exchanges.

    PYTHONPATH=src python examples/quickstart.py
"""
import time

from repro.configs.rads import DEFAULT_ENGINE, EngineConfig, QUERIES
from repro.core import Pattern, best_plan, rads_enumerate
from repro.core.baselines import psgl_enumerate
from repro.graph import load_dataset, partition

g = load_dataset("dblp_bench")
print(f"data graph: {g.n} vertices, {g.n_edges} edges, "
      f"max degree {g.max_degree}")
pg = partition(g, 4, method="bfs")

for qname in ("q1", "q5"):
    pattern = Pattern.from_edges(QUERIES[qname])
    plan = best_plan(pattern)
    print(f"\n=== {qname}: {pattern.n} vertices, "
          f"{len(pattern.edges)} edges ===")
    print("execution plan:", [(u.piv, u.leaves) for u in plan.units],
          f"({plan.n_rounds} rounds, matching order {plan.matching_order})")
    t0 = time.perf_counter()
    cfg = EngineConfig(frontier_cap=1 << 13, fetch_cap=1 << 10,
                       verify_cap=1 << 12, region_group_budget=1 << 12)
    res = rads_enumerate(pg, pattern, cfg, mode="sim",
                         return_embeddings=False)
    dt = time.perf_counter() - t0
    st = res.stats
    print(f"RADS: {res.count} embeddings in {dt:.2f}s | SM-E seeds "
          f"{st['n_sme_seeds']}/{st['n_sme_seeds']+st['n_dist_seeds']} | "
          f"fetchV {st['bytes_fetch']/1e3:.1f}KB verifyE "
          f"{st['bytes_verify']/1e3:.1f}KB | adj-cache hit-rate "
          f"{st['cache_hit_rate']:.2f} (saved "
          f"{st['bytes_saved_cache']/1e3:.1f}KB)")
    base = psgl_enumerate(pg, pattern, return_embeddings=False)
    print(f"PSgL baseline: {base.count} embeddings, shuffled "
          f"{base.bytes_shuffled/1e3:.1f}KB "
          f"(RADS ships {base.bytes_shuffled/max(st['bytes_fetch']+st['bytes_verify'],1):.1f}x less)")
    assert base.count == res.count
