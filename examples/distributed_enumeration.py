"""End-to-end distributed driver (the paper's kind of workload): run the
R-Meef engine in true SPMD mode over 8 devices — real ``all_to_all``
fetchV/verifyE under shard_map — and validate against the single-machine
oracle. Re-execs itself with forced host devices.

    PYTHONPATH=src python examples/distributed_enumeration.py
"""
import os
import sys

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.execv(sys.executable, [sys.executable] + sys.argv)

import time

import jax

from repro.configs.rads import EngineConfig, QUERIES
from repro.core import Pattern, canonicalize, enumerate_oracle, rads_enumerate
from repro.graph import load_dataset, partition
from repro.launch.mesh import make_engine_mesh

NDEV = 8
print(f"devices: {jax.devices()}")
mesh = make_engine_mesh(NDEV)
g = load_dataset("dblp_bench")
pg = partition(g, NDEV, method="bfs")
cfg = EngineConfig(frontier_cap=1 << 14, fetch_cap=1 << 10,
                   verify_cap=1 << 12, region_group_budget=1 << 13)

for qname in ("q1", "q3"):
    pattern = Pattern.from_edges(QUERIES[qname])
    t0 = time.perf_counter()
    res = rads_enumerate(pg, pattern, cfg, mode="spmd", mesh=mesh)
    dt = time.perf_counter() - t0
    oracle = canonicalize(enumerate_oracle(g, pattern), pattern)
    ok = canonicalize(res.embeddings, pattern) == oracle
    st = res.stats
    print(f"{qname}: {res.count} embeddings in {dt:.1f}s on {NDEV} devices "
          f"| oracle match: {ok} | fetchV {st['bytes_fetch']/1e3:.1f}KB "
          f"verifyE {st['bytes_verify']/1e3:.1f}KB | groups {st['n_groups']}")
    assert ok
print("distributed enumeration verified against oracle.")
