"""Serve a DIN CTR model with batched requests: brief training on the
planted-signal stream, then batched online scoring + top-k retrieval
against a candidate set — the recsys serving shapes in miniature.

    PYTHONPATH=src python examples/serve_din.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.data import din_batch_stream
from repro.models.recsys import (DINBatch, din_logits, din_loss, init_din,
                                 retrieval_scores)
from repro.optim import AdamWConfig, adamw_update, init_opt_state

cfg = get_reduced("din")
params = init_din(jax.random.PRNGKey(0), cfg)
opt = AdamWConfig(lr=2e-3, warmup_steps=10, total_steps=300,
                  weight_decay=0.0)
state = init_opt_state(params, opt)


def to_batch(d):
    return DINBatch(**{k: jnp.asarray(v) for k, v in d.items()})


@jax.jit
def train_step(params, state, batch):
    loss, g = jax.value_and_grad(lambda p: din_loss(p, cfg, batch))(params)
    params, state, _ = adamw_update(params, g, state, opt)
    return params, state, loss


stream = din_batch_stream(cfg.n_items, cfg.n_cates, cfg.n_user_feats,
                          batch=256, seq_len=cfg.seq_len, seed=0)
for i, d in enumerate(stream):
    params, state, loss = train_step(params, state, to_batch(d))
    if i == 0 or (i + 1) % 100 == 0:
        print(f"train step {i+1}: loss {float(loss):.4f}")
    if i >= 299:
        break

# --- batched online serving (serve_p99 shape in miniature) --------------- #
serve = jax.jit(lambda p, b: jax.nn.sigmoid(din_logits(p, cfg, b)))
test = to_batch(next(iter(din_batch_stream(
    cfg.n_items, cfg.n_cates, cfg.n_user_feats, batch=512,
    seq_len=cfg.seq_len, seed=999))))
t0 = time.perf_counter()
scores = serve(params, test).block_until_ready()
lat = (time.perf_counter() - t0) * 1e3
auc_pairs = 0
pos = np.asarray(scores)[np.asarray(test.labels) > 0.5]
neg = np.asarray(scores)[np.asarray(test.labels) < 0.5]
auc = float((pos[:, None] > neg[None, :]).mean()) if len(pos) and len(neg) else 0.5
print(f"serve: batch=512 in {lat:.1f}ms | AUC {auc:.3f}")
assert auc > 0.65, "CTR model failed to learn the planted signal"

# --- retrieval: score 1 user against 100k candidates in one dot ---------- #
cand = jnp.arange(100_000) % cfg.n_items
t0 = time.perf_counter()
sc = retrieval_scores(params, cfg, test, cand, cand % cfg.n_cates)
topk = jax.lax.top_k(sc[0], 10)[1].block_until_ready()
print(f"retrieval: 100k candidates scored + top-10 in "
      f"{(time.perf_counter()-t0)*1e3:.1f}ms; top ids {np.asarray(topk)[:5]}")
