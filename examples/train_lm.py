"""Train a small qwen-style LM end to end on the synthetic-but-learnable
token stream: full production stack (AdamW + schedule, async checkpoints,
fault injection mid-run, bit-exact recovery). Loss must fall.

    PYTHONPATH=src python examples/train_lm.py [--steps 120]
"""
import argparse
import dataclasses
import shutil

import jax
import jax.numpy as jnp

from repro.configs import get_reduced
from repro.data import Prefetcher, lm_token_stream
from repro.models.transformer import init_lm_params, lm_loss
from repro.optim import AdamWConfig
from repro.runtime import FaultInjector, Trainer, TrainerConfig

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=120)
args = ap.parse_args()

cfg = dataclasses.replace(get_reduced("qwen3-4b"), d_model=128, n_layers=3,
                          vocab=512)
print(f"model: {cfg.n_layers}L d={cfg.d_model} vocab={cfg.vocab} "
      f"(~{cfg.param_count()/1e6:.2f}M params)")
params = init_lm_params(jax.random.PRNGKey(0), cfg)
loss_fn = lambda p, b: lm_loss(p, cfg, jnp.asarray(b["tokens"]),
                               jnp.asarray(b["labels"]))
shutil.rmtree("/tmp/repro_example_lm", ignore_errors=True)
tr = Trainer(loss_fn, params,
             AdamWConfig(lr=2e-3, warmup_steps=10, total_steps=args.steps),
             TrainerConfig(ckpt_dir="/tmp/repro_example_lm", ckpt_every=25,
                           log_every=20))
data = Prefetcher(lm_token_stream(cfg.vocab, 16, 64, seed=1))
# inject a fault mid-run: the trainer restores from the async checkpoint
# and replays — final losses are bit-identical to an uninterrupted run
hist = tr.run(data, args.steps, fault=FaultInjector(fail_at={60}))
print(f"loss: {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f} "
      f"(fault at step 60 recovered transparently)")
assert hist[-1]["loss"] < hist[0]["loss"] - 0.5, "model failed to learn"
